"""Quickstart: the HiStore hybrid index in 60 seconds.

    PYTHONPATH=src python examples/quickstart.py

One typed client over one index group (1 hash table + 2 sorted replicas +
logs): PUT / GET / SCAN / DELETE, a primary failure survived mid-stream,
and recovery — the paper's §3 in miniature, all through `HiStoreClient`.
"""
import jax
import numpy as np

from repro.configs.histore import scaled
from repro.core.client import HiStoreClient, LocalBackend
from repro.core.hashing import key_dtype
from repro.kernels import ops as kops

CFG = scaled(log_capacity=1 << 12, async_apply_batch=1024)


def main():
    client = HiStoreClient(LocalBackend(4096, CFG), batch_quantum=64,
                           apply_every_n_ops=2048)

    # which index hot path serves this demo: "kernel" (Pallas GET-probe /
    # scan / merge kernels) or "jnp" (the reference path) — cfg knob
    # use_kernels=off|on|auto, auto resolves by platform + HISTORE_USE_KERNELS
    print(f"index hot path: {kops.active_path(CFG, key_dtype())} "
          f"(use_kernels={CFG.use_kernels}, "
          f"platform={jax.default_backend()})")

    # PUT a batch (primary log -> backup logs -> hash table, §3.2.2)
    keys = np.random.RandomState(0).choice(10 ** 6, 500, replace=False)
    res = client.put(keys, np.arange(500))
    print(f"PUT 500 keys: ok={res.all_ok} retries={res.retries}")

    # GET: one-sided hash probe (1 sub-bucket read each), typed result
    g = client.get(keys[:8])
    print(f"GET hits={g.found.tolist()} accesses={g.accesses.tolist()} "
          f"values={g.values[:, 0].tolist()}")

    # SCAN: drains the async log, then walks the sorted replica
    s = client.scan(0, 10 ** 6, limit=10)
    print(f"SCAN first {int(s.count)} keys: {s.keys[:int(s.count)].tolist()}")

    # DELETE: tombstone through the log; compacts out of the replicas
    d = client.delete(keys[:4])
    g = client.get(keys[:8])
    print(f"DELETE 4: found={d.found.tolist()} -> GET now "
          f"hits={g.found.tolist()}")

    # failure: primary dies; GETs fall back to sorted replica + pending log
    client.fail_server(0)
    g = client.get(keys[4:8])
    print(f"degraded GET hits={g.found.tolist()} "
          f"accesses={g.accesses.tolist()}")

    # recovery: rebuild the hash table from a sorted replica (§4.3)
    client.recover_server(0)
    g = client.get(keys[4:8])
    print(f"post-recovery GET hits={g.found.tolist()} "
          f"accesses={g.accesses.tolist()}")
    assert g.all_found

    # telemetry: every op above was counted + histogrammed (the default
    # cfg.telemetry="counters"); scrape-ready Prometheus text
    print("\n--- client.metrics_text() ---")
    print(client.metrics_text())
    print("quickstart OK")


if __name__ == "__main__":
    main()
