"""Quickstart: the HiStore hybrid index in 60 seconds.

    PYTHONPATH=src python examples/quickstart.py

Builds one index group (1 hash table + 2 sorted replicas + logs), runs
PUT / GET / SCAN / DELETE, injects a primary failure, keeps serving, and
recovers — the paper's §3 in miniature.
"""
import jax.numpy as jnp
import numpy as np

from repro.configs.histore import scaled
from repro.core import index_group as ig
from repro.core.hashing import key_dtype

CFG = scaled(log_capacity=1 << 12, async_apply_batch=1024)
KD = key_dtype()


def main():
    g = ig.create(capacity=4096, cfg=CFG)

    # PUT a batch (primary log -> backup logs -> hash table, §3.2.2)
    keys = jnp.asarray(np.random.RandomState(0).choice(10 ** 6, 500,
                                                       replace=False), KD)
    addrs = jnp.arange(500, dtype=jnp.int32)
    g, ok = ig.put(g, keys, addrs, CFG)
    print(f"PUT 500 keys: ok={bool(ok.all())}")

    # GET: one-sided hash probe (1 sub-bucket read each)
    addr, found, acc = ig.get(g, keys[:8], CFG)
    print(f"GET hits={found.tolist()} accesses={acc.tolist()}")

    # SCAN: drains the async log, then walks the sorted replica
    (sk, sa, n), g = ig.scan(g, jnp.asarray(0, KD),
                             jnp.asarray(10 ** 6, KD), 10, CFG)
    print(f"SCAN first {int(n)} keys: {sk[:int(n)].tolist()}")

    # failure: primary dies; GETs fall back to sorted replica + pending log
    g = ig.fail(g, 0)
    addr, found, acc = ig.get(g, keys[:4], CFG)
    print(f"degraded GET hits={found.tolist()} accesses={acc.tolist()}")

    # recovery: rebuild the hash table from a sorted replica (§4.3)
    g = ig.recover_primary(g, CFG)
    addr, found, acc = ig.get(g, keys[:4], CFG)
    print(f"post-recovery GET hits={found.tolist()} accesses={acc.tolist()}")
    print("quickstart OK")


if __name__ == "__main__":
    main()
