"""End-to-end training driver: a ~100M-class decoder trained for a few
hundred steps on the synthetic LM stream, with checkpoints and restart.

    PYTHONPATH=src python examples/train_lm.py --steps 100
    PYTHONPATH=src python examples/train_lm.py --steps 200   # resumes!

The default size is CPU-friendly (~20M params; pass --d-model 704
--n-layers 12 for the full ~100M run on real hardware).  Loss on the
synthetic copy-structure stream drops from ~ln(V) toward the copy floor.
"""
import argparse

import jax

from repro.configs.base import ModelConfig, ShapeSpec
from repro.launch.mesh import make_local_mesh
from repro.train.trainer import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--d-model", type=int, default=384)
    ap.add_argument("--n-layers", type=int, default=6)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    cfg = ModelConfig(
        name="train-lm-demo", family="dense",
        n_layers=args.n_layers, d_model=args.d_model,
        n_heads=max(4, args.d_model // 64), n_kv_heads=max(2, args.d_model // 128),
        head_dim=64, d_ff=args.d_model * 4, vocab_size=2048,
        attn_q_block=64, attn_kv_block=64, dtype="float32",
    )
    from repro.models.transformer import count_params, init_params
    n = count_params(jax.eval_shape(
        lambda k: init_params(cfg, k), jax.random.PRNGKey(0)))
    print(f"model: {n/1e6:.1f}M params, mesh={len(jax.devices())} device(s)")
    shape = ShapeSpec("demo", args.seq_len, args.batch, "train")
    out = train(cfg, make_local_mesh(), shape, steps=args.steps,
                ckpt_dir=args.ckpt_dir, ckpt_every=25, lr=args.lr,
                log_every=5)
    h = out["history"]
    print(f"loss: {h[0]['loss']:.3f} -> {h[-1]['loss']:.3f} "
          f"over steps {h[0]['step']}..{h[-1]['step']}")


if __name__ == "__main__":
    main()
