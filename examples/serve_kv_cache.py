"""Serving example: batched requests through the continuous-batching
engine whose paged-KV directory is a HiStore index group.

    PYTHONPATH=src python examples/serve_kv_cache.py

Shows: continuous batching over decode_step, page registration (PUT),
SCAN-based page reclamation on sequence completion, and prefix-reuse GET
hits when prompts repeat.
"""
import jax

from repro.configs.tiny import tiny_config
from repro.models.transformer import init_params
from repro.serving.engine import ServingEngine


def main():
    cfg = tiny_config("mistral-nemo-12b", d_model=128, n_layers=4)
    params = init_params(cfg, jax.random.PRNGKey(0))
    eng = ServingEngine(cfg, params, batch_slots=4, max_len=96, page_size=8)

    wave1 = [[1, 2, 3, 4], [9, 8, 7], [5, 5, 5, 5, 5], [6, 7]]
    for p in wave1:
        eng.submit(p, max_new=12)
    steps = eng.run()
    # second wave repeats two prompts -> prefix-reuse hits in the hash index
    wave2 = [[1, 2, 3, 4], [9, 8, 7]]
    for p in wave2:
        eng.submit(p, max_new=12)
    steps += eng.run()
    prompts = wave1 + wave2
    s = eng.stats
    print(f"served {len(prompts)} requests in {steps} engine steps "
          f"({s['decode_steps']} decode steps)")
    print(f"page directory: {s['pages_registered']} pages registered via "
          f"PUT, {s['pages_freed']} reclaimed via SCAN "
          f"({s['index_scans']} range scans)")
    print(f"prefix reuse: {s['prefix_hits']} hash-index hits on repeated "
          f"prompts ({s['index_gets']} GETs total)")
    assert s["prefix_hits"] >= 2
    print("serving example OK")


if __name__ == "__main__":
    main()
