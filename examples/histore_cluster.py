import os
if "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
"""Distributed HiStore on an 8-device cluster (run by file path!).

    PYTHONPATH=src python examples/histore_cluster.py

Each device is the primary of one index group and backup for two
neighbours.  The same `HiStoreClient` front door as the single-node
quickstart, now over the shard_map backend: one-sided GETs (routed
all_to_all + owner-side gathers), two-sided PUTs with ppermute log
replication, distributed DELETE tombstones, SCAN fan-out, and a failover.
"""
import jax
import numpy as np

from repro.configs.histore import scaled
from repro.core import kvstore as kv
from repro.core.client import DistributedBackend, HiStoreClient


def main():
    cfg = scaled(log_capacity=512, async_apply_batch=128)
    n = len(jax.devices())
    mesh = jax.make_mesh((n,), (kv.AXIS,))
    print(f"cluster: {n} index servers (1 group each, 2 backups)")
    client = HiStoreClient(
        DistributedBackend(mesh, cfg, 4096, capacity_q=64, scan_limit=64),
        batch_quantum=64)

    keys = np.random.RandomState(1).choice(10 ** 6, 128, replace=False) + 1
    res = client.put(keys, np.arange(128))
    print(f"PUT 128: ok={res.all_ok} retries={res.retries}")

    g = client.get(keys[:16])
    print(f"GET 16: found={g.all_found} "
          f"max_accesses={int(np.asarray(g.accesses).max())} "
          f"values_ok={bool((np.asarray(g.values)[:, 0] == np.arange(16)).all())}")

    s = client.scan(0, 10 ** 7)
    print(f"SCAN: first={int(np.asarray(s.keys)[0])} "
          f"sorted={bool((np.diff(np.asarray(s.keys[:int(s.count)])) >= 0).all())}")

    d = client.delete(keys[:8])
    g2 = client.get(keys[:8])
    print(f"DELETE 8: found={bool(d.found.all())} -> GET misses="
          f"{not bool(g2.found.any())}")

    client.fail_server(3)          # index state wiped; data shard survives
    g3 = client.get(keys[8:])
    print(f"server 3 DOWN -> GET still found={g3.all_found}")
    w = client.put(keys + 10 ** 7, np.arange(128))
    rep = np.asarray(w.replicas)
    print(f"PUT under failure: ok={w.all_ok} "
          f"replicas min/max={int(rep.min())}/{int(rep.max())} "
          f"(reduced replication reported honestly)")
    client.recover_server(3)       # hash rebuilt from replica, clones resync
    g4 = client.get(keys[8:])
    print(f"server 3 RECOVERED -> GET found={g4.all_found} "
          f"parity={all(p['agree'] for p in kv.parity_report(client.backend.store, cfg))}")
    print("cluster example OK")


if __name__ == "__main__":
    main()
