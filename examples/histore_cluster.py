import os
if "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
"""Distributed HiStore on an 8-device cluster (run by file path!).

    PYTHONPATH=src python examples/histore_cluster.py

Each device is the primary of one index group and backup for two
neighbours.  Shows the one-sided GET (routed all_to_all + owner-side
gathers), the two-sided PUT with ppermute log replication, SCAN fan-out,
and a failover.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.histore import scaled
from repro.core import kvstore as kv
from repro.core.hashing import key_dtype


def main():
    cfg = scaled(log_capacity=512, async_apply_batch=128)
    n = len(jax.devices())
    mesh = jax.make_mesh((n,), (kv.AXIS,))
    print(f"cluster: {n} index servers (1 group each, 2 backups)")
    store = kv.create(mesh, 4096, cfg)
    ops = kv.make_ops(mesh, cfg, capacity_q=64, scan_limit=64)
    KD = key_dtype()

    keys = jnp.asarray(np.random.RandomState(1).choice(10 ** 6, 128,
                                                       replace=False) + 1, KD)
    vals = jnp.tile(jnp.arange(128, dtype=jnp.int32)[:, None], (1, 4))
    store, ok, addrs = ops["put"](store, keys, jnp.zeros(128, jnp.int32), vals)
    print(f"PUT 128: ok={bool(np.asarray(ok).all())}")

    addr, found, acc, val = ops["get"](store, keys[:16])
    print(f"GET 16: found={bool(np.asarray(found).all())} "
          f"max_accesses={int(np.asarray(acc).max())} "
          f"values_ok={bool((np.asarray(val)[:, 0] == np.arange(16)).all())}")

    lo = jnp.full((128,), 0, KD)
    hi = jnp.full((128,), 10 ** 7, KD)
    sk, sa, store = ops["scan"](store, lo, hi)
    print(f"SCAN: first={int(np.asarray(sk)[0])} "
          f"sorted={bool((np.diff(np.asarray(sk)) >= 0).all())}")

    store = kv.fail_server(store, 3)
    addr, found, acc, _ = ops["get"](store, keys)
    print(f"server 3 DOWN -> GET still found={bool(np.asarray(found).all())}")
    store = kv.recover_server(store, 3)
    print("cluster example OK")


if __name__ == "__main__":
    main()
