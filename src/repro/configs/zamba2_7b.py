"""zamba2-7b [hybrid]
81L d_model=3584 32H (GQA kv=32) d_ff=14336 vocab=32000, ssm_state=64
— Mamba2 + shared attn blocks [arXiv:2411.15242; unverified]

Backbone: 81 Mamba-2 layers.  A single *weight-tied* attention+MLP block
(32 MHA heads, d_ff=14336) is invoked after every 6th mamba layer
(Zamba2-style shared block; the per-invocation LoRA deltas of the release
are omitted — noted in DESIGN.md).  Mamba2: d_inner=2*d_model=7168,
head_dim=64 (112 SSD heads), state=64, groups=16 (16 to divide the 16-way model axis).
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="zamba2-7b",
    family="hybrid",
    n_layers=81,
    d_model=3584,
    n_heads=32,
    n_kv_heads=32,
    head_dim=112,
    d_ff=14336,
    vocab_size=32000,
    mamba_version=2,
    ssm_state=64,
    ssm_conv=4,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_groups=16,
    shared_attn_every=6,
))
