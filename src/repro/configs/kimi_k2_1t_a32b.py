"""kimi-k2-1t-a32b [moe]
61L d_model=7168 64H (GQA kv=8) d_ff=2048 vocab=163840, MoE 384e top-8
— Kimi K2, trillion-param MoE (paper-table) [arXiv:2501.kimi2; unverified]

We follow the assignment's structured spec verbatim: GQA (64H, kv=8),
384 routed experts with expert d_ff=2048, top-8 routing, 1 shared expert,
first layer dense (d_ff dense = 8*2048).  (The public K2 uses MLA; the
assignment pins GQA kv=8, which we honor — noted in DESIGN.md.)
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=16384,            # dense first layer: 8 * 2048
    vocab_size=163840,
    n_experts=384,
    n_shared_experts=1,
    top_k=8,
    moe_d_ff=2048,
    first_k_dense=1,
    rope_theta=5e4,
    fsdp=True,             # 1T params require param sharding over data axis
))
