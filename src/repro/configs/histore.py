"""HiStore (the paper's own system) deployment configuration.

These are the KV-store parameters used by the core library, the examples and
the paper-reproduction benchmarks.  Defaults mirror the paper's evaluation
setup scaled to this container: key 16 B (we use int64 keys + a 64-bit
signature pair — see DESIGN.md §Key codec), value 32 B, chained hash buckets
of 7+1 slots (64 B), skiplist → 128-fanout hierarchical sorted directory.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass


@dataclass(frozen=True)
class HiStoreConfig:
    # hash index ---------------------------------------------------------
    slots_per_bucket: int = 8      # paper: 7 slots + next ptr in a 64B bucket;
                                   # we pre-link chains so all 8 are key slots
    max_chain: int = 4             # pre-linked chain length (paper: dynamic)
    load_factor: float = 0.5       # buckets over-provisioned to avoid resizing
    # sorted index (skiplist → hierarchical directory) --------------------
    fanout: int = 128              # TPU lane width; one "express lane" hop
                                   # searches a 128-wide node branchlessly
    # index group ---------------------------------------------------------
    n_backups: int = 2             # replicas of the sorted index (paper §3.3)
    log_capacity: int = 1 << 16    # per-group append-only log entries
    # value store ----------------------------------------------------------
    value_words: int = 4           # 32 B values = 4 x int64 words
    n_value_replicas: int = 1      # mirror copies of each data shard; data
                                   # servers are their own failure domain
                                   # (paper §2), so value replication is
                                   # independent of n_backups
    # distribution ---------------------------------------------------------
    groups_per_device: int = 1
    # failure detection ----------------------------------------------------
    lease_misses: int = 3          # master switch: 0 disables detection
                                   # entirely (no heartbeat reads).  In
                                   # "rounds" mode it is also the bound:
                                   # observation rounds a server may miss
                                   # heartbeats before the client demotes
                                   # it to degraded routing
    lease_clock: str = "wall"      # "wall": leases age by elapsed
                                   # time.monotonic() — the paper §5
                                   # semantics; an idle client detects via
                                   # the background ticker.  "rounds": age
                                   # by observation rounds (deterministic
                                   # test mode — the exact lease_misses
                                   # detection bound)
    lease_timeout_s: float = 1.0   # wall mode: a heartbeat stalled this
                                   # long demotes the server
    lease_interval_s: float = 0.25  # wall mode: the client-side background
                                   # ticker issues a heartbeat-only tick
                                   # round whenever no foreground traffic
                                   # ran for this long
    # telemetry ------------------------------------------------------------
    telemetry: str = "counters"    # "off": record nothing (snapshots never
                                   # change); "counters": op counters +
                                   # log-bucketed latency histograms (the
                                   # default — no device syncs added);
                                   # "trace": counters + a bounded ring of
                                   # per-op spans for forensics
                                   # (core/telemetry.py)
    # batching -------------------------------------------------------------
    async_apply_batch: int = 4096  # log entries merged into the sorted index
                                   # per asynchronous apply
    # kernel dispatch -------------------------------------------------------
    use_kernels: str = "auto"      # "on": serve the index hot path (GET
                                   # probe, scan bounds, log->sorted merge)
                                   # through the Pallas kernels in
                                   # kernels/ops.py; "off": the pure-jnp
                                   # reference path; "auto" (default):
                                   # kernels on TPU, jnp elsewhere — the
                                   # HISTORE_USE_KERNELS env var ("on"/
                                   # "off") overrides auto, which is how
                                   # CI runs the interpret-mode kernel
                                   # leg without touching configs.  Both
                                   # paths are bit-exact by contract
                                   # (DESIGN.md §Kernelized index hot
                                   # path)

    def __post_init__(self):
        if self.use_kernels not in ("off", "on", "auto"):
            raise ValueError(
                f"use_kernels must be 'off', 'on' or 'auto', "
                f"got {self.use_kernels!r}")


DEFAULT = HiStoreConfig()


def scaled(**kw) -> HiStoreConfig:
    return dataclasses.replace(DEFAULT, **kw)
