"""HiStore (the paper's own system) deployment configuration.

These are the KV-store parameters used by the core library, the examples and
the paper-reproduction benchmarks.  Defaults mirror the paper's evaluation
setup scaled to this container: key 16 B (we use int64 keys + a 64-bit
signature pair — see DESIGN.md §Key codec), value 32 B, chained hash buckets
of 7+1 slots (64 B), skiplist → 128-fanout hierarchical sorted directory.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass


@dataclass(frozen=True)
class HiStoreConfig:
    # hash index ---------------------------------------------------------
    slots_per_bucket: int = 8      # paper: 7 slots + next ptr in a 64B bucket;
                                   # we pre-link chains so all 8 are key slots
    max_chain: int = 4             # pre-linked chain length (paper: dynamic)
    load_factor: float = 0.5       # buckets over-provisioned to avoid resizing
    # sorted index (skiplist → hierarchical directory) --------------------
    fanout: int = 128              # TPU lane width; one "express lane" hop
                                   # searches a 128-wide node branchlessly
    # index group ---------------------------------------------------------
    n_backups: int = 2             # replicas of the sorted index (paper §3.3)
    log_capacity: int = 1 << 16    # per-group append-only log entries
    # value store ----------------------------------------------------------
    value_words: int = 4           # 32 B values = 4 x int64 words
    n_value_replicas: int = 1      # mirror copies of each data shard; data
                                   # servers are their own failure domain
                                   # (paper §2), so value replication is
                                   # independent of n_backups
    # distribution ---------------------------------------------------------
    groups_per_device: int = 1
    # failure detection ----------------------------------------------------
    lease_misses: int = 3          # op rounds a server may miss heartbeats
                                   # before the client demotes it to degraded
                                   # routing (paper §5's lease timeout,
                                   # measured in observation rounds rather
                                   # than wall time; 0 disables detection)
    # batching -------------------------------------------------------------
    async_apply_batch: int = 4096  # log entries merged into the sorted index
                                   # per asynchronous apply


DEFAULT = HiStoreConfig()


def scaled(**kw) -> HiStoreConfig:
    return dataclasses.replace(DEFAULT, **kw)
