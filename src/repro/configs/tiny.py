"""Reduced same-family configs for smoke tests, examples and CI.

Each assigned architecture gets a scaled-down twin: same layer pattern /
mixer kinds / routing structure, small widths.  Used by
tests/test_models_smoke.py and examples/.
"""
from __future__ import annotations

from repro.configs.base import ModelConfig
from repro.configs import get_config


def tiny_config(arch: str, **extra) -> ModelConfig:
    cfg = get_config(arch)
    kw: dict = dict(
        d_model=64, n_heads=4, n_kv_heads=4 if cfg.n_kv_heads == cfg.n_heads else 2,
        head_dim=16, d_ff=128 if cfg.d_ff else 0, vocab_size=256,
        attn_q_block=8, attn_kv_block=8, ssm_chunk=8,
        dtype="float32",
    )
    # layer counts small but pattern-preserving
    if cfg.local_global_pattern:
        kw.update(n_layers=8, local_global_pattern=3, sliding_window=8)
    elif cfg.shared_attn_every:
        kw.update(n_layers=7, shared_attn_every=3)
    elif cfg.first_k_dense:
        kw.update(n_layers=3)
    else:
        kw.update(n_layers=2)
    if cfg.n_experts:
        kw.update(n_experts=8, top_k=2, moe_d_ff=32,
                  n_shared_experts=min(cfg.n_shared_experts, 1) or 0)
    if cfg.attn_kind == "mla":
        kw.update(kv_lora_rank=16, qk_rope_dim=8, qk_nope_dim=16,
                  v_head_dim=16, head_dim=24)
    if cfg.mamba_version:
        kw.update(ssm_state=8, ssm_expand=2, ssm_head_dim=8, ssm_groups=2)
    kw.update(extra)
    return cfg.scaled(**kw)
