"""internvl2-76b [vlm]
80L d_model=8192 64H (GQA kv=8) d_ff=28672 vocab=128256 — InternViT + InternLM2
[arXiv:2404.16821; unverified]

Per the assignment, the entry specifies the transformer BACKBONE only; the
InternViT modality frontend is a STUB: ``input_specs()`` provides precomputed
patch embeddings of shape (B, S, d_model).
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="internvl2-76b",
    family="vlm",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=28672,
    vocab_size=128256,
    frontend="embed",
    rope_theta=1e6,
))
