"""Architecture registry: importing this package registers every assigned
architecture (plus the paper's own HiStore configuration)."""
from repro.configs.base import (  # noqa: F401
    ModelConfig, ShapeSpec, SHAPES, Stage, layer_plan, input_specs,
    shape_applicable, get_config, all_archs, register,
)

# Assigned architectures (one module per arch id).
from repro.configs import zamba2_7b            # noqa: F401
from repro.configs import internvl2_76b        # noqa: F401
from repro.configs import mistral_large_123b   # noqa: F401
from repro.configs import command_r_35b        # noqa: F401
from repro.configs import gemma3_27b           # noqa: F401
from repro.configs import mistral_nemo_12b     # noqa: F401
from repro.configs import deepseek_v2_lite_16b # noqa: F401
from repro.configs import kimi_k2_1t_a32b      # noqa: F401
from repro.configs import musicgen_large       # noqa: F401
from repro.configs import falcon_mamba_7b      # noqa: F401

# Paper config (HiStore KV-store deployment parameters).
from repro.configs import histore              # noqa: F401

ARCH_IDS = [
    "zamba2-7b", "internvl2-76b", "mistral-large-123b", "command-r-35b",
    "gemma3-27b", "mistral-nemo-12b", "deepseek-v2-lite-16b",
    "kimi-k2-1t-a32b", "musicgen-large", "falcon-mamba-7b",
]
