"""falcon-mamba-7b [ssm]
64L d_model=4096 (attn-free) d_ff=0 vocab=65024, ssm_state=16 — mamba1 arch
[arXiv:2410.05355; unverified]

Pure Mamba-1: each layer is a single Mamba block (no attention, no separate
FFN — d_ff=0).  d_inner = 2*d_model = 8192, dt_rank = d_model/16 = 256,
conv kernel 4.  Constant-size recurrent state makes long_500k decode
in-scope (the flagship long-context arch for this assignment).
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="falcon-mamba-7b",
    family="ssm",
    n_layers=64,
    d_model=4096,
    n_heads=1,            # unused (attn-free)
    n_kv_heads=1,
    head_dim=64,
    d_ff=0,
    vocab_size=65024,
    mamba_version=1,
    ssm_state=16,
    ssm_conv=4,
    ssm_expand=2,
    tie_embeddings=True,
))
