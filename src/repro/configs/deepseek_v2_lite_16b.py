"""deepseek-v2-lite-16b [moe]
27L d_model=2048 16H (GQA kv=16) d_ff=1408 vocab=102400, MoE 64e top-6
— MLA kv_lora=512, 2 shared + routed top-6 [arXiv:2405.04434; hf]

Notes vs the assignment line: the line says "2 shared+160 routed top-6" in
the free-text but "MoE 64e top-6" in the structured spec; the published
V2-Lite config is 64 routed experts (160 is the full V2).  We follow the
structured spec: 64 routed, top-6, 2 shared, expert d_ff=1408.
MLA: kv_lora_rank=512, qk_nope=128, qk_rope=64, v_head=128, no q-lora.
First layer uses a dense MLP (d_ff = 10944 in the release; we use the
assignment's structured d_ff for experts and 8*1408 for the dense layer).
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="deepseek-v2-lite-16b",
    family="moe",
    n_layers=27,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=11264,            # dense first layer: 8 * 1408
    vocab_size=102400,
    attn_kind="mla",
    kv_lora_rank=512,
    qk_rope_dim=64,
    qk_nope_dim=128,
    v_head_dim=128,
    head_dim=192,          # qk_nope + qk_rope
    n_experts=64,
    n_shared_experts=2,
    top_k=6,
    moe_d_ff=1408,
    first_k_dense=1,
    rope_theta=1e4,
))
