"""Model/run configuration system.

Every assigned architecture is expressed as a frozen ``ModelConfig``.  The
transformer stack is driven entirely by the config: per-layer *layer specs*
(mixer kind, ffn kind) are derived from the config fields, and the model
builder groups repeated specs into scanned "pattern units" so that the HLO
stays small (one body per unique pattern position) while the dry-run can
optionally unroll everything for exact cost analysis.

Input shapes are the four assigned shape points (train_4k / prefill_32k /
decode_32k / long_500k); ``input_specs`` produces ShapeDtypeStruct stand-ins
(never allocating) for each (config, shape) cell.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# Layer specs
# ---------------------------------------------------------------------------
# A LayerSpec is (mixer, ffn):
#   mixer ∈ {"attn", "local", "mla", "mamba1", "mamba2", "mamba2+shared"}
#   ffn   ∈ {"mlp", "moe", None}
# "mamba2+shared" marks a mamba2 layer after which the *tied* shared
# attention+MLP block (Zamba2-style) is invoked.
LayerSpec = tuple[str, Optional[str]]


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                     # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0               # 0 -> d_model // n_heads

    # layer pattern ------------------------------------------------------
    local_global_pattern: int = 0   # gemma3: N local layers per 1 global
    sliding_window: int = 0
    attn_kind: str = "attn"         # attn | mla   (mixer for attention layers)

    # MoE -----------------------------------------------------------------
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    first_k_dense: int = 0          # leading dense-MLP layers (DeepSeek style)
    capacity_factor: float = 1.25
    moe_impl: str = "sort"          # sort | dense  (dispatch implementation)

    # MLA -----------------------------------------------------------------
    kv_lora_rank: int = 0
    qk_rope_dim: int = 64
    qk_nope_dim: int = 128
    v_head_dim: int = 128
    mla_absorb: bool = False        # decode-time absorbed projections (opt.)

    # SSM -----------------------------------------------------------------
    mamba_version: int = 0          # 0 = no ssm, 1 = mamba1, 2 = mamba2
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_head_dim: int = 64          # mamba2
    ssm_groups: int = 8             # mamba2 B/C groups
    ssm_chunk: int = 128            # chunked-scan length

    # Zamba2-style shared attention block ---------------------------------
    shared_attn_every: int = 0

    # IO -------------------------------------------------------------------
    frontend: str = "token"         # token | embed (VLM/audio stubs)
    tie_embeddings: bool = False

    # misc -----------------------------------------------------------------
    rope_theta: float = 1e4
    norm_eps: float = 1e-5
    dtype: str = "bfloat16"
    # attention implementation: "flash" (blockwise online-softmax scan) or
    # "naive" (materialised scores; only for tiny smoke configs)
    attn_impl: str = "flash"
    attn_q_block: int = 512
    attn_kv_block: int = 512
    # hillclimb levers (all default to the baseline path; see EXPERIMENTS.md
    # §Perf for the measured effect of each)
    attn_block_skip: bool = False   # skip fully-masked causal kv blocks
    remat: str = "unit"             # none | unit  (checkpoint each pattern unit)
    zero1: bool = True              # shard optimizer state over data axis
    fsdp: bool = False              # additionally shard params over data axis
    decode_cache_hint: bool = False  # constrain KV cache sharding post-update
    ssm_scan_dtype: str = "float32"  # bfloat16 -> halve scan-intermediate bytes
    ssm_impl: str = "jnp"            # jnp | pallas (fused VMEM-resident scan)

    def with_opts(self, opts: str) -> "ModelConfig":
        """Apply 'k=v,k=v' overrides (dryrun --set); ints/floats/bools
        parsed, strings passed through."""
        if not opts:
            return self
        kw = {}
        for item in opts.split(","):
            k, v = item.split("=")
            cur = getattr(self, k)
            if isinstance(cur, bool):
                kw[k] = v.lower() in ("1", "true", "yes")
            elif isinstance(cur, int):
                kw[k] = int(v)
            elif isinstance(cur, float):
                kw[k] = float(v)
            else:
                kw[k] = v
        return self.scaled(**kw)

    # ------------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def subquadratic(self) -> bool:
        """True if long-context (500k) decode is within scope: SSM/hybrid or
        mostly-local attention archs."""
        return self.mamba_version > 0 or self.local_global_pattern > 0

    @property
    def param_dtype(self):
        return jnp.dtype(self.dtype)

    def layer_specs(self) -> list[LayerSpec]:
        specs: list[LayerSpec] = []
        for i in range(self.n_layers):
            # mixer
            if self.mamba_version == 1:
                mixer = "mamba1"
            elif self.mamba_version == 2:
                mixer = "mamba2"
                if self.shared_attn_every and (i + 1) % self.shared_attn_every == 0:
                    mixer = "mamba2+shared"
            elif self.local_global_pattern:
                p = self.local_global_pattern
                mixer = "attn" if (i % (p + 1)) == p else "local"
            else:
                mixer = self.attn_kind
            # ffn
            if self.mamba_version:  # mamba blocks are the whole layer
                ffn = None
            elif self.n_experts and i >= self.first_k_dense:
                ffn = "moe"
            else:
                ffn = "mlp"
            specs.append((mixer, ffn))
        return specs

    def scaled(self, **kw) -> "ModelConfig":
        """Reduced config of the same family (for smoke tests)."""
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# Layer plan: group the spec list into scannable stages
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class Stage:
    kind: str                 # "single" | "scan"
    pattern: tuple[LayerSpec, ...]
    n_rep: int                # repeats (1 for single)


def layer_plan(cfg: ModelConfig) -> list[Stage]:
    """Decompose the layer-spec list into [leading singles] + [scanned
    pattern repeats] + [trailing singles].  Keeps HLO small for compile."""
    specs = cfg.layer_specs()
    stages: list[Stage] = []
    i = 0
    # leading singles (e.g. first_k_dense)
    while i < len(specs) and cfg.first_k_dense and i < cfg.first_k_dense:
        stages.append(Stage("single", (specs[i],), 1))
        i += 1
    rest = specs[i:]
    if not rest:
        return stages
    # find smallest repeating pattern length
    best = None
    for plen in range(1, min(9, len(rest) + 1)):
        pat = tuple(rest[:plen])
        reps = 1
        while (reps + 1) * plen <= len(rest) and tuple(
            rest[reps * plen:(reps + 1) * plen]) == pat:
            reps += 1
        rem = len(rest) - reps * plen
        score = rem + plen  # prefer small remainder then small pattern
        if best is None or score < best[0]:
            best = (score, pat, reps, rem)
    _, pat, reps, rem = best
    if reps > 1:
        stages.append(Stage("scan", pat, reps))
    else:
        for s in pat:
            stages.append(Stage("single", (s,), 1))
    for s in rest[reps * len(pat):]:
        stages.append(Stage("single", (s,), 1))
    return stages


# ---------------------------------------------------------------------------
# Shapes
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str                 # "train" | "prefill" | "decode"


SHAPES: dict[str, ShapeSpec] = {
    "train_4k":    ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k":  ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k":   ShapeSpec("long_500k", 524_288, 1, "decode"),
}


def shape_applicable(cfg: ModelConfig, shape: ShapeSpec) -> tuple[bool, str]:
    if shape.name == "long_500k" and not cfg.subquadratic:
        return False, ("skip: pure full-attention arch; long_500k requires "
                       "sub-quadratic attention (see DESIGN.md)")
    return True, ""


def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> dict:
    """ShapeDtypeStruct stand-ins for every model input (no allocation).

    train/prefill: token ids (or precomputed frontend embeddings for
    vlm/audio stubs) + labels.  decode: one new token per sequence + per-seq
    position, with the KV cache handled separately (see serving.kvcache).
    """
    B, S = shape.global_batch, shape.seq_len
    dt = cfg.param_dtype
    if shape.kind in ("train", "prefill"):
        if cfg.frontend == "embed":
            d = {"embeds": jax.ShapeDtypeStruct((B, S, cfg.d_model), dt)}
        else:
            d = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32)}
        d["targets"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
        return d
    else:  # decode: one new token, KV cache of length S
        if cfg.frontend == "embed":
            d = {"embeds": jax.ShapeDtypeStruct((B, 1, cfg.d_model), dt)}
        else:
            d = {"tokens": jax.ShapeDtypeStruct((B, 1), jnp.int32)}
        d["pos"] = jax.ShapeDtypeStruct((B,), jnp.int32)
        return d


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------
_REGISTRY: dict[str, ModelConfig] = {}


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    # populate the registry lazily
    if not _REGISTRY:
        from repro import configs  # noqa: F401  (imports all arch modules)
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def all_archs() -> list[str]:
    if not _REGISTRY:
        from repro import configs  # noqa: F401
    return sorted(_REGISTRY)
