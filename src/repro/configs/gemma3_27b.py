"""gemma3-27b [dense]
62L d_model=5376 32H (GQA kv=16) d_ff=21504 vocab=262144 — 5:1 local:global, 128k
[hf:google/gemma-3-1b-pt; unverified]

5 sliding-window (1024) layers per 1 global layer.  Mostly-local attention
makes the arch sub-quadratic for long-context decode: local layers keep a
window-sized cache; only every 6th layer keeps the full-length cache.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="gemma3-27b",
    family="dense",
    n_layers=62,
    d_model=5376,
    n_heads=32,
    n_kv_heads=16,
    head_dim=128,
    d_ff=21504,
    vocab_size=262144,
    local_global_pattern=5,
    sliding_window=1024,
    rope_theta=1e6,
    tie_embeddings=True,
))
