"""musicgen-large [audio]
48L d_model=2048 32H (GQA kv=32) d_ff=8192 vocab=2048 — decoder-only over
EnCodec tokens [arXiv:2306.05284; hf]

The backbone is a plain decoder-only transformer over EnCodec codebook
tokens (vocab 2048).  The EnCodec encoder/decoder and the 4-codebook delay
pattern are modality-frontend concerns and are STUBBED at the data layer:
inputs are already flattened token ids.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="musicgen-large",
    family="audio",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,   # MHA (kv=32)
    head_dim=64,
    d_ff=8192,
    vocab_size=2048,
    rope_theta=1e4,
))
