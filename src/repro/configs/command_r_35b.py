"""command-r-35b [dense]
40L d_model=8192 64H (GQA kv=8) d_ff=22528 vocab=256000 — GQA, no-bias
[hf:CohereForAI/c4ai-command-r-v01; unverified]

Note: vocab 256000 is not divisible by the 16-way model axis; we round up to
256016? No — we keep the published 256000 and shard the vocab over the model
axis only when divisible; 256000 = 16 * 16000, so it divides cleanly.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="command-r-35b",
    family="dense",
    n_layers=40,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=22528,
    vocab_size=256000,
    rope_theta=1e4,
    tie_embeddings=True,   # Command-R ties input/output embeddings
))
