"""HiStore core: hybrid index (hash table + sorted index) in JAX.

Modules:
  hashing       — 32-bit key mixing (shared with the Pallas kernels)
  hash_index    — chained bucket hash table (primary index)
  sorted_index  — hierarchical-directory sorted array (TPU skiplist)
  log           — append-only update log with applied-prefix marks
  index_group   — 1 hash + N sorted replicas + logs; consistency; recovery
  kvstore       — distributed store over index groups (see also verbs.py)
  client        — HiStoreClient: the one typed front door (use this)
  results       — PutResult/GetResult/DeleteResult/ScanResult
"""
from repro.core import hash_index, hashing, index_group, log, sorted_index  # noqa: F401
from repro.core.backend import Backend  # noqa: F401
from repro.core.client import (DistributedBackend, HiStoreClient,  # noqa: F401
                               LocalBackend)
from repro.core.results import (DeleteResult, GetResult, PutResult,  # noqa: F401
                                ScanResult)
