"""Chained bucket hash table as dense JAX arrays (the paper's primary
index, adapted to TPU).

Paper structure: 64 B buckets of 7 slots + a next-pointer, chains grown on
demand.  TPU adaptation (DESIGN.md §Hash index): chains are PRE-LINKED —
each logical bucket owns ``max_chain`` contiguous sub-buckets of
``slots_per_bucket`` slots; the paper itself over-provisions buckets to
avoid resizing, we over-provision the chain the same way.  A GET probes
sub-bucket after sub-bucket, exactly like following next-pointers: the
reported ``n_accesses`` equals the number of 64 B reads the RDMA client
would issue (Fig. 3a reproduction).

Batched inserts replace the paper's RDMA CAS with a sort-based
conflict-free schedule: sort new keys by bucket, rank within bucket, place
at fill+rank — one scatter, no retries (the TPU-native analogue of CAS
contention resolution).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.hashing import bucket_of, next_pow2, sig_fp_of

I32 = jnp.int32
I64 = jnp.int64
TOMBSTONE = -1
BIG = jnp.int32(2 ** 30)


class HashIndex(NamedTuple):
    sig: jnp.ndarray    # int32 [nb, CS]   0=empty, -1=tombstone
    fp: jnp.ndarray     # int32 [nb, CS]
    addr: jnp.ndarray   # int32 [nb, CS]
    fill: jnp.ndarray   # int32 [nb]  (appended slots incl. tombstones)

    @property
    def n_buckets(self) -> int:
        return self.sig.shape[0]

    @property
    def chain_slots(self) -> int:
        return self.sig.shape[1]


def create(capacity: int, cfg) -> HashIndex:
    """Size the table so expected occupancy is cfg.load_factor."""
    cs = cfg.slots_per_bucket * cfg.max_chain
    nb = next_pow2(max(8, int(capacity / (cs * cfg.load_factor) + 1)))
    return HashIndex(
        sig=jnp.zeros((nb, cs), I32),
        fp=jnp.zeros((nb, cs), I32),
        addr=jnp.full((nb, cs), -1, I32),
        fill=jnp.zeros((nb,), I32),
    )


def descriptors(idx: HashIndex, keys):
    """Kernel-ready probe descriptors (bucket, signature, fingerprint) —
    int32 whatever the key dtype, shared by the jnp probe below and the
    Pallas dispatch layer (kernels/ops.py)."""
    b = bucket_of(keys, idx.sig.shape[0])
    sig, fp = sig_fp_of(keys)
    return b, sig, fp


def _locate(idx: HashIndex, keys):
    """Vectorized probe.  Returns (found, slot_flat, addr, n_accesses)."""
    nb, cs = idx.sig.shape
    b, sig, fp = descriptors(idx, keys)
    rows_sig = idx.sig[b]                       # [Q, CS]
    rows_fp = idx.fp[b]
    match = (rows_sig == sig[:, None]) & (rows_fp == fp[:, None])
    found = match.any(axis=1)
    off = jnp.argmax(match, axis=1)             # first match
    slot_flat = b * cs + off
    addr = jnp.where(found, idx.addr[b, off], -1)
    return found, slot_flat, addr, b, off


def lookup(idx: HashIndex, keys, cfg):
    """GET probe.  Returns (addr [Q] int32, found [Q] bool, n_accesses [Q]).

    n_accesses counts 64 B sub-bucket reads: hit -> sub-bucket containing
    the slot; miss -> all occupied sub-buckets (>=1), exactly the one-sided
    RDMA READ count of the paper's client."""
    S = cfg.slots_per_bucket
    found, _, addr, b, off = _locate(idx, keys)
    occupied = jnp.maximum(idx.fill[b], 1)
    acc_hit = off // S + 1
    acc_miss = (occupied + S - 1) // S
    n_acc = jnp.where(found, acc_hit, acc_miss)
    return addr, found, n_acc


def dedupe_last(keys):
    """Mask of entries that are the LAST occurrence of their key
    (shared last-writer-wins dedupe: hash inserts, value-slot allocation)."""
    Q = keys.shape[0]
    pos = jnp.arange(Q)
    order = jnp.lexsort((pos, keys))
    k_s = keys[order]
    is_last_sorted = jnp.concatenate(
        [k_s[1:] != k_s[:-1], jnp.ones((1,), bool)])
    live = jnp.zeros((Q,), bool).at[order].set(is_last_sorted)
    return live


def dedupe_last_valid(keys, valid):
    """dedupe_last over the valid lanes of a padded batch.  Invalid lanes
    must not shadow a valid lane holding the same key in last-wins
    dedupe: they get unique placeholder keys (< -1, outside the
    application key space) before ranking."""
    Q = keys.shape[0]
    ph = -(jnp.arange(Q, dtype=keys.dtype) + 2)
    return dedupe_last(jnp.where(valid, keys, ph)) & valid


def insert(idx: HashIndex, keys, addrs, cfg, valid=None):
    """Batched PUT/UPDATE.  Last-wins within the batch; updates in place
    if the key exists, else places at the bucket's first free slot —
    tombstoned slots are REUSED before the virgin tail (the hash-side
    slot GC: without it, delete + re-insert churn clogs the pre-linked
    chains with tombstones long before the table is actually full).
    Returns (idx, ok [Q]) where ok=False means the chain overflowed
    (caller surfaces the error, mirroring the paper's add-bucket RPC).
    ``valid=False`` lanes are ignored entirely (padding lanes of a
    fixed-shape batch) and report ok=True."""
    nb, cs = idx.sig.shape
    Q = keys.shape[0]
    if valid is None:
        live = dedupe_last(keys)
    else:
        live = dedupe_last_valid(keys, valid)
    sig, fp = sig_fp_of(keys)
    found, slot_flat, _, b, _ = _locate(idx, keys)

    addr_flat = idx.addr.reshape(-1)
    # in-place update of existing keys
    upd = found & live
    addr_flat = addr_flat.at[jnp.where(upd, slot_flat, BIG)].set(
        addrs, mode="drop")

    # free-slot map per bucket: tombstones (low offsets, reused first) and
    # the virgin tail beyond fill
    virgin = jnp.arange(cs)[None, :] >= idx.fill[:, None]        # [nb, cs]
    freeslot = (idx.sig == TOMBSTONE) | virgin
    free_order = jnp.argsort(~freeslot, axis=1, stable=True)
    nfree = freeslot.sum(axis=1).astype(I32)

    # place new keys: rank within bucket among accepted new entries, the
    # rank-th entry takes the bucket's rank-th free slot (sort-based
    # conflict-free schedule, as before)
    new = (~found) & live
    pos = jnp.arange(Q)
    b_for_sort = jnp.where(new, b, nb)          # push non-new to the end
    order = jnp.lexsort((pos, b_for_sort))
    b_s = b_for_sort[order]
    start = jnp.searchsorted(b_s, b_s)          # first idx of each bucket run
    rank = jnp.arange(Q) - start
    b_c = jnp.clip(b_s, 0, nb - 1)
    off = free_order[b_c, jnp.clip(rank, 0, cs - 1)]
    ok_s = (b_s < nb) & (rank < nfree[b_c])
    slot_s = jnp.where(ok_s, b_c * cs + off, BIG)
    sig_flat = idx.sig.reshape(-1)
    fp_flat = idx.fp.reshape(-1)
    sig_flat = sig_flat.at[slot_s].set(sig[order], mode="drop")
    fp_flat = fp_flat.at[slot_s].set(fp[order], mode="drop")
    addr_flat = addr_flat.at[slot_s].set(addrs[order], mode="drop")
    # fill still counts the appended prefix (incl. tombstones): reused
    # slots sit below it, virgin placements extend it
    fill = idx.fill.at[jnp.where(ok_s, b_s, nb)].max(
        (off + 1).astype(I32), mode="drop")

    ok = jnp.zeros((Q,), bool).at[order].set(ok_s)
    ok = ok | upd | ~live                        # dup-superseded entries: ok
    new_idx = HashIndex(sig_flat.reshape(nb, cs), fp_flat.reshape(nb, cs),
                        addr_flat.reshape(nb, cs), fill)
    return new_idx, ok


def delete(idx: HashIndex, keys, cfg, valid=None):
    """Batched DELETE: tombstone the slot (reclaimed on rebuild).
    ``valid=False`` lanes (padding) touch nothing and report found=False."""
    nb, cs = idx.sig.shape
    found, slot_flat, _, _, _ = _locate(idx, keys)
    if valid is not None:
        found = found & valid
    tgt = jnp.where(found, slot_flat, BIG)
    sig_flat = idx.sig.reshape(-1).at[tgt].set(TOMBSTONE, mode="drop")
    fp_flat = idx.fp.reshape(-1).at[tgt].set(0, mode="drop")
    addr_flat = idx.addr.reshape(-1).at[tgt].set(-1, mode="drop")
    return HashIndex(sig_flat.reshape(nb, cs), fp_flat.reshape(nb, cs),
                     addr_flat.reshape(nb, cs), idx.fill), found


def replay_pending(idx: HashIndex, log, cfg) -> HashIndex:
    """Online-recovery helper: apply a log's PENDING window to a
    snapshot-built hash table (net effect, last-writer-wins per key).
    The hash is synchronous with the log by contract, so a hash rebuilt
    from an UNDRAINED sorted snapshot must replay the pending delta even
    though the sorted replica itself catches up later through the
    ordinary incremental applies.  Host-side, eager; batches are padded
    to powers of two so repeated recoveries reuse compiled inserts."""
    import numpy as np

    from repro.core import log as lg
    from repro.core import sorted_index as six
    from repro.core.hashing import pad_pow2 as padded

    k, a, o = lg.pending_entries_np(log)
    if len(k) == 0:
        return idx
    net: dict = {}
    for kk, aa, oo in zip(k.tolist(), a.tolist(), o.tolist()):
        if oo:
            net[kk] = (int(oo), int(aa))
    dels = np.asarray([kk for kk, (oo, _) in net.items()
                       if oo == int(six.OP_DEL)], k.dtype)
    puts = [(kk, aa) for kk, (oo, aa) in net.items()
            if oo == int(six.OP_PUT)]
    if len(dels):
        kp, vm = padded(dels, 0)
        idx, _ = delete(idx, kp, cfg, vm)
    if puts:
        pk = np.asarray([p[0] for p in puts], k.dtype)
        pa = np.asarray([p[1] for p in puts], np.int32)
        kp, vm = padded(pk, 0)
        ap, _ = padded(pa, -1)
        idx, _ = insert(idx, kp, ap, cfg, vm)
    return idx


def valid_mask(idx: HashIndex):
    return (idx.sig != 0) & (idx.sig != TOMBSTONE)


def n_items(idx: HashIndex):
    return valid_mask(idx).sum()
