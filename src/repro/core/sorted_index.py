"""Sorted index: the paper's skiplist, re-thought for TPU.

A skiplist is pointer-chased express lanes — hostile to vector units.  The
TPU-native equivalent (DESIGN.md §Sorted index) is an *implicit hierarchical
directory over a packed sorted array*: level l is the stride-fanout^l view
of the keys array; one "hop" loads a fanout-wide node (fanout=128 = the TPU
lane width) and counts keys <= q branchlessly — exactly a skiplist level
descent, one vector op per level.  n_accesses = number of levels touched,
the analogue of the paper's per-lookup memory accesses.

Updates are batched merges (the asynchronous log apply of §3.2.2): the
incoming batch is sorted and merged with the packed array, newest-wins per
key, DELETE entries compacted away — the skiplist "list split" cost becomes
one streaming merge, which is also what the Pallas bitonic/merge kernels
accelerate.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.hashing import key_dtype, key_inf

I32 = jnp.int32

OP_PUT = jnp.int8(1)
OP_DEL = jnp.int8(2)


class SortedIndex(NamedTuple):
    keys: jnp.ndarray    # int64 [cap], ascending, empty = KEY_INF
    addrs: jnp.ndarray   # int32 [cap]
    size: jnp.ndarray    # int32 scalar


def create(capacity: int, dtype=None) -> SortedIndex:
    dtype = dtype or key_dtype()
    return SortedIndex(
        keys=jnp.full((capacity,), key_inf(dtype), dtype),
        addrs=jnp.full((capacity,), -1, I32),
        size=jnp.zeros((), I32),
    )


def bulk_load(idx: SortedIndex, keys, addrs) -> SortedIndex:
    """Load (unsorted) pairs into an empty index."""
    cap = idx.keys.shape[0]
    order = jnp.argsort(keys)
    k = keys[order]
    a = addrs[order]
    n = keys.shape[0]
    new_keys = idx.keys.at[:n].set(k)
    new_addrs = idx.addrs.at[:n].set(a)
    return SortedIndex(new_keys, new_addrs, jnp.asarray(n, I32))


def merge(idx: SortedIndex, keys, addrs, ops) -> SortedIndex:
    """Apply a batch of log entries (PUT/DEL).  Newest-wins per key; DELETEs
    compact away.  Invalid entries are marked op=0 (ignored)."""
    cap = idx.keys.shape[0]
    m = keys.shape[0]
    INF = key_inf(idx.keys.dtype)
    # priority: existing entries 0; batch entries 1..m by arrival order
    all_keys = jnp.concatenate(
        [idx.keys, jnp.where(ops > 0, keys.astype(idx.keys.dtype), INF)])
    all_addrs = jnp.concatenate([idx.addrs, addrs])
    all_del = jnp.concatenate(
        [jnp.zeros((cap,), bool), ops == OP_DEL])
    prio = jnp.concatenate([jnp.zeros((cap,), I32), 1 + jnp.arange(m, dtype=I32)])
    order = jnp.lexsort((prio, all_keys))
    k = all_keys[order]
    a = all_addrs[order]
    d = all_del[order]
    # keep the last entry of each equal-key run; drop if it's a DELETE or INF
    is_last = jnp.concatenate([k[1:] != k[:-1], jnp.ones((1,), bool)])
    keep = is_last & (~d) & (k != INF)
    dest = jnp.cumsum(keep) - 1
    dest = jnp.where(keep, dest, cap + m)  # dropped -> out of range
    new_keys = jnp.full((cap,), INF, idx.keys.dtype).at[dest].set(
        k, mode="drop")
    new_addrs = jnp.full((cap,), -1, I32).at[dest].set(a, mode="drop")
    return SortedIndex(new_keys, new_addrs, keep.sum().astype(I32))


def directory_levels(cap: int, fanout: int) -> int:
    lv = 1
    span = fanout
    while span < cap:
        span *= fanout
        lv += 1
    return lv


def search(idx: SortedIndex, keys, fanout: int = 128):
    """Hierarchical lookup.  keys: [Q] -> (addr, found, n_accesses).

    Descends the implicit directory: at level l (stride fanout^l) it loads
    the fanout-wide node starting at the current position and counts
    entries <= key (branchless).  n_accesses = levels = ceil(log_f cap)."""
    cap = idx.keys.shape[0]
    levels = directory_levels(cap, fanout)
    Q = keys.shape[0]
    pos = jnp.zeros((Q,), I32)           # node start, in units of stride
    for l in range(levels - 1, -1, -1):
        stride = fanout ** l
        offs = jnp.arange(fanout, dtype=I32)
        gather_idx = pos[:, None] + offs[None, :] * stride   # [Q, fanout]
        node = idx.keys[jnp.clip(gather_idx, 0, cap - 1)]
        node = jnp.where(gather_idx < cap, node, key_inf(idx.keys.dtype))
        cnt = (node <= keys[:, None]).sum(axis=1).astype(I32)
        step = jnp.maximum(cnt - 1, 0)
        pos = pos + step * stride
    found = idx.keys[pos] == keys
    addr = jnp.where(found, idx.addrs[pos], -1)
    n_acc = jnp.full((Q,), levels, I32)
    return addr, found, n_acc


def range_from_start(idx: SortedIndex, start, hi, limit: int):
    """SCAN tail shared by the jnp and kernel paths: take ``limit``
    entries from position ``start`` (the lower bound — searchsorted
    here, the search kernel's descent position on the kernel path) and
    mask to keys <= hi.  Returns (keys [limit], addrs [limit], count)."""
    cap = idx.keys.shape[0]
    take = jnp.clip(start + jnp.arange(limit), 0, cap - 1)
    k = idx.keys[take]
    a = idx.addrs[take]
    INF = key_inf(idx.keys.dtype)
    valid = ((start + jnp.arange(limit)) < cap) & (k <= hi) & (k != INF)
    k = jnp.where(valid, k, INF)
    a = jnp.where(valid, a, -1)
    return k, a, valid.sum().astype(I32)


def range_query(idx: SortedIndex, lo, hi, limit: int):
    """SCAN [lo, hi]: up to ``limit`` ascending entries.
    lo, hi: scalars.  Returns (keys [limit], addrs [limit], count)."""
    return range_from_start(idx, jnp.searchsorted(idx.keys, lo), hi, limit)


def items(idx: SortedIndex):
    """(keys, addrs, valid) of live entries (for rebuilds)."""
    valid = idx.keys != key_inf(idx.keys.dtype)
    return idx.keys, idx.addrs, valid
