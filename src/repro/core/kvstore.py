"""HiStore: the distributed key-value store over index groups.

Topology (one group per device; cfg.groups_per_device generalises):
  device g is the PRIMARY server of group g (hash table + primary log + the
  group's data-server shard) and the BACKUP server for groups g-1 (replica
  0) and g-2 (replica 1): backup arrays use the SHIFTED layout — slice
  [r, p] stores replica r of group (p - r - 1) mod G, so placing slice p on
  device p puts every replica on a different failure domain, and log
  replication is a ppermute by r+1 hops.  The value plane (slot allocator,
  mirror replication, free queues) is the ``data`` field — see
  data_plane.py; data servers are a failure domain separate from the index
  servers (paper §2).

Ops (all shard_map'd over the 1-D "kv" mesh axis; see verbs.py for the
RDMA-verb mapping):
  put    — route to owner; owner allocates a free slot on its data shard
           (overwrites free the old slot first — the data-server GC),
           stores + mirrors the value, appends its log, pushes the entries
           to the LIVE backup logs (ppermute; dead holders are skipped),
           updates the hash table, acks with the replica count actually
           written.  A full shard rejects the lane (client retries after
           a GC round).
  put_degraded — as put, plus the replica probe that finds the old slot at
           a temporary primary, and one-hop value displacement when the
           owner's own data shard is masked dead.
  get    — one-sided: route, owner-side gather-only probe, value gather,
           reverse route.  Primary dead -> the query is routed to a backup
           holder, which consults its pending log + sorted replica; values
           stored on another shard are flagged for a second-hop fetch.
  fetch  — second-hop value read: route by address to the first LIVE data
           holder of the owning shard (primary copy, then its mirrors).
  delete — route to owner; owner appends a tombstone to its log, pushes it
           to the live backup logs (ppermute), tombstones the hash slot,
           frees the value slot (queued for the gc op when remote), acks
           (degraded found answered from the replica + pending log).
           The tombstone compacts out of the sorted replicas on apply.
  scan   — backup-side: every device fully drains and range-queries the
           replicas it holds, results are all_gathered and merged.
  apply_async — one batched log->sorted merge round on every backup.
  gc     — one routed flush round of the pending free queues (frees whose
           slot lives on another shard travel home and clear the bit).
  fail_server / recover_server / parity_report — host-side failure
           control plane: fail WIPES the device's index state, recover
           rebuilds the hash from a drained sorted replica and re-clones
           lost replicas from survivors (DESIGN.md §Fault tolerance).
  fail_data_server / recover_data_server / migrate_values — the value
           plane's control plane (data_plane.py): mirror-rebuild recovery
           and the background migration that moves degraded-write values
           home and patches index addresses (second-hop fetch elision).

All mutating ops take a ``valid`` lane mask so the client can pad request
batches to fixed shapes (DESIGN.md §Client); invalid lanes are routed
nowhere, consume no exchange capacity, and mutate nothing.  External
callers should not call these ops directly — go through
repro.core.client.HiStoreClient, which adds overflow retry, batch padding
and the async-apply policy.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import data_plane as dp
from repro.core import hash_index as hix
from repro.core import log as lg
from repro.core import sorted_index as six
from repro.core.hashing import fmix32, key_inf
from repro.core.verbs import (exchange, replicate_shift, route_build,
                              route_return)

I32 = jnp.int32
AXIS = "kv"


class KVStore(NamedTuple):
    hash: hix.HashIndex       # leaves [G, ...]
    plog: lg.UpdateLog        # leaves [G, ...]
    bsorted: six.SortedIndex  # leaves [R, G, ...] (shifted layout)
    blog: lg.UpdateLog        # leaves [R, G, ...]
    data: dp.DataPlane        # value plane (shard + allocator + mirrors)
    alive: jnp.ndarray        # [G] bool (index server up)


def create(mesh, capacity_per_group: int, cfg, key_dt=None) -> KVStore:
    G = mesh.devices.size
    R = cfg.n_backups
    rep = lambda t, n: jax.tree.map(
        lambda a: jnp.broadcast_to(a[None], (n,) + a.shape).copy(), t)
    one_hash = hix.create(capacity_per_group, cfg)
    one_plog = lg.create(cfg.log_capacity, key_dt)
    one_sorted = six.create(capacity_per_group, key_dt)
    one_blog = lg.create(cfg.log_capacity, key_dt)
    store = KVStore(
        hash=rep(one_hash, G),
        plog=rep(one_plog, G),
        bsorted=rep(rep(one_sorted, G), R),
        blog=rep(rep(one_blog, G), R),
        data=dp.create(G, capacity_per_group, cfg, key_dt),
        alive=jnp.ones((G,), bool),
    )
    return jax.device_put(store, store_sharding(mesh))


def store_sharding(mesh):
    from jax.sharding import NamedSharding

    # group axis position differs: hash/plog/data shard dim0; bsorted/blog
    # shard dim1; alive replicated.
    return KVStore(
        hash=hix.HashIndex(*[NamedSharding(mesh, P(AXIS))] * 4),
        plog=lg.UpdateLog(*[NamedSharding(mesh, P(AXIS))] * 5),
        bsorted=six.SortedIndex(*[NamedSharding(mesh, P(None, AXIS))] * 3),
        blog=lg.UpdateLog(*[NamedSharding(mesh, P(None, AXIS))] * 5),
        data=dp.sharding(mesh, AXIS),
        alive=NamedSharding(mesh, P()),
    )


def _specs():
    return KVStore(
        hash=hix.HashIndex(*[P(AXIS)] * 4),
        plog=lg.UpdateLog(*[P(AXIS)] * 5),
        bsorted=six.SortedIndex(*[P(None, AXIS)] * 3),
        blog=lg.UpdateLog(*[P(None, AXIS)] * 5),
        data=dp.specs(AXIS),
        alive=P(),
    )


def owner_group(keys, G: int):
    """Group routing hash — decorrelated from the bucket hash."""
    from repro.core.hashing import key_mix
    h1, h2 = key_mix(keys)
    return (fmix32(h2 ^ jnp.uint32(0xA5A5A5A5)) % jnp.uint32(G)).astype(I32)


def _first_alive_holder(g, alive):
    """Device to contact for group g: primary g, else backup holders."""
    G = alive.shape[0]
    cand = jnp.stack([g % G, (g + 1) % G, (g + 2) % G])
    ok = alive[cand]
    pick = jnp.argmax(ok)          # first alive in priority order
    return cand[pick]


def _first_alive_data_holder(s, dalive, Rv: int):
    """Data server to contact for shard s: the shard itself, else the
    devices hosting its mirror copies (priority order).  Returns
    (holder, any_alive): when every holder is dead (loss beyond the
    configured value replication) the caller must leave the lane
    un-routed — a push-back, never a fabricated value."""
    G = dalive.shape[0]
    cand = jnp.stack([s % G] + [(s + r + 1) % G for r in range(Rv)])
    ok = dalive[cand]
    return cand[jnp.argmax(ok)], ok.any()


# ---------------------------------------------------------------------------
# shard_map bodies (one device's view; leading group axis is local size 1)
# ---------------------------------------------------------------------------
def _sq(tree):
    return jax.tree.map(lambda a: a[0], tree)


def _ex(tree, val):
    return jax.tree.map(lambda a, v: a.at[0].set(v), tree, val)


def _route_to_owner(store, keys, valid, G, capacity, extra=None):
    """Shared routing prologue of the mutating ops: invalid (padding) lanes
    get an out-of-range destination, so they occupy no exchange capacity
    and arrive nowhere."""
    dest_g = owner_group(keys, G)
    dest = jax.vmap(lambda g: _first_alive_holder(g, store.alive))(dest_g)
    dest = jnp.where(valid, dest, G)
    payloads = {"k": (keys, 0), "g": (jnp.where(valid, dest_g, -1), -1)}
    if extra:
        payloads.update(extra)
    return route_build(dest, payloads, G, capacity)


def _queue_remote_frees(data, rk, old_addr, mask):
    """Frees targeting another device's shard ride the per-device free
    queue until the gc op routes them home.  The queue holds
    log_capacity entries — the client's room guarantee bounds new frees
    per drain cycle to that — but entries addressed to a DEAD data shard
    wait out its outage here, so a long outage can overflow and drop
    frees; the slots then surface as `orphaned` in value_slot_audit and
    are reclaimed by the recovery mark-sweep (ROADMAP: data-outage
    back-pressure)."""
    freeq, _ = lg.append(_sq(data.freeq), jnp.zeros_like(rk), old_addr,
                         jnp.where(mask, 1, 0).astype(jnp.int8), mask)
    return _ex(data.freeq, freeq)


def _put_body(cfg, G, capacity, store: KVStore, keys, vals, valid,
              degraded: bool):
    """Routed PUT.  ``degraded`` is the compile-time liveness hint (same
    contract as delete's): the healthy variant assumes every index server
    and data server is up, so it skips the replica probe (old-slot lookup
    at a temporary primary) and the one-hop value displacement; the
    backend picks the variant from its host-side liveness view."""
    me = jax.lax.axis_index(AXIS)
    bufs, slot, ok_route = _route_to_owner(
        store, keys, valid, G, capacity, {"v": (vals, 0)})
    recv = exchange(bufs, AXIS)
    rk, rv, rg = recv["k"], recv["v"], recv["g"]
    valid = rg >= 0
    am_primary = rg == me
    data = store.data
    dcap = data.vals.shape[1]
    dalive_me = data.alive[me]
    winner = dp.winner_mask(rk, valid)
    # pre-batch address of the overwritten key: hash at the true primary,
    # replica + pending log at a temporary primary
    old_a, old_f, _ = hix.lookup(_sq(store.hash), rk, cfg)
    if degraded:
        old_ab, old_fb, _ = _backup_probe(cfg, store, rk, me, G)
        old_a = jnp.where(am_primary, old_a, old_ab)
        old_f = jnp.where(am_primary, old_f, old_fb)
    # --- owner side: place the value -------------------------------------
    # overwrite whose old slot is on MY live shard: update in place (no
    # allocator churn); new keys and remote-old strays: allocate fresh.
    # In-place writes land before the commit decision — like a real data
    # server's non-atomic value update, a lane nacked AFTER the write has
    # already exposed the new bytes at the old address; the client's
    # retry re-puts the same value, so the store converges, and the
    # window only exists when a backup ring rejects an append the
    # client's room guarantee should have prevented
    inplace = winner & old_f & (old_a // dcap == me) & dalive_me
    allocw = winner & ~inplace
    want = (allocw & dalive_me) if degraded else allocw
    used, slot_d, aok = dp.alloc(data.used[0], want)
    wslot = jnp.where(inplace, old_a % dcap, jnp.where(aok, slot_d, dcap))
    wmask = inplace | aok
    dvals = data.vals[0].at[jnp.where(wmask, wslot, dcap)].set(
        rv, mode="drop")
    addr_lane = jnp.where(
        inplace, old_a,
        jnp.where(aok, me * dcap + slot_d, -1)).astype(I32)
    writes = [(wslot, rv, wmask)]
    disp = jnp.zeros_like(valid)
    if degraded:
        # my own data shard is dead: displace the value one hop (the
        # neighbour's shard holds it until migrate_values brings it home)
        need_fwd = allocw & ~dalive_me
        f = replicate_shift({"v": rv, "need": need_fwd}, 1, AXIS)
        used, fslot, faok = dp.alloc(used, f["need"] & dalive_me)
        dvals = dvals.at[jnp.where(faok, fslot, dcap)].set(
            f["v"], mode="drop")
        back = replicate_shift({"slot": fslot, "aok": faok}, G - 1,
                               AXIS)
        disp = need_fwd & back["aok"]
        addr_lane = jnp.where(disp, ((me + 1) % G) * dcap + back["slot"],
                              addr_lane).astype(I32)
        writes.append((fslot, f["v"], faok))
    mirror = data.mirror
    for r in range(mirror.shape[0]):
        for ms, mv, mm in writes:
            out = replicate_shift({"s": ms, "v": mv, "m": mm}, r + 1,
                                  AXIS)
            tgt = jnp.where(out["m"] & dalive_me, out["s"], dcap)
            mirror = mirror.at[r, 0].set(
                mirror[r, 0].at[tgt].set(out["v"], mode="drop"))
    # superseded duplicate lanes share their winner's address; a failed
    # allocation (-1) un-acks the whole duplicate group for a client retry
    addr = dp.spread_winner_addr(rk, valid, winner, addr_lane)
    landed = valid & (addr >= 0)
    # --- primary log -> backup logs -> hash, commit-gated ----------------
    ops = jnp.where(landed & am_primary, six.OP_PUT, 0).astype(jnp.int8)
    plog, ok_p = lg.append(_sq(store.plog), rk, addr, ops,
                           landed & am_primary)
    # the hash update is synchronous, so primary-log entries are applied
    # the moment the batch commits; advancing the prefix keeps the ring's
    # pending window from exhausting (entries stay on disk for recovery).
    plog = plog._replace(applied=plog.tail)
    blog, ok_rep, nrep, _ = _replicate_logs(
        store.blog, store.alive, rk, addr, ops, landed, rg, me, G,
        six.OP_PUT)
    ok_commit = landed & ok_rep & ((am_primary & ok_p) | ~am_primary)
    new_hash, ok_h = hix.insert(_sq(store.hash), rk, addr, cfg,
                                ok_commit & am_primary)
    ok_req = ok_commit & (ok_h | ~am_primary)
    # --- data-server GC, commit-gated ------------------------------------
    # a committed move (new slot elsewhere) frees the old slot; an
    # un-acked lane rolls its fresh allocation back (the retry re-places)
    # ONLY when no log anywhere recorded its entry (nrep == 0): a slot a
    # replica log already references must never return to the allocator
    # — a dangling reference to re-allocatable memory is worse than a
    # leak the retry's last-writer-wins entry supersedes
    moved = winner & old_f & ~inplace & ok_req & (old_a >= 0)
    free_local = moved & (old_a // dcap == me) & dalive_me
    used = dp.free_slots(used, old_a % dcap, free_local)
    undo = ~ok_req & (nrep == 0)
    used = dp.free_slots(used, slot_d, aok & undo)
    undo_remote = disp & undo     # displaced slot lives on the neighbour
    qmask = (moved & ~free_local) | undo_remote
    qaddr = jnp.where(undo_remote, addr, old_a)
    freeq = _queue_remote_frees(data, rk, qaddr, qmask)
    ret = route_return({"ok": ok_req.astype(I32), "addr": addr,
                        "rep": nrep}, slot, AXIS)
    new_data = data._replace(
        vals=data.vals.at[0].set(dvals), used=data.used.at[0].set(used),
        mirror=mirror, freeq=freeq)
    new_store = store._replace(
        hash=_ex(store.hash, new_hash), plog=_ex(store.plog, plog),
        blog=blog, data=new_data)
    return (new_store, ret["ok"].astype(bool) & ok_route, ret["addr"],
            ret["rep"])


def _replicate_logs(blog, alive, rk, addr, ops, valid, rg, me, G, opcode):
    """Push an owner-side batch of log entries to the backup logs.
    Returns (blog, ok, nrep, ok_local):

      ok[i]   — False when a backup-log append for owner-lane i was
                rejected by a LIVE backup (ring full) — ppermuted back to
                the owner so the ack can carry the push-back instead of
                silently losing replicas.
      nrep[i] — how many replica logs actually recorded the entry.  Dead
                backups are skipped (the paper's observation that PUT
                speeds up under a backup failure), so nrep < n_backups is
                the honest report of reduced replication.
      ok_local[i] — True unless MY OWN backup-log append for a
                temporary-primary lane was rejected.  The degraded free /
                rollback decisions key on it: a retry's replica probe
                consults exactly this log, so "recorded locally" is the
                one predicate that keeps slot frees idempotent across
                retries (free the old slot / keep the new one iff the
                entry the probe will see exists).

    Healthy path: replicate the primary's entries (``ops``) to the r+1-hop
    backup holders via ppermute.  Degraded path (paper §4.3): requests
    routed to me as a BACKUP holder (primary dead) — I act as temporary
    primary, append to my backup log for that group, and forward
    replica-0 entries one hop to the replica-1 holder."""
    R = blog.tail.shape[0]
    ok = jnp.ones(rk.shape, bool)
    ok_local = jnp.ones(rk.shape, bool)
    nrep = jnp.zeros(rk.shape, I32)
    alive_me = alive[me]
    for r in range(R):
        pk = replicate_shift(rk, r + 1, AXIS)
        pa = replicate_shift(addr, r + 1, AXIS)
        po = replicate_shift(ops, r + 1, AXIS)
        should = (po > 0) & alive_me          # dead holders skip the append
        one = jax.tree.map(lambda a: a[r, 0], blog)
        one, okr = lg.append(one, pk, pa, po, should)
        ok = ok & replicate_shift(okr, (G - (r + 1)) % G, AXIS)
        nrep = nrep + replicate_shift(
            (should & okr).astype(I32), (G - (r + 1)) % G, AXIS)
        blog = jax.tree.map(lambda full, v, r=r: full.at[r, 0].set(v),
                            blog, one)
    for r in range(R):
        mine_as_backup = valid & (rg == (me - r - 1) % G) & (rg != me)
        opsb = jnp.where(mine_as_backup, opcode, 0).astype(jnp.int8)
        one = jax.tree.map(lambda a: a[r, 0], blog)
        one, okb = lg.append(one, rk, addr, opsb, mine_as_backup)
        ok = ok & okb
        ok_local = ok_local & okb
        nrep = nrep + (mine_as_backup & okb).astype(I32)
        blog = jax.tree.map(lambda full, v, r=r: full.at[r, 0].set(v),
                            blog, one)
    if R >= 2:
        ops0 = jnp.where(valid & (rg == (me - 1) % G) & (rg != me),
                         opcode, 0).astype(jnp.int8)
        fk = replicate_shift(rk, 1, AXIS)
        fa = replicate_shift(addr, 1, AXIS)
        fo = replicate_shift(ops0, 1, AXIS)
        fshould = (fo > 0) & alive_me
        one = jax.tree.map(lambda a: a[1, 0], blog)
        one, okf = lg.append(one, fk, fa, fo, fshould)
        ok = ok & replicate_shift(okf, (G - 1) % G, AXIS)
        nrep = nrep + replicate_shift(
            (fshould & okf).astype(I32), (G - 1) % G, AXIS)
        blog = jax.tree.map(lambda full, v: full.at[1, 0].set(v), blog, one)
    return blog, ok, nrep, ok_local


def _backup_probe(cfg, store: KVStore, rk, me, G):
    """Degraded lookup at a backup holder: for each replica slot I hold,
    consult its PENDING log first (newest wins), then the sorted replica.
    Lane i is answered by replica r iff I hold replica r of lane i's owner
    group.  Returns (addr, found, n_accesses)."""
    addr_b = jnp.full(rk.shape, -1, I32)
    found_b = jnp.zeros(rk.shape, bool)
    acc_b = jnp.zeros(rk.shape, I32)
    for r in range(store.blog.tail.shape[0]):
        srt = jax.tree.map(lambda a: a[r, 0], store.bsorted)
        blog = jax.tree.map(lambda a: a[r, 0], store.blog)
        a_s, f_s, c_s = six.search(srt, rk, cfg.fanout)
        hit, op, praw = lg.pending_lookup(blog, rk)
        a_r = jnp.where(hit, jnp.where(op == six.OP_PUT, praw, -1), a_s)
        f_r = jnp.where(hit, op == six.OP_PUT, f_s)
        sel = (me - r - 1) % G == owner_group(rk, G)
        addr_b = jnp.where(sel, a_r, addr_b)
        found_b = jnp.where(sel, f_r, found_b)
        acc_b = jnp.where(sel, c_s + 1, acc_b)
    return addr_b, found_b, acc_b


def _delete_body(cfg, G, capacity, store: KVStore, keys, valid,
                 degraded: bool):
    """Distributed DELETE: tombstone through primary log -> backup logs ->
    hash delete, mirroring _put_body minus the data-shard write; the
    value slot is freed immediately (the paper's data-server GC) — queued
    for the gc op when it lives on another shard.  The tombstones compact
    out of the sorted replicas at apply time.

    ``degraded`` is the compile-time analogue of the local layer's static
    primary_alive hint: with every server alive all requests land on true
    primaries, so the healthy variant skips the replica probe entirely;
    the backend picks the variant from its host-side liveness view."""
    me = jax.lax.axis_index(AXIS)
    bufs, slot, ok_route = _route_to_owner(store, keys, valid, G, capacity)
    recv = exchange(bufs, AXIS)
    rk, rg = recv["k"], recv["g"]
    valid = rg >= 0
    addr = jnp.full(rk.shape, -1, I32)
    am_primary = rg == me
    data = store.data
    dcap = data.vals.shape[1]
    old_a, old_f, _ = hix.lookup(_sq(store.hash), rk, cfg)
    if degraded:
        # existence check BEFORE this batch's tombstones land: the
        # temporary primary consults its replica + pending log, so DELETE
        # reports found honestly even while the true primary is down
        addr_b, found_b, _ = _backup_probe(cfg, store, rk, me, G)
        old_a = jnp.where(am_primary, old_a, addr_b)
        old_f = jnp.where(am_primary, old_f, found_b)
    else:
        found_b = jnp.zeros(rk.shape, bool)   # no degraded lanes exist
    ops = jnp.where(valid & am_primary, six.OP_DEL, 0).astype(jnp.int8)
    plog, ok_p = lg.append(_sq(store.plog), rk, addr, ops,
                           valid & am_primary)
    plog = plog._replace(applied=plog.tail)
    new_hash, found = hix.delete(_sq(store.hash), rk, cfg,
                                 valid & am_primary)
    blog, ok_rep, nrep, ok_loc = _replicate_logs(
        store.blog, store.alive, rk, addr, ops, valid, rg, me, G,
        six.OP_DEL)
    # data-server GC, commit-gated (winner-deduped so a double-delete in
    # one batch frees exactly once): a primary lane frees once the hash
    # tombstoned the entry — the slot is unreferenced from that moment,
    # whatever the replication ack says; a temporary-primary lane frees
    # once MY pending log recorded the tombstone — the one predicate the
    # retry's probe consults, so the free fires exactly once whether the
    # wider replication acked or not
    gate = jnp.where(am_primary, found, ok_loc & old_f)
    freed = dp.winner_mask(rk, valid) & gate & (old_a >= 0)
    free_local = freed & (old_a // dcap == me) & data.alive[me]
    used = dp.free_slots(data.used[0], old_a % dcap, free_local)
    freeq = _queue_remote_frees(data, rk, old_a, freed & ~free_local)
    ok_req = (valid & ok_rep
              & ((am_primary & ok_p) | ~am_primary)).astype(I32)
    found_req = jnp.where(am_primary, found, found_b & valid).astype(I32)
    ret = route_return({"ok": ok_req, "found": found_req, "rep": nrep},
                       slot, AXIS)
    new_store = store._replace(
        hash=_ex(store.hash, new_hash), plog=_ex(store.plog, plog),
        blog=blog, data=data._replace(used=data.used.at[0].set(used),
                                      freeq=freeq))
    return (new_store, ret["ok"].astype(bool) & ok_route,
            ret["found"].astype(bool), ret["rep"])


def _get_body(cfg, G, capacity, store: KVStore, keys, valid):
    me = jax.lax.axis_index(AXIS)
    dest_g = owner_group(keys, G)
    dest = jax.vmap(lambda g: _first_alive_holder(g, store.alive))(dest_g)
    dest = jnp.where(valid, dest, G)   # padding lanes: no capacity consumed
    bufs, slot, ok_route = route_build(
        dest, {"k": (keys, key_inf(keys.dtype))}, G, capacity)
    recv = exchange(bufs, AXIS)
    rk = recv["k"]
    # --- primary path: one-sided probe (gathers only) -------------------
    addr_p, found_p, acc_p = hix.lookup(_sq(store.hash), rk, cfg)
    # --- backup path: pending log + sorted replica (per replica slot) ---
    addr_b, found_b, acc_b = _backup_probe(cfg, store, rk, me, G)
    am_primary = owner_group(rk, G) == me
    addr = jnp.where(am_primary, addr_p, addr_b)
    found = jnp.where(am_primary, found_p, found_b)
    acc = jnp.where(am_primary, acc_p, acc_b)
    # --- value gather: one-sided read from the LOCAL data shard ---------
    dcap = store.data.vals.shape[1]
    val_ok = found & (addr // dcap == me) & store.data.alive[me]
    local_slot = jnp.where(val_ok, addr % dcap, dcap)
    vals = jnp.concatenate(
        [store.data.vals[0], jnp.zeros((1,) + store.data.vals.shape[2:],
                                       I32)]
    )[jnp.clip(local_slot, 0, dcap)]
    # remote addr (value written on a different shard during a degraded
    # write, or this shard's data server masked dead): flagged
    # val_ok=False for a second-hop _fetch_body read (paper: the client
    # reads the value from the data server given the address).
    back = route_return({"addr": addr, "found": found.astype(I32),
                         "acc": acc, "val": vals,
                         "vok": val_ok.astype(I32)}, slot, AXIS)
    # ok_route is reported separately from found: an unrouted lane (queue
    # full) is a push-back the client retries, not a miss
    return (back["addr"], back["found"].astype(bool) & ok_route,
            back["acc"], back["val"], ok_route,
            back["vok"].astype(bool))


def _fetch_body(G, capacity, store: KVStore, addrs, valid):
    """Second-hop value read: route each request to the first LIVE data
    holder of the shard owning its address — the shard itself, else a
    device hosting one of its mirror copies — and gather the value: the
    paper's client-side one-sided READ from the data server.  The data
    servers are a separate failure domain from the index servers (paper
    §2), so a fetch is answered even when the device's INDEX state is
    masked dead, and the mirrors answer when the DATA server is."""
    data = store.data
    dcap = data.vals.shape[1]
    Rv = data.mirror.shape[0]
    shard = jnp.where(addrs >= 0, addrs // dcap, 0)
    dest, servable = jax.vmap(
        lambda s: _first_alive_data_holder(s, data.alive, Rv))(shard)
    dest = jnp.where(valid & (addrs >= 0) & servable, dest, G)
    bufs, slot, ok_route = route_build(dest, {"a": (addrs, -1)}, G, capacity)
    recv = exchange(bufs, AXIS)
    ra = recv["a"]
    me = jax.lax.axis_index(AXIS)
    rs = jnp.where(ra >= 0, ra // dcap, G)
    lslot = jnp.where(ra >= 0, ra % dcap, dcap)
    pad = lambda a: jnp.concatenate(
        [a, jnp.zeros((1,) + a.shape[1:], a.dtype)])
    vals = pad(data.vals[0])[jnp.clip(lslot, 0, dcap)]
    taken = rs == me
    for r in range(Rv):
        sel = (rs == (me - r - 1) % G) & ~taken
        mv = pad(data.mirror[r, 0])[jnp.clip(lslot, 0, dcap)]
        vals = jnp.where(sel[:, None], mv, vals)
        taken = taken | sel
    back = route_return({"val": vals}, slot, AXIS)
    # a lane whose every holder is dead reports un-routed (push-back the
    # client surfaces as routed=False), never a fabricated zero value
    return back["val"], ok_route & (servable | ~valid | (addrs < 0))


def _gc_body(G, capacity, store: KVStore):
    """One flush round of the pending free queues: route each queued freed
    address to the data shard that owns it, which clears the allocator
    bit.  Frees whose destination shard is masked dead, or that overflow
    the exchange, are re-queued for a later round."""
    data = store.data
    dcap = data.vals.shape[1]
    freeq = _sq(data.freeq)
    B = min(freeq.keys.shape[0], G * capacity)
    k, a, o, freeq = lg.take_pending(freeq, B)
    pend = o > 0
    dest_s = jnp.where(pend & (a >= 0), a // dcap, G)
    deliver = pend & (dest_s < G) & data.alive[jnp.clip(dest_s, 0, G - 1)]
    dest = jnp.where(deliver, dest_s, G)
    bufs, _, okq = route_build(dest, {"a": (a, -1)}, G, capacity)
    recv = exchange(bufs, AXIS)
    ra = recv["a"]
    used = dp.free_slots(data.used[0],
                         jnp.where(ra >= 0, ra % dcap, dcap), ra >= 0)
    requeue = pend & ~(deliver & okq)
    freeq, _ = lg.append(freeq, k, a,
                         jnp.where(requeue, 1, 0).astype(jnp.int8), requeue)
    return store._replace(data=data._replace(
        used=data.used.at[0].set(used), freeq=_ex(data.freeq, freeq)))


def _apply_body(cfg, batch, store: KVStore):
    blog = store.blog
    bsorted = store.bsorted
    for r in range(store.blog.tail.shape[0]):
        one_log = jax.tree.map(lambda a: a[r, 0], blog)
        one_srt = jax.tree.map(lambda a: a[r, 0], bsorted)
        keys, addrs, ops, one_log = lg.take_pending(one_log, batch)
        one_srt = six.merge(one_srt, keys, addrs, ops)
        blog = jax.tree.map(lambda f, v, r=r: f.at[r, 0].set(v), blog, one_log)
        bsorted = jax.tree.map(lambda f, v, r=r: f.at[r, 0].set(v),
                               bsorted, one_srt)
    return store._replace(blog=blog, bsorted=bsorted)


def _scan_body(cfg, G, limit, store: KVStore, lo, hi):
    me = jax.lax.axis_index(AXIS)
    # drain my replicas, then range-query the ones I should serve.  The
    # ring bounds pending entries by log_capacity, so the round bound
    # guarantees a COMPLETE drain (SCAN serializability); the while_loop
    # exits as soon as this device's logs are empty, so a mostly-drained
    # store pays one merge round, not log_capacity/batch of them.  (No
    # collectives in the body, so per-device trip counts are safe.)
    rounds = max(1, -(-cfg.log_capacity // cfg.async_apply_batch))

    def _pending(st):
        return jnp.max(st.blog.tail - st.blog.applied)

    st, _ = jax.lax.while_loop(
        lambda c: (c[1] < rounds) & (_pending(c[0]) > 0),
        lambda c: (_apply_body(cfg, cfg.async_apply_batch, c[0]), c[1] + 1),
        (store, jnp.int32(0)))
    outs_k, outs_a = [], []
    for r in range(store.blog.tail.shape[0]):
        srt = jax.tree.map(lambda a: a[r, 0], st.bsorted)
        k, a, n = six.range_query(srt, lo[0], hi[0], limit)
        g = (me - r - 1) % G
        # serve replica r of group g iff I'm alive and (r==0 or the r-1
        # holder (device g+r) is dead)
        holder_prev_ok = store.alive[(g + r) % G] if r > 0 else jnp.array(False)
        serve = store.alive[me] & ((r == 0) | ~holder_prev_ok)
        k = jnp.where(serve, k, key_inf(k.dtype))
        a = jnp.where(serve, a, -1)
        outs_k.append(k)
        outs_a.append(a)
    mk = jnp.stack(outs_k)          # [R, limit]
    ma = jnp.stack(outs_a)
    allk = jax.lax.all_gather(mk, AXIS).reshape(-1)   # [G*R*limit]
    alla = jax.lax.all_gather(ma, AXIS).reshape(-1)
    order = jnp.argsort(allk)
    return allk[order][:limit], alla[order][:limit], st


# ---------------------------------------------------------------------------
# Public API (jit + shard_map wrappers)
# ---------------------------------------------------------------------------
def _smap(mesh, f, in_specs, out_specs):
    from repro.sharding.smap import shard_map
    return jax.jit(shard_map(f, mesh, in_specs, out_specs))


@functools.lru_cache(maxsize=32)
def make_ops(mesh, cfg, capacity_q: int = 64, scan_limit: int = 128):
    """Build the jitted distributed ops for a mesh.

    put(st, keys, vals, valid)  -> (st, ok, addrs, nrep)
    put_degraded(...)           -> as put, plus the old-slot replica probe
                                   at temporary primaries and the one-hop
                                   value displacement off dead data shards
                                   (use while any server is masked dead)
    get(st, keys, valid)        -> (addrs, found, accesses, vals, routed,
                                    val_ok)
    fetch(st, addrs, valid)     -> (vals, routed)   second-hop value read
    delete(st, keys, valid)     -> (st, ok, found, nrep)
    delete_degraded(...)        -> as delete, plus the replica probe that
                                   answers found at a temporary primary
                                   (use while any server is masked dead)
    apply(st)                   -> st
    gc(st)                      -> st   one free-queue flush round
    scan(st, lo, hi)            -> (keys, addrs, st)
    """
    G = mesh.devices.size
    S = _specs()

    put, put_degraded = (
        _smap(mesh,
              lambda st, k, v, m, d=d: _put_body(cfg, G, capacity_q,
                                                 st, k, v, m, d),
              (S, P(AXIS), P(AXIS), P(AXIS)),
              (S, P(AXIS), P(AXIS), P(AXIS)))
        for d in (False, True))
    get = _smap(mesh, lambda st, k, m: _get_body(cfg, G, capacity_q, st, k, m),
                (S, P(AXIS), P(AXIS)),
                (P(AXIS), P(AXIS), P(AXIS), P(AXIS), P(AXIS), P(AXIS)))
    fetch = _smap(mesh,
                  lambda st, a, m: _fetch_body(G, capacity_q, st, a, m),
                  (S, P(AXIS), P(AXIS)), (P(AXIS), P(AXIS)))
    delete, delete_degraded = (
        _smap(mesh,
              lambda st, k, m, d=d: _delete_body(cfg, G, capacity_q,
                                                 st, k, m, d),
              (S, P(AXIS), P(AXIS)),
              (S, P(AXIS), P(AXIS), P(AXIS)))
        for d in (False, True))
    apply_async = _smap(mesh,
                        lambda st: _apply_body(cfg, cfg.async_apply_batch, st),
                        (S,), S)
    gc = _smap(mesh, lambda st: _gc_body(G, capacity_q, st), (S,), S)
    scan = _smap(mesh, lambda st, lo, hi: _scan_body(cfg, G, scan_limit,
                                                     st, lo, hi),
                 (S, P(AXIS), P(AXIS)), (P(), P(), S))
    return {"put": put, "put_degraded": put_degraded, "get": get,
            "fetch": fetch, "delete": delete,
            "delete_degraded": delete_degraded, "apply": apply_async,
            "gc": gc, "scan": scan}


# ---------------------------------------------------------------------------
# Failure & recovery protocol (paper §4.3, host-side control plane)
# ---------------------------------------------------------------------------
def fail_server(store: KVStore, dev: int, wipe: bool = True) -> KVStore:
    """Mask device ``dev``'s INDEX server dead.  ``wipe`` (default) also
    destroys the index state it held — the hash table + primary log of
    group ``dev`` and every sorted replica + backup log hosted on ``dev``
    — so recovery MUST rebuild from surviving copies (the honest failure
    model; the data shard survives: data servers are a separate failure
    domain, paper §2 — fail_data_server is their own kill switch)."""
    store = store._replace(alive=store.alive.at[dev].set(False))
    if not wipe:
        return store
    INF = key_inf(store.bsorted.keys.dtype)
    h, s = store.hash, store.bsorted
    p_empty = lg.clear(jax.tree.map(lambda a: a[dev], store.plog))
    b_empty = lg.clear(jax.tree.map(lambda a: a[:, dev], store.blog))
    return store._replace(
        hash=hix.HashIndex(
            sig=h.sig.at[dev].set(0), fp=h.fp.at[dev].set(0),
            addr=h.addr.at[dev].set(-1), fill=h.fill.at[dev].set(0)),
        plog=jax.tree.map(lambda f, v: f.at[dev].set(v), store.plog,
                          p_empty),
        bsorted=six.SortedIndex(
            keys=s.keys.at[:, dev].set(INF),
            addrs=s.addrs.at[:, dev].set(-1),
            size=s.size.at[:, dev].set(0)),
        blog=jax.tree.map(lambda f, v: f.at[:, dev].set(v), store.blog,
                          b_empty))


def fail_data_server(store: KVStore, dev: int, wipe: bool = True) -> KVStore:
    """Mask device ``dev``'s DATA server dead (see data_plane.py)."""
    return dp.fail_data_server(store, dev, wipe)


def recover_data_server(store: KVStore, dev: int, cfg) -> KVStore:
    """Rebuild device ``dev``'s data shard from its mirrors and mark-sweep
    the allocator (see data_plane.py)."""
    return dp.recover_data_server(store, dev, cfg)


def migrate_values(store: KVStore, cfg):
    """Background value migration: move degraded-write strays back to
    their owner group's shard and patch the index addresses, restoring
    one-RTT GETs (see data_plane.py).  Returns (store, n_moved)."""
    return dp.migrate_values(store, cfg, owner_group)


# the shared eager drain primitive (one home for the semantics)
_drain_one = dp.drain_pair


def _set_slice(tree, val, idx):
    return jax.tree.map(lambda f, v: f.at[idx].set(v), tree, val)


def recover_server(store: KVStore, dev: int, cfg) -> KVStore:
    """Recover device ``dev``'s index server from surviving copies
    (host-side control plane; eager, not shard_map'd):

      1. rebuild group ``dev``'s hash table from the first live sorted
         replica of that group (drained first), exactly the paper's
         hash-from-skiplist rebuild;
      2. re-clone every sorted replica + backup log ``dev`` hosts from the
         surviving copy of the same group (skiplist-from-replica rebuild);
      3. mark ``dev`` alive again.

    Requires at least one live holder per lost structure (single-failure
    tolerance with n_backups=2; simultaneous multi-failure rebuild beyond
    that is an open item — see ROADMAP)."""
    import numpy as np

    G = int(store.alive.shape[0])
    R = int(store.blog.tail.shape[0])
    alive = np.asarray(store.alive)
    if bool(alive[dev]):
        return store
    if G == 1:
        # single-server store: nothing was wiped (no surviving copy could
        # exist), recovery is just the liveness flip
        return store._replace(alive=store.alive.at[dev].set(True))

    def first_live_holder(group, exclude):
        for r in range(R):
            h = (group + r + 1) % G
            if h != exclude and alive[h]:
                return r, h
        return None

    # -- 1. hash-from-sorted-replica rebuild for group ``dev`` ------------
    src = first_live_holder(dev, dev)
    if src is None:
        raise ValueError(
            f"group {dev}: no live replica holder to rebuild from")
    r, h = src
    srt = jax.tree.map(lambda a: a[r, h], store.bsorted)
    blog = jax.tree.map(lambda a: a[r, h], store.blog)
    srt, blog = _drain_one(srt, blog, cfg)
    store = store._replace(bsorted=_set_slice(store.bsorted, srt, (r, h)),
                           blog=_set_slice(store.blog, blog, (r, h)))
    keys, addrs, valid = six.items(srt)
    hs = jax.tree.map(lambda a: a[dev], store.hash)
    fresh = hix.HashIndex(sig=jnp.zeros_like(hs.sig),
                          fp=jnp.zeros_like(hs.fp),
                          addr=jnp.full_like(hs.addr, -1),
                          fill=jnp.zeros_like(hs.fill))
    # the valid mask keeps empty sorted-array slots out of the table
    # entirely (no appended-then-tombstoned junk eating chain headroom)
    new_hash, _ = hix.insert(fresh, keys, addrs, cfg, valid)
    store = store._replace(hash=_set_slice(store.hash, new_hash, dev),
                           plog=_set_slice(
                               store.plog,
                               lg.create(store.plog.keys.shape[1],
                                         store.plog.keys.dtype), dev))
    # -- 2. sorted-replica re-clone for each group hosted on ``dev`` ------
    for r2 in range(R):
        g = (dev - r2 - 1) % G
        src2 = first_live_holder(g, dev)
        if src2 is None:
            continue   # no surviving copy: loss beyond tolerance
        r3, h3 = src2
        s_srt = jax.tree.map(lambda a: a[r3, h3], store.bsorted)
        s_blog = jax.tree.map(lambda a: a[r3, h3], store.blog)
        s_srt, s_blog = _drain_one(s_srt, s_blog, cfg)
        store = store._replace(
            bsorted=_set_slice(_set_slice(store.bsorted, s_srt, (r3, h3)),
                               s_srt, (r2, dev)),
            blog=_set_slice(_set_slice(store.blog, s_blog, (r3, h3)),
                            s_blog, (r2, dev)))
    return store._replace(alive=store.alive.at[dev].set(True))


def parity_report(store: KVStore, cfg) -> list:
    """Hash/sorted parity + value-slot audit (test/debug helper, eager).
    For every group g and replica r: drain a COPY of the replica, then
    check the replica's live item count equals the hash table's, every
    replica key is found in the hash, and the addresses agree.  A final
    ``value_slots`` entry audits the data plane's slot accounting (every
    live address allocated, nothing orphaned or double-referenced — see
    data_plane.value_slot_audit).  Returns a list of dicts with an
    ``agree`` bool; entries carry ``primary_alive``/``holder_alive`` so a
    mid-failure caller can restrict the assertion to live structures."""
    import numpy as np

    G = int(store.alive.shape[0])
    R = int(store.blog.tail.shape[0])
    alive = np.asarray(store.alive)
    out = []
    for g in range(G):
        hs = jax.tree.map(lambda a: a[g], store.hash)
        n_hash = int(hix.n_items(hs))
        for r in range(R):
            h = (g + r + 1) % G
            srt = jax.tree.map(lambda a: a[r, h], store.bsorted)
            blog = jax.tree.map(lambda a: a[r, h], store.blog)
            srt, _ = _drain_one(srt, blog, cfg)
            keys, addrs, valid = six.items(srt)
            n_sorted = int(valid.sum())
            a_h, f_h, _ = hix.lookup(hs, keys, cfg)
            found_ok = bool(np.asarray(f_h | ~valid).all())
            addr_ok = bool(np.asarray((a_h == addrs) | ~valid).all())
            out.append({"group": g, "replica": r, "holder": h,
                        "primary_alive": bool(alive[g]),
                        "holder_alive": bool(alive[h]),
                        "n_hash": n_hash, "n_sorted": n_sorted,
                        "agree": (n_hash == n_sorted) and found_ok
                        and addr_ok})
    out.append(dp.value_slot_audit(store, cfg))
    return out
