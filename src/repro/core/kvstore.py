"""HiStore: the distributed key-value store over index groups.

Topology (one group per device; cfg.groups_per_device generalises):
  device g is the PRIMARY server of group g (hash table + primary log + the
  group's data-server shard) and the BACKUP server for groups g-1 (replica
  0) and g-2 (replica 1): backup arrays use the SHIFTED layout — slice
  [r, p] stores replica r of group (p - r - 1) mod G, so placing slice p on
  device p puts every replica on a different failure domain, and log
  replication is a ppermute by r+1 hops.  The value plane (slot allocator,
  mirror replication, free queues) is the ``data`` field — see
  data_plane.py; data servers are a failure domain separate from the index
  servers (paper §2).

Ops (all shard_map'd over the 1-D "kv" mesh axis; see verbs.py for the
RDMA-verb mapping):
  put    — route to owner; owner allocates a free slot on its data shard
           (overwrites free the old slot first — the data-server GC),
           stores + mirrors the value, appends its log, pushes the entries
           to the LIVE backup logs (ppermute; dead holders are skipped),
           updates the hash table, acks with the replica count actually
           written.  A full shard rejects the lane (client retries after
           a GC round).
  put_degraded — as put, plus the replica probe that finds the old slot at
           a temporary primary, and one-hop value displacement when the
           owner's own data shard is masked dead.
  get    — one-sided: route, owner-side gather-only probe, value gather,
           reverse route.  Primary dead -> the query is routed to a backup
           holder, which consults its pending log + sorted replica; values
           stored on another shard are flagged for a second-hop fetch.
  fetch  — second-hop value read: route by address to the first LIVE data
           holder of the owning shard (primary copy, then its mirrors).
  delete — route to owner; owner appends a tombstone to its log, pushes it
           to the live backup logs (ppermute), tombstones the hash slot,
           frees the value slot (queued for the gc op when remote), acks
           (degraded found answered from the replica + pending log).
           The tombstone compacts out of the sorted replicas on apply.
  scan   — backup-side: every device fully drains and range-queries the
           replicas it holds, results are all_gathered and merged.
  apply_async — one batched log->sorted merge round on every backup.
  gc     — one routed flush round of the pending free queues (frees whose
           slot lives on another shard travel home and clear the bit).
  tick   — heartbeat-only round: every device bumps its per-server
           heartbeat counters — index AND data plane (as every routed op
           does in-body); the client ages the counters host-side
           (elapsed wall-clock time by default, observation rounds in
           the deterministic test mode) and demotes a server to degraded
           routing when its lease expires — failure DETECTION without an
           oracle caller (DESIGN.md §Failure detection).  An idle
           client's background ticker thread issues tick rounds so
           detection needs no foreground traffic.
  fail_server / sever_server / recover_server / re_replicate /
  parity_report — host-side failure control plane: fail WIPES the
           device's index state with the client told at once; sever
           wipes it but only STOPS ITS HEARTBEATS (the client must
           detect); recover snapshot-clones from survivors and lets the
           pending log delta stream into the rebuilt replicas through
           the ordinary apply rounds while foreground traffic continues
           (online catch-up; falls back to the hash + the keys stored
           with the data items on multi-failure, raising the typed
           RecoveryError only when truly no copy exists); re_replicate
           verifies every live holder against the group authorities and
           rebuilds divergent copies (DESIGN.md §Fault tolerance).
  fail_data_server / sever_data_server / recover_data_server /
  migrate_values — the value plane's control plane (data_plane.py):
           oracle kill, lease-detected kill (heartbeats cut, routing
           view untouched), mirror-rebuild recovery, and the background
           migration that moves degraded-write values home and patches
           index addresses (second-hop fetch elision).

All mutating ops take a ``valid`` lane mask so the client can pad request
batches to fixed shapes (DESIGN.md §Client); invalid lanes are routed
nowhere, consume no exchange capacity, and mutate nothing.  External
callers should not call these ops directly — go through
repro.core.client.HiStoreClient, which adds overflow retry, batch padding
and the async-apply policy.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import data_plane as dp
from repro.core import hash_index as hix
from repro.core import log as lg
from repro.core import sorted_index as six
from repro.core.hashing import fmix32, key_inf
from repro.kernels import ops as kops
from repro.core.verbs import (exchange, replicate_shift, route_build,
                              route_return)

I32 = jnp.int32
AXIS = "kv"

RecoveryError = dp.RecoveryError   # typed multi-failure recovery error


class KVStore(NamedTuple):
    hash: hix.HashIndex       # leaves [G, ...]
    plog: lg.UpdateLog        # leaves [G, ...]
    bsorted: six.SortedIndex  # leaves [R, G, ...] (shifted layout)
    blog: lg.UpdateLog        # leaves [R, G, ...]
    data: dp.DataPlane        # value plane (shard + allocator + mirrors)
    alive: jnp.ndarray        # [G] bool — the CLIENT's routing view of
    #                           index-server liveness (flipped by the
    #                           oracle kill switch OR the lease detector)
    sever: jnp.ndarray        # [G] bool — heartbeats severed: the server
    #                           has crashed but the client has not noticed
    #                           yet; lanes delivered there are nacked (the
    #                           RPC-timeout analogue) and its heartbeat
    #                           counter stops advancing
    hb: jnp.ndarray           # [G] int32 heartbeat counters — each device
    #                           bumps its own inside every routed op; the
    #                           client ages them host-side (leases)


def create(mesh, capacity_per_group: int, cfg, key_dt=None) -> KVStore:
    G = mesh.devices.size
    R = cfg.n_backups
    rep = lambda t, n: jax.tree.map(
        lambda a: jnp.broadcast_to(a[None], (n,) + a.shape).copy(), t)
    one_hash = hix.create(capacity_per_group, cfg)
    one_plog = lg.create(cfg.log_capacity, key_dt)
    one_sorted = six.create(capacity_per_group, key_dt)
    one_blog = lg.create(cfg.log_capacity, key_dt)
    store = KVStore(
        hash=rep(one_hash, G),
        plog=rep(one_plog, G),
        bsorted=rep(rep(one_sorted, G), R),
        blog=rep(rep(one_blog, G), R),
        data=dp.create(G, capacity_per_group, cfg, key_dt),
        alive=jnp.ones((G,), bool),
        sever=jnp.zeros((G,), bool),
        hb=jnp.zeros((G,), I32),
    )
    return jax.device_put(store, store_sharding(mesh))


def store_sharding(mesh):
    from jax.sharding import NamedSharding

    # group axis position differs: hash/plog/data shard dim0; bsorted/blog
    # shard dim1; alive/sever replicated, hb sharded (each device owns its
    # own heartbeat counter).
    return KVStore(
        hash=hix.HashIndex(*[NamedSharding(mesh, P(AXIS))] * 4),
        plog=lg.UpdateLog(*[NamedSharding(mesh, P(AXIS))] * 5),
        bsorted=six.SortedIndex(*[NamedSharding(mesh, P(None, AXIS))] * 3),
        blog=lg.UpdateLog(*[NamedSharding(mesh, P(None, AXIS))] * 5),
        data=dp.sharding(mesh, AXIS),
        alive=NamedSharding(mesh, P()),
        sever=NamedSharding(mesh, P()),
        hb=NamedSharding(mesh, P(AXIS)),
    )


def _specs():
    return KVStore(
        hash=hix.HashIndex(*[P(AXIS)] * 4),
        plog=lg.UpdateLog(*[P(AXIS)] * 5),
        bsorted=six.SortedIndex(*[P(None, AXIS)] * 3),
        blog=lg.UpdateLog(*[P(None, AXIS)] * 5),
        data=dp.specs(AXIS),
        alive=P(),
        sever=P(),
        hb=P(AXIS),
    )


def owner_group(keys, G: int):
    """Group routing hash — decorrelated from the bucket hash."""
    from repro.core.hashing import key_mix
    h1, h2 = key_mix(keys)
    return (fmix32(h2 ^ jnp.uint32(0xA5A5A5A5)) % jnp.uint32(G)).astype(I32)


def _first_alive_holder(g, alive):
    """Device to contact for group g: primary g, else backup holders."""
    G = alive.shape[0]
    cand = jnp.stack([g % G, (g + 1) % G, (g + 2) % G])
    ok = alive[cand]
    pick = jnp.argmax(ok)          # first alive in priority order
    return cand[pick]


def _first_alive_data_holder(s, dalive, Rv: int):
    """Data server to contact for shard s: the shard itself, else the
    devices hosting its mirror copies (priority order).  Returns
    (holder, any_alive): when every holder is dead (loss beyond the
    configured value replication) the caller must leave the lane
    un-routed — a push-back, never a fabricated value."""
    G = dalive.shape[0]
    cand = jnp.stack([s % G] + [(s + r + 1) % G for r in range(Rv)])
    ok = dalive[cand]
    return cand[jnp.argmax(ok)], ok.any()


# ---------------------------------------------------------------------------
# shard_map bodies (one device's view; leading group axis is local size 1)
# ---------------------------------------------------------------------------
def _sq(tree):
    return jax.tree.map(lambda a: a[0], tree)


def _ex(tree, val):
    return jax.tree.map(lambda a, v: a.at[0].set(v), tree, val)


def _route_to_owner(store, keys, valid, G, capacity, extra=None):
    """Shared routing prologue of the mutating ops: invalid (padding) lanes
    get an out-of-range destination, so they occupy no exchange capacity
    and arrive nowhere."""
    dest_g = owner_group(keys, G)
    dest = jax.vmap(lambda g: _first_alive_holder(g, store.alive))(dest_g)
    dest = jnp.where(valid, dest, G)
    payloads = {"k": (keys, 0), "g": (jnp.where(valid, dest_g, -1), -1)}
    if extra:
        payloads.update(extra)
    return route_build(dest, payloads, G, capacity)


def _queue_remote_frees(data, rk, old_addr, mask):
    """Frees targeting another device's shard ride the per-device free
    queue until the gc op routes them home.  The queue holds
    log_capacity entries; entries addressed to a DEAD data shard wait
    out its outage here, so a long outage can FILL it.  The op bodies
    pre-gate on queue room (lanes that would need to queue a free are
    nacked for a client retry when no room exists — push-back, never a
    silent drop), so the append below cannot overflow; ``ok`` is still
    returned so any residual rejection lands in the ``fq_spill`` audit
    counter instead of vanishing."""
    freeq, ok = lg.append(_sq(data.freeq), jnp.zeros_like(rk), old_addr,
                          jnp.where(mask, 1, 0).astype(jnp.int8), mask)
    return _ex(data.freeq, freeq), ok


def _fq_pregate(data, may_queue):
    """Queue-full push-back: lanes that may need to queue a remote free
    are admitted only while the per-device free queue has room for them
    (cumulative rank within the batch).  Returns the per-lane admit
    mask."""
    fq = _sq(data.freeq)
    room = fq.keys.shape[0] - (fq.tail - fq.applied)
    qrank = jnp.cumsum(may_queue.astype(I32)) - 1
    return ~may_queue | (qrank < room)


def _bump_hb(store):
    """Heartbeat: every device advances its own INDEX-server counter and
    its own DATA-server counter inside each routed op — unless the
    respective server's heartbeats are severed (crashed).  Bumping both
    planes everywhere keeps either lease from stalling spuriously under
    a one-sided workload (e.g. a drain's apply rounds must not expire
    healthy data-server leases).  The client ages both counter arrays
    host-side (the unified liveness plane)."""
    me = jax.lax.axis_index(AXIS)
    d = store.data
    return store._replace(
        hb=store.hb + jnp.where(store.sever[me], 0, 1).astype(I32),
        data=d._replace(
            hb=d.hb + jnp.where(d.sever[me], 0, 1).astype(I32)))


def _put_body(cfg, G, capacity, store: KVStore, keys, vals, valid,
              degraded: bool):
    """Routed PUT.  ``degraded`` is the compile-time liveness hint (same
    contract as delete's): the healthy variant assumes every index server
    and data server is up, so it skips the replica probe (old-slot lookup
    at a temporary primary) and the one-hop value displacement; the
    backend picks the variant from its host-side liveness view."""
    me = jax.lax.axis_index(AXIS)
    bufs, slot, ok_route = _route_to_owner(
        store, keys, valid, G, capacity, {"v": (vals, 0)})
    recv = exchange(bufs, AXIS)
    rk, rv, rg = recv["k"], recv["v"], recv["g"]
    # a severed (crashed-but-undetected) server answers nothing: lanes
    # delivered here are dropped un-acked — the RPC-timeout the client
    # retries until its lease detector demotes this device
    valid = (rg >= 0) & ~store.sever[me]
    am_primary = rg == me
    data = store.data
    dcap = data.vals.shape[1]
    # effective data-server liveness: a severed (crashed-but-undetected)
    # data server accepts no writes — its lanes fail allocation and nack
    # for a client retry (the RPC timeout), until the lease detector
    # demotes it and the degraded variant displaces instead
    dalive_me = data.alive[me] & ~data.sever[me]
    winner = dp.winner_mask(rk, valid)
    # pre-batch address of the overwritten key: hash at the true primary,
    # replica + pending log at a temporary primary
    if degraded:
        old_a, old_f, _, old_ab, old_fb, _ = _index_probe(
            cfg, store, rk, me, G)
        old_a = jnp.where(am_primary, old_a, old_ab)
        old_f = jnp.where(am_primary, old_f, old_fb)
    else:
        old_a, old_f, _ = kops.probe(cfg, _sq(store.hash), rk)
    # --- owner side: place the value -------------------------------------
    # overwrite whose old slot is on MY live shard: update in place (no
    # allocator churn); new keys and remote-old strays: allocate fresh.
    # In-place writes land before the commit decision — like a real data
    # server's non-atomic value update, a lane nacked AFTER the write has
    # already exposed the new bytes at the old address; the client's
    # retry re-puts the same value, so the store converges, and the
    # window only exists when a backup ring rejects an append the
    # client's room guarantee should have prevented
    inplace = winner & old_f & (old_a // dcap == me) & dalive_me
    allocw = winner & ~inplace
    # free-queue push-back BEFORE anything commits: a lane that may need
    # to queue a remote free (moved overwrite whose old slot lives on
    # another shard; displaced write whose rollback would queue) is
    # admitted only while the queue has room — so queued frees can never
    # be dropped, only pushed back to the client's retry loop
    may_queue = allocw & old_f & (old_a >= 0) & (old_a // dcap != me)
    if degraded:
        may_queue = may_queue | (allocw & ~dalive_me)
    fq_ok = _fq_pregate(data, may_queue)
    allocw = allocw & fq_ok
    want = allocw & dalive_me
    used, slot_d, aok = dp.alloc(data.used[0], want)
    wslot = jnp.where(inplace, old_a % dcap, jnp.where(aok, slot_d, dcap))
    wmask = inplace | aok
    wtgt = jnp.where(wmask, wslot, dcap)
    dvals = data.vals[0].at[wtgt].set(rv, mode="drop")
    # the data item carries its KEY alongside the value (paper §2): an
    # index rebuild can fetch (key, addr) pairs back from the data
    # servers — the multi-failure recovery authority of last resort
    dkeys = data.keys[0].at[wtgt].set(rk, mode="drop")
    addr_lane = jnp.where(
        inplace, old_a,
        jnp.where(aok, me * dcap + slot_d, -1)).astype(I32)
    writes = [(wslot, rv, rk, wmask)]
    disp = jnp.zeros_like(valid)
    if degraded:
        # my own data shard is dead: displace the value one hop (the
        # neighbour's shard holds it until migrate_values brings it home)
        need_fwd = allocw & ~dalive_me
        f = replicate_shift({"v": rv, "k": rk, "need": need_fwd}, 1, AXIS)
        used, fslot, faok = dp.alloc(used, f["need"] & dalive_me)
        ftgt = jnp.where(faok, fslot, dcap)
        dvals = dvals.at[ftgt].set(f["v"], mode="drop")
        dkeys = dkeys.at[ftgt].set(f["k"], mode="drop")
        back = replicate_shift({"slot": fslot, "aok": faok}, G - 1,
                               AXIS)
        disp = need_fwd & back["aok"]
        addr_lane = jnp.where(disp, ((me + 1) % G) * dcap + back["slot"],
                              addr_lane).astype(I32)
        writes.append((fslot, f["v"], f["k"], faok))
    mirror, kmirror = data.mirror, data.kmirror
    for r in range(mirror.shape[0]):
        for ms, mv, mk, mm in writes:
            out = replicate_shift({"s": ms, "v": mv, "k": mk, "m": mm},
                                  r + 1, AXIS)
            tgt = jnp.where(out["m"] & dalive_me, out["s"], dcap)
            mirror = mirror.at[r, 0].set(
                mirror[r, 0].at[tgt].set(out["v"], mode="drop"))
            kmirror = kmirror.at[r, 0].set(
                kmirror[r, 0].at[tgt].set(out["k"], mode="drop"))
    # superseded duplicate lanes share their winner's address; a failed
    # allocation (-1) un-acks the whole duplicate group for a client retry
    addr = dp.spread_winner_addr(rk, valid, winner, addr_lane)
    landed = valid & (addr >= 0)
    # --- primary log -> backup logs -> hash, commit-gated ----------------
    ops = jnp.where(landed & am_primary, six.OP_PUT, 0).astype(jnp.int8)
    plog, ok_p = lg.append(_sq(store.plog), rk, addr, ops,
                           landed & am_primary)
    # the hash update is synchronous, so primary-log entries are applied
    # the moment the batch commits; advancing the prefix keeps the ring's
    # pending window from exhausting (entries stay on disk for recovery).
    plog = plog._replace(applied=plog.tail)
    blog, ok_rep, nrep, _ = _replicate_logs(
        store.blog, store.alive & ~store.sever, rk, addr, ops, landed,
        rg, me, G, six.OP_PUT)
    ok_commit = landed & ok_rep & ((am_primary & ok_p) | ~am_primary)
    new_hash, ok_h = hix.insert(_sq(store.hash), rk, addr, cfg,
                                ok_commit & am_primary)
    ok_req = ok_commit & (ok_h | ~am_primary)
    # --- data-server GC, commit-gated ------------------------------------
    # a committed move (new slot elsewhere) frees the old slot; an
    # un-acked lane rolls its fresh allocation back (the retry re-places)
    # ONLY when no log anywhere recorded its entry (nrep == 0): a slot a
    # replica log already references must never return to the allocator
    # — a dangling reference to re-allocatable memory is worse than a
    # leak the retry's last-writer-wins entry supersedes
    moved = winner & old_f & ~inplace & ok_req & (old_a >= 0)
    free_local = moved & (old_a // dcap == me) & dalive_me
    used = dp.free_slots(used, old_a % dcap, free_local)
    undo = ~ok_req & (nrep == 0)
    used = dp.free_slots(used, slot_d, aok & undo)
    undo_remote = disp & undo     # displaced slot lives on the neighbour
    qmask = (moved & ~free_local) | undo_remote
    qaddr = jnp.where(undo_remote, addr, old_a)
    freeq, fq_acc = _queue_remote_frees(data, rk, qaddr, qmask)
    fq_spill = data.fq_spill + (qmask & ~fq_acc).sum().astype(I32)
    ret = route_return({"ok": ok_req.astype(I32), "addr": addr,
                        "rep": nrep}, slot, AXIS)
    new_data = data._replace(
        vals=data.vals.at[0].set(dvals), used=data.used.at[0].set(used),
        keys=data.keys.at[0].set(dkeys), mirror=mirror, kmirror=kmirror,
        freeq=freeq, fq_spill=fq_spill)
    new_store = _bump_hb(store._replace(
        hash=_ex(store.hash, new_hash), plog=_ex(store.plog, plog),
        blog=blog, data=new_data))
    return (new_store, ret["ok"].astype(bool) & ok_route, ret["addr"],
            ret["rep"])


def _replicate_logs(blog, alive, rk, addr, ops, valid, rg, me, G, opcode):
    """Push an owner-side batch of log entries to the backup logs.
    Returns (blog, ok, nrep, ok_local):

      ok[i]   — False when a backup-log append for owner-lane i was
                rejected by a LIVE backup (ring full) — ppermuted back to
                the owner so the ack can carry the push-back instead of
                silently losing replicas.
      nrep[i] — how many replica logs actually recorded the entry.  Dead
                backups are skipped (the paper's observation that PUT
                speeds up under a backup failure), so nrep < n_backups is
                the honest report of reduced replication.
      ok_local[i] — True unless MY OWN backup-log append for a
                temporary-primary lane was rejected.  The degraded free /
                rollback decisions key on it: a retry's replica probe
                consults exactly this log, so "recorded locally" is the
                one predicate that keeps slot frees idempotent across
                retries (free the old slot / keep the new one iff the
                entry the probe will see exists).

    Healthy path: replicate the primary's entries (``ops``) to the r+1-hop
    backup holders via ppermute.  Degraded path (paper §4.3): requests
    routed to me as a BACKUP holder (primary dead) — I act as temporary
    primary, append to my backup log for that group, and forward
    replica-0 entries one hop to the replica-1 holder."""
    R = blog.tail.shape[0]
    ok = jnp.ones(rk.shape, bool)
    ok_local = jnp.ones(rk.shape, bool)
    nrep = jnp.zeros(rk.shape, I32)
    alive_me = alive[me]
    for r in range(R):
        pk = replicate_shift(rk, r + 1, AXIS)
        pa = replicate_shift(addr, r + 1, AXIS)
        po = replicate_shift(ops, r + 1, AXIS)
        should = (po > 0) & alive_me          # dead holders skip the append
        one = jax.tree.map(lambda a: a[r, 0], blog)
        one, okr = lg.append(one, pk, pa, po, should)
        ok = ok & replicate_shift(okr, (G - (r + 1)) % G, AXIS)
        nrep = nrep + replicate_shift(
            (should & okr).astype(I32), (G - (r + 1)) % G, AXIS)
        blog = jax.tree.map(lambda full, v, r=r: full.at[r, 0].set(v),
                            blog, one)
    for r in range(R):
        mine_as_backup = valid & (rg == (me - r - 1) % G) & (rg != me)
        opsb = jnp.where(mine_as_backup, opcode, 0).astype(jnp.int8)
        one = jax.tree.map(lambda a: a[r, 0], blog)
        one, okb = lg.append(one, rk, addr, opsb, mine_as_backup)
        ok = ok & okb
        ok_local = ok_local & okb
        nrep = nrep + (mine_as_backup & okb).astype(I32)
        blog = jax.tree.map(lambda full, v, r=r: full.at[r, 0].set(v),
                            blog, one)
    if R >= 2:
        ops0 = jnp.where(valid & (rg == (me - 1) % G) & (rg != me),
                         opcode, 0).astype(jnp.int8)
        fk = replicate_shift(rk, 1, AXIS)
        fa = replicate_shift(addr, 1, AXIS)
        fo = replicate_shift(ops0, 1, AXIS)
        fshould = (fo > 0) & alive_me
        one = jax.tree.map(lambda a: a[1, 0], blog)
        one, okf = lg.append(one, fk, fa, fo, fshould)
        ok = ok & replicate_shift(okf, (G - 1) % G, AXIS)
        nrep = nrep + replicate_shift(
            (fshould & okf).astype(I32), (G - 1) % G, AXIS)
        blog = jax.tree.map(lambda full, v: full.at[1, 0].set(v), blog, one)
    return blog, ok, nrep, ok_local


def _index_probe(cfg, store: KVStore, rk, me, G):
    """The fused index probe (hash chain walk + per-replica-slot backup
    probe in one kernel-dispatch call): the hash table answers lanes I
    own as true primary; for each replica slot I hold, the backup side
    consults its PENDING log first (newest wins), then the sorted
    replica — lane i is answered by replica r iff I hold replica r of
    lane i's owner group.  Returns (addr_p, found_p, acc_p, addr_b,
    found_b, acc_b); the caller combines the pair with its own
    ``am_primary`` mask."""
    R = store.blog.tail.shape[0]
    og = owner_group(rk, G)
    rep_sel = jnp.stack(
        [((me - r - 1) % G == og).astype(I32) for r in range(R)], axis=1)
    srt = jax.tree.map(lambda a: a[:, 0], store.bsorted)
    blg = jax.tree.map(lambda a: a[:, 0], store.blog)
    return kops.group_probe(cfg, _sq(store.hash), srt, blg, rk, rep_sel)


def _delete_body(cfg, G, capacity, store: KVStore, keys, valid,
                 degraded: bool):
    """Distributed DELETE: tombstone through primary log -> backup logs ->
    hash delete, mirroring _put_body minus the data-shard write; the
    value slot is freed immediately (the paper's data-server GC) — queued
    for the gc op when it lives on another shard.  The tombstones compact
    out of the sorted replicas at apply time.

    ``degraded`` is the compile-time analogue of the local layer's static
    primary_alive hint: with every server alive all requests land on true
    primaries, so the healthy variant skips the replica probe entirely;
    the backend picks the variant from its host-side liveness view."""
    me = jax.lax.axis_index(AXIS)
    bufs, slot, ok_route = _route_to_owner(store, keys, valid, G, capacity)
    recv = exchange(bufs, AXIS)
    rk, rg = recv["k"], recv["g"]
    # severed server: delivered lanes dropped un-acked (see _put_body)
    valid = (rg >= 0) & ~store.sever[me]
    addr = jnp.full(rk.shape, -1, I32)
    am_primary = rg == me
    data = store.data
    dcap = data.vals.shape[1]
    if degraded:
        # existence check BEFORE this batch's tombstones land: the
        # temporary primary consults its replica + pending log, so DELETE
        # reports found honestly even while the true primary is down
        old_a, old_f, _, addr_b, found_b, _ = _index_probe(
            cfg, store, rk, me, G)
        old_a = jnp.where(am_primary, old_a, addr_b)
        old_f = jnp.where(am_primary, old_f, found_b)
    else:
        old_a, old_f, _ = kops.probe(cfg, _sq(store.hash), rk)
        found_b = jnp.zeros(rk.shape, bool)   # no degraded lanes exist
    # free-queue push-back BEFORE the tombstone lands: a delete whose
    # value slot lives on another shard (or a dead one) must queue its
    # free — no room means the lane is nacked for a client retry, so the
    # free can never be silently dropped.  A nacked winner takes its
    # whole duplicate-key group with it (same rule as put's
    # spread_winner_addr): otherwise a loser lane would be re-elected
    # winner by the post-gate dedupe and append its free to the very
    # queue that had no room
    winner0 = dp.winner_mask(rk, valid)
    deff_me = data.alive[me] & ~data.sever[me]   # effective data liveness
    may_queue = (winner0 & old_f & (old_a >= 0)
                 & ~((old_a // dcap == me) & deff_me))
    bad = may_queue & ~_fq_pregate(data, may_queue)
    same = (rk[None, :] == rk[:, None]) & valid[None, :] & valid[:, None]
    valid = valid & ~(same & bad[None, :]).any(axis=1)
    ops = jnp.where(valid & am_primary, six.OP_DEL, 0).astype(jnp.int8)
    plog, ok_p = lg.append(_sq(store.plog), rk, addr, ops,
                           valid & am_primary)
    plog = plog._replace(applied=plog.tail)
    new_hash, found = hix.delete(_sq(store.hash), rk, cfg,
                                 valid & am_primary)
    blog, ok_rep, nrep, ok_loc = _replicate_logs(
        store.blog, store.alive & ~store.sever, rk, addr, ops, valid, rg,
        me, G, six.OP_DEL)
    # data-server GC, commit-gated (winner-deduped so a double-delete in
    # one batch frees exactly once): a primary lane frees once the hash
    # tombstoned the entry — the slot is unreferenced from that moment,
    # whatever the replication ack says; a temporary-primary lane frees
    # once MY pending log recorded the tombstone — the one predicate the
    # retry's probe consults, so the free fires exactly once whether the
    # wider replication acked or not
    gate = jnp.where(am_primary, found, ok_loc & old_f)
    freed = dp.winner_mask(rk, valid) & gate & (old_a >= 0)
    free_local = freed & (old_a // dcap == me) & deff_me
    used = dp.free_slots(data.used[0], old_a % dcap, free_local)
    freeq, fq_acc = _queue_remote_frees(data, rk, old_a,
                                        freed & ~free_local)
    fq_spill = data.fq_spill + (
        freed & ~free_local & ~fq_acc).sum().astype(I32)
    ok_req = (valid & ok_rep
              & ((am_primary & ok_p) | ~am_primary)).astype(I32)
    found_req = jnp.where(am_primary, found, found_b & valid).astype(I32)
    ret = route_return({"ok": ok_req, "found": found_req, "rep": nrep},
                       slot, AXIS)
    new_store = _bump_hb(store._replace(
        hash=_ex(store.hash, new_hash), plog=_ex(store.plog, plog),
        blog=blog, data=data._replace(used=data.used.at[0].set(used),
                                      freeq=freeq, fq_spill=fq_spill)))
    return (new_store, ret["ok"].astype(bool) & ok_route,
            ret["found"].astype(bool), ret["rep"])


def _get_body(cfg, G, capacity, store: KVStore, keys, valid):
    me = jax.lax.axis_index(AXIS)
    dest_g = owner_group(keys, G)
    dest = jax.vmap(lambda g: _first_alive_holder(g, store.alive))(dest_g)
    dest = jnp.where(valid, dest, G)   # padding lanes: no capacity consumed
    bufs, slot, ok_route = route_build(
        dest, {"k": (keys, key_inf(keys.dtype))}, G, capacity)
    recv = exchange(bufs, AXIS)
    rk = recv["k"]
    # primary path (one-sided hash probe) + backup path (pending log +
    # sorted replica, per replica slot) in ONE fused dispatch call
    addr_p, found_p, acc_p, addr_b, found_b, acc_b = _index_probe(
        cfg, store, rk, me, G)
    am_primary = owner_group(rk, G) == me
    addr = jnp.where(am_primary, addr_p, addr_b)
    found = jnp.where(am_primary, found_p, found_b)
    acc = jnp.where(am_primary, acc_p, acc_b)
    # --- value gather: one-sided read from the LOCAL data shard ---------
    # a severed data server's bytes are gone: flag its addresses for the
    # second-hop fetch, which fails over to a surviving mirror per-op
    dcap = store.data.vals.shape[1]
    val_ok = (found & (addr // dcap == me) & store.data.alive[me]
              & ~store.data.sever[me])
    local_slot = jnp.where(val_ok, addr % dcap, dcap)
    vals = jnp.concatenate(
        [store.data.vals[0], jnp.zeros((1,) + store.data.vals.shape[2:],
                                       I32)]
    )[jnp.clip(local_slot, 0, dcap)]
    # remote addr (value written on a different shard during a degraded
    # write, or this shard's data server masked dead): flagged
    # val_ok=False for a second-hop _fetch_body read (paper: the client
    # reads the value from the data server given the address).
    # A severed (crashed-but-undetected) server answers nothing: its
    # lanes come back srv=0 and the client retries them as un-routed
    # (the RPC timeout) until the lease detector demotes the device.
    srv = jnp.where(store.sever[me], jnp.zeros(rk.shape, I32),
                    jnp.ones(rk.shape, I32))
    back = route_return({"addr": addr, "found": found.astype(I32),
                         "acc": acc, "val": vals,
                         "vok": val_ok.astype(I32), "srv": srv}, slot, AXIS)
    # ok_route is reported separately from found: an unrouted lane (queue
    # full) is a push-back the client retries, not a miss
    routed = ok_route & back["srv"].astype(bool)
    return (back["addr"], back["found"].astype(bool) & routed,
            back["acc"], back["val"], routed,
            back["vok"].astype(bool))


def _fetch_body(G, capacity, store: KVStore, addrs, valid):
    """Second-hop value read: route each request to the first LIVE data
    holder of the shard owning its address — the shard itself, else a
    device hosting one of its mirror copies — and gather the value: the
    paper's client-side one-sided READ from the data server.  The data
    servers are a separate failure domain from the index servers (paper
    §2), so a fetch is answered even when the device's INDEX state is
    masked dead, and the mirrors answer when the DATA server is — masked
    OR severed: the failover keys on effective liveness, so mirror-served
    reads start the moment the data server crashes, ahead of the slower
    lease demotion.  Returns the store too (the fetch round renews the
    answering data servers' heartbeats)."""
    data = store.data
    dcap = data.vals.shape[1]
    Rv = data.mirror.shape[0]
    deff = data.alive & ~data.sever
    shard = jnp.where(addrs >= 0, addrs // dcap, 0)
    dest, servable = jax.vmap(
        lambda s: _first_alive_data_holder(s, deff, Rv))(shard)
    dest = jnp.where(valid & (addrs >= 0) & servable, dest, G)
    bufs, slot, ok_route = route_build(dest, {"a": (addrs, -1)}, G, capacity)
    recv = exchange(bufs, AXIS)
    ra = recv["a"]
    me = jax.lax.axis_index(AXIS)
    rs = jnp.where(ra >= 0, ra // dcap, G)
    lslot = jnp.where(ra >= 0, ra % dcap, dcap)
    pad = lambda a: jnp.concatenate(
        [a, jnp.zeros((1,) + a.shape[1:], a.dtype)])
    vals = pad(data.vals[0])[jnp.clip(lslot, 0, dcap)]
    taken = rs == me
    for r in range(Rv):
        sel = (rs == (me - r - 1) % G) & ~taken
        mv = pad(data.mirror[r, 0])[jnp.clip(lslot, 0, dcap)]
        vals = jnp.where(sel[:, None], mv, vals)
        taken = taken | sel
    back = route_return({"val": vals}, slot, AXIS)
    # a lane whose every holder is dead reports un-routed (push-back the
    # client surfaces as routed=False), never a fabricated zero value
    return (_bump_hb(store), back["val"],
            ok_route & (servable | ~valid | (addrs < 0)))


def _gc_body(G, capacity, store: KVStore):
    """One flush round of the pending free queues: route each queued freed
    address to the data shard that owns it, which clears the allocator
    bit.  Frees whose destination shard is masked dead, or that overflow
    the exchange, are re-queued for a later round."""
    data = store.data
    dcap = data.vals.shape[1]
    freeq = _sq(data.freeq)
    B = min(freeq.keys.shape[0], G * capacity)
    k, a, o, freeq = lg.take_pending(freeq, B)
    pend = o > 0
    dest_s = jnp.where(pend & (a >= 0), a // dcap, G)
    deff = data.alive & ~data.sever   # a severed shard's allocator is gone
    deliver = pend & (dest_s < G) & deff[jnp.clip(dest_s, 0, G - 1)]
    dest = jnp.where(deliver, dest_s, G)
    bufs, _, okq = route_build(dest, {"a": (a, -1)}, G, capacity)
    recv = exchange(bufs, AXIS)
    ra = recv["a"]
    used = dp.free_slots(data.used[0],
                         jnp.where(ra >= 0, ra % dcap, dcap), ra >= 0)
    requeue = pend & ~(deliver & okq)
    # re-queueing can't overflow (the round took out at least as many
    # entries as it puts back), but any rejection is counted so a drop
    # could never pass the audit silently
    freeq, okr = lg.append(freeq, k, a,
                           jnp.where(requeue, 1, 0).astype(jnp.int8),
                           requeue)
    fq_spill = data.fq_spill + (requeue & ~okr).sum().astype(I32)
    return _bump_hb(store._replace(data=data._replace(
        used=data.used.at[0].set(used), freeq=_ex(data.freeq, freeq),
        fq_spill=fq_spill)))


def _apply_body(cfg, batch, store: KVStore):
    blog = store.blog
    bsorted = store.bsorted
    for r in range(store.blog.tail.shape[0]):
        one_log = jax.tree.map(lambda a: a[r, 0], blog)
        one_srt = jax.tree.map(lambda a: a[r, 0], bsorted)
        keys, addrs, ops, one_log = lg.take_pending(one_log, batch)
        one_srt = kops.merge(cfg, one_srt, keys, addrs, ops)
        blog = jax.tree.map(lambda f, v, r=r: f.at[r, 0].set(v), blog, one_log)
        bsorted = jax.tree.map(lambda f, v, r=r: f.at[r, 0].set(v),
                               bsorted, one_srt)
    return _bump_hb(store._replace(blog=blog, bsorted=bsorted))


def _tick_body(store: KVStore):
    """Heartbeat-only round: lets read-only traffic (GET/fetch) age the
    leases without mutating index state."""
    return _bump_hb(store)


def _scan_body(cfg, G, limit, store: KVStore, lo, hi):
    me = jax.lax.axis_index(AXIS)
    # drain my replicas, then range-query the ones I should serve.  The
    # ring bounds pending entries by log_capacity, so the round bound
    # guarantees a COMPLETE drain (SCAN serializability); the while_loop
    # exits as soon as this device's logs are empty, so a mostly-drained
    # store pays one merge round, not log_capacity/batch of them.  (No
    # collectives in the body, so per-device trip counts are safe.)
    rounds = max(1, -(-cfg.log_capacity // cfg.async_apply_batch))

    def _pending(st):
        return jnp.max(st.blog.tail - st.blog.applied)

    st, _ = jax.lax.while_loop(
        lambda c: (c[1] < rounds) & (_pending(c[0]) > 0),
        lambda c: (_apply_body(cfg, cfg.async_apply_batch, c[0]), c[1] + 1),
        (store, jnp.int32(0)))
    outs_k, outs_a = [], []
    # effective liveness: a severed holder cannot serve (its replica was
    # destroyed in the crash), and duty falls through to the next replica
    # immediately — the per-op failover a real scan client gets from an
    # RPC timeout, independent of the slower lease-based demotion
    eff = store.alive & ~store.sever
    for r in range(store.blog.tail.shape[0]):
        srt = jax.tree.map(lambda a: a[r, 0], st.bsorted)
        k, a, n = kops.range_query(cfg, srt, lo[0], hi[0], limit)
        g = (me - r - 1) % G
        # serve replica r of group g iff I'm alive and EVERY
        # lower-replica holder (devices g+1 .. g+r) is dead — exactly
        # one live holder serves whatever the dead/alive pattern (with
        # R >= 3 an alive-dead-alive ladder must not double-serve)
        prev_ok = jnp.zeros((), bool)
        for rp in range(r):
            prev_ok = prev_ok | eff[(g + rp + 1) % G]
        serve = eff[me] & ~prev_ok
        k = jnp.where(serve, k, key_inf(k.dtype))
        a = jnp.where(serve, a, -1)
        outs_k.append(k)
        outs_a.append(a)
    mk = jnp.stack(outs_k)          # [R, limit]
    ma = jnp.stack(outs_a)
    allk = jax.lax.all_gather(mk, AXIS).reshape(-1)   # [G*R*limit]
    alla = jax.lax.all_gather(ma, AXIS).reshape(-1)
    order = jnp.argsort(allk)
    # scan-completeness contract: group g is COVERED iff at least one of
    # its R holders is effective-alive (scans are backup-served; the
    # primary's hash cannot answer a range query).  A group with zero
    # live, unsevered holders was silently absent from the merge above —
    # the honest flag lets the client retry/report instead (eff is
    # replicated, so every device computes the identical mask)
    gidx = jnp.arange(G)
    covered = jnp.zeros((G,), bool)
    for r in range(store.blog.tail.shape[0]):
        covered = covered | eff[(gidx + r + 1) % G]
    return allk[order][:limit], alla[order][:limit], covered, _bump_hb(st)


# ---------------------------------------------------------------------------
# Public API (jit + shard_map wrappers)
# ---------------------------------------------------------------------------
def _smap(mesh, f, in_specs, out_specs):
    from repro.sharding.smap import shard_map
    return jax.jit(shard_map(f, mesh, in_specs, out_specs))


@functools.lru_cache(maxsize=32)
def make_ops(mesh, cfg, capacity_q: int = 64, scan_limit: int = 128):
    """Build the jitted distributed ops for a mesh.

    put(st, keys, vals, valid)  -> (st, ok, addrs, nrep)
    put_degraded(...)           -> as put, plus the old-slot replica probe
                                   at temporary primaries and the one-hop
                                   value displacement off dead data shards
                                   (use while any server is masked dead)
    get(st, keys, valid)        -> (addrs, found, accesses, vals, routed,
                                    val_ok)
    fetch(st, addrs, valid)     -> (st, vals, routed)  second-hop value
                                   read (returns the store: the round
                                   renews data-server heartbeats)
    delete(st, keys, valid)     -> (st, ok, found, nrep)
    delete_degraded(...)        -> as delete, plus the replica probe that
                                   answers found at a temporary primary
                                   (use while any server is masked dead)
    apply(st)                   -> st
    gc(st)                      -> st   one free-queue flush round
    scan(st, lo, hi)            -> (keys, addrs, covered, st) —
                                   covered[g] False when group g had no
                                   live, unsevered holder to serve it
                                   (the scan-completeness contract)
    tick(st)                    -> st   heartbeat-only round: read-heavy
                                   clients age their leases without a
                                   mutating op in flight
    """
    G = mesh.devices.size
    S = _specs()

    put, put_degraded = (
        _smap(mesh,
              lambda st, k, v, m, d=d: _put_body(cfg, G, capacity_q,
                                                 st, k, v, m, d),
              (S, P(AXIS), P(AXIS), P(AXIS)),
              (S, P(AXIS), P(AXIS), P(AXIS)))
        for d in (False, True))
    get = _smap(mesh, lambda st, k, m: _get_body(cfg, G, capacity_q, st, k, m),
                (S, P(AXIS), P(AXIS)),
                (P(AXIS), P(AXIS), P(AXIS), P(AXIS), P(AXIS), P(AXIS)))
    fetch = _smap(mesh,
                  lambda st, a, m: _fetch_body(G, capacity_q, st, a, m),
                  (S, P(AXIS), P(AXIS)), (S, P(AXIS), P(AXIS)))
    delete, delete_degraded = (
        _smap(mesh,
              lambda st, k, m, d=d: _delete_body(cfg, G, capacity_q,
                                                 st, k, m, d),
              (S, P(AXIS), P(AXIS)),
              (S, P(AXIS), P(AXIS), P(AXIS)))
        for d in (False, True))
    apply_async = _smap(mesh,
                        lambda st: _apply_body(cfg, cfg.async_apply_batch, st),
                        (S,), S)
    gc = _smap(mesh, lambda st: _gc_body(G, capacity_q, st), (S,), S)
    scan = _smap(mesh, lambda st, lo, hi: _scan_body(cfg, G, scan_limit,
                                                     st, lo, hi),
                 (S, P(AXIS), P(AXIS)), (P(), P(), P(), S))
    tick = _smap(mesh, _tick_body, (S,), S)
    return {"put": put, "put_degraded": put_degraded, "get": get,
            "fetch": fetch, "delete": delete,
            "delete_degraded": delete_degraded, "apply": apply_async,
            "gc": gc, "scan": scan, "tick": tick}


def device_counters(store: KVStore) -> dict:
    """Surface the store's device-resident counters as host ints for the
    telemetry snapshot: live servers per plane, heartbeat totals, the
    worst backup log's pending depth, plus the value plane's counters
    (``fq_spill``, free-queue occupancy).  Called only at snapshot time
    (``client.metrics()``), never from an op body — telemetry adds no
    device syncs to the hot path."""
    alive, hb, pending = jax.device_get(
        (store.alive, store.hb, store.blog.tail - store.blog.applied))
    import numpy as np
    out = {
        "live_index_servers": int(np.asarray(alive).sum()),
        "index_heartbeats": int(np.asarray(hb).sum()),
        "pending_log_ops": int(np.asarray(pending).max()),
    }
    out.update(dp.device_counters(store.data))
    return out


# ---------------------------------------------------------------------------
# Failure & recovery protocol (paper §4.3, host-side control plane)
# ---------------------------------------------------------------------------
def _wipe_index_state(store: KVStore, dev: int) -> KVStore:
    """Destroy the index state device ``dev`` held — the hash table +
    primary log of group ``dev`` and every sorted replica + backup log
    hosted on ``dev`` (the crash's data loss; the data shard survives:
    data servers are a separate failure domain, paper §2)."""
    INF = key_inf(store.bsorted.keys.dtype)
    h, s = store.hash, store.bsorted
    p_empty = lg.clear(jax.tree.map(lambda a: a[dev], store.plog))
    b_empty = lg.clear(jax.tree.map(lambda a: a[:, dev], store.blog))
    return store._replace(
        hash=hix.HashIndex(
            sig=h.sig.at[dev].set(0), fp=h.fp.at[dev].set(0),
            addr=h.addr.at[dev].set(-1), fill=h.fill.at[dev].set(0)),
        plog=jax.tree.map(lambda f, v: f.at[dev].set(v), store.plog,
                          p_empty),
        bsorted=six.SortedIndex(
            keys=s.keys.at[:, dev].set(INF),
            addrs=s.addrs.at[:, dev].set(-1),
            size=s.size.at[:, dev].set(0)),
        blog=jax.tree.map(lambda f, v: f.at[:, dev].set(v), store.blog,
                          b_empty))


def fail_server(store: KVStore, dev: int, wipe: bool = True) -> KVStore:
    """ORACLE kill switch: mask device ``dev``'s INDEX server dead with
    the client told immediately.  ``wipe`` (default) also destroys the
    index state it held, so recovery MUST rebuild from surviving copies
    (the honest failure model; fail_data_server is the data plane's own
    kill switch).  For failures the client must DISCOVER via its leases,
    use ``sever_server`` instead."""
    store = store._replace(alive=store.alive.at[dev].set(False))
    return _wipe_index_state(store, dev) if wipe else store


def sever_server(store: KVStore, dev: int, wipe: bool = True) -> KVStore:
    """Crash device ``dev``'s index server WITHOUT telling the client:
    its index state is destroyed (``wipe``) and its heartbeats stop, but
    ``alive`` — the client's routing view — still says up.  Requests
    delivered there are dropped un-acked (RPC timeouts the client
    retries) until the client's lease detector notices the stalled
    heartbeat counter and demotes the device to degraded routing — the
    paper's §5 failure-detection story, with no oracle fail_server
    call anywhere."""
    store = store._replace(sever=store.sever.at[dev].set(True))
    return _wipe_index_state(store, dev) if wipe else store




def fail_data_server(store: KVStore, dev: int, wipe: bool = True) -> KVStore:
    """Mask device ``dev``'s DATA server dead (see data_plane.py)."""
    return dp.fail_data_server(store, dev, wipe)


def sever_data_server(store: KVStore, dev: int,
                      wipe: bool = True) -> KVStore:
    """Crash device ``dev``'s DATA server without telling the client —
    the value plane's lease-detection kill switch (see data_plane.py)."""
    return dp.sever_data_server(store, dev, wipe)


def recover_data_server(store: KVStore, dev: int, cfg,
                        apply_fn=None) -> KVStore:
    """Rebuild device ``dev``'s data shard from its mirrors and mark-sweep
    the allocator (see data_plane.py); ``apply_fn`` turns the mark-sweep's
    log barrier into incremental shard_map'd catch-up rounds."""
    return dp.recover_data_server(store, dev, cfg, apply_fn)


def migrate_values(store: KVStore, cfg, apply_fn=None):
    """Background value migration: move degraded-write strays back to
    their owner group's shard and patch the index addresses, restoring
    one-RTT GETs (see data_plane.py).  ``apply_fn`` (the mesh's jitted
    apply op) turns the pass's log barrier into incremental shard_map'd
    catch-up rounds.  Returns (store, n_moved)."""
    return dp.migrate_values(store, cfg, owner_group, apply_fn)


# the shared eager drain primitive (one home for the semantics)
_drain_one = dp.drain_pair


def _set_slice(tree, val, idx):
    return jax.tree.map(lambda f, v: f.at[idx].set(v), tree, val)


def _fresh_hash_like(hs) -> hix.HashIndex:
    return hix.HashIndex(sig=jnp.zeros_like(hs.sig),
                         fp=jnp.zeros_like(hs.fp),
                         addr=jnp.full_like(hs.addr, -1),
                         fill=jnp.zeros_like(hs.fill))


def _hash_from_items(hs_like, keys, addrs, cfg):
    """Fresh hash table holding exactly the given host-side items."""
    import numpy as np

    from repro.core.hashing import pad_pow2
    kp, vm = pad_pow2(keys, 0)
    ap, _ = pad_pow2(np.asarray(addrs, np.int32), -1)
    new_hash, _ = hix.insert(_fresh_hash_like(hs_like), kp, ap, cfg, vm)
    return new_hash


def _sorted_from_items(srt_like, keys, addrs):
    """Fresh sorted replica holding exactly the given host-side items."""
    import numpy as np

    cap = int(srt_like.keys.shape[0])
    kd = np.asarray(srt_like.keys).dtype
    order = np.argsort(np.asarray(keys, kd), kind="stable")
    n = len(order)
    ks = np.full((cap,), np.iinfo(kd).max, kd)
    ads = np.full((cap,), -1, np.int32)
    ks[:n] = np.asarray(keys, kd)[order]
    ads[:n] = np.asarray(addrs, np.int32)[order]
    return six.SortedIndex(keys=jnp.asarray(ks), addrs=jnp.asarray(ads),
                           size=jnp.asarray(n, I32))


def _group_authority_items(store: KVStore, cfg, g: int, eff):
    """Host-side (keys, addrs) of group ``g`` from its best surviving
    authority: the primary's hash (keys fetched from the data items —
    the paper's rebuild-from-data), else a live drained sorted replica,
    else the data-plane slot scan.  Raises RecoveryError when none of
    the three can answer."""
    import numpy as np

    G = int(store.alive.shape[0])
    R = int(store.blog.tail.shape[0])
    if eff[g]:
        hs = jax.tree.map(lambda a: a[g], store.hash)
        vm = np.asarray(hix.valid_mask(hs))
        addrs = np.asarray(hs.addr)[vm]
        try:
            keys = dp.keys_for_addrs(store, addrs)
        except dp.RecoveryError as e:
            raise dp.RecoveryError(
                g, ["hash + data-plane keys"] + e.searched, e.blockers)
        return keys, addrs.astype(np.int32)
    for r in range(R):
        h = (g + r + 1) % G
        if not eff[h]:
            continue
        srt = jax.tree.map(lambda a: a[r, h], store.bsorted)
        blog = jax.tree.map(lambda a: a[r, h], store.blog)
        srt, _ = _drain_one(srt, blog, cfg)
        keys, addrs, valid = six.items(srt)
        v = np.asarray(valid)
        return np.asarray(keys)[v], np.asarray(addrs)[v]
    return dp.group_items_from_data(store, cfg, g, owner_group)


def recover_server(store: KVStore, dev: int, cfg,
                   online: bool = True) -> KVStore:
    """Recover device ``dev``'s index server from surviving copies
    (host-side control plane; eager, not shard_map'd):

      1. rebuild group ``dev``'s hash table from the first live sorted
         replica of that group — the paper's hash-from-skiplist rebuild;
      2. re-clone every sorted replica + backup log ``dev`` hosts from a
         surviving copy of the same group (skiplist-from-replica);
      3. clear a severed heartbeat and mark ``dev`` alive again.

    ``online`` (default) clones SNAPSHOTS — the source replica is NOT
    drained first; its pending UpdateLog delta is cloned alongside and
    streams into the rebuilt replicas through the ordinary incremental
    ``apply`` op while foreground PUT/GET/SCAN traffic continues.  The
    hash (synchronous by contract) is built from the snapshot plus a
    replay of the cloned pending window.  ``online=False`` keeps the
    stop-the-world drain-then-clone for comparison (fig13's
    catch-up-vs-stop-the-world mode).

    Multi-failure fallback: a group with no live sorted replica rebuilds
    from its primary's hash + the keys stored with the data items
    (paper: the skiplist rebuild fetches the keys from the data
    servers), else from a full data-plane slot scan; RecoveryError (with
    the searched sources and actionable blockers) is raised only when
    truly no copy exists."""
    import numpy as np

    G = int(store.alive.shape[0])
    R = int(store.blog.tail.shape[0])
    alive = np.asarray(store.alive)
    sever = np.asarray(store.sever)
    if bool(alive[dev]) and not bool(sever[dev]):
        return store
    # the recovered server heartbeats again; it stays routed-dead until
    # the rebuild below completes
    store = store._replace(sever=store.sever.at[dev].set(False),
                           alive=store.alive.at[dev].set(False))
    if G == 1:
        # single-server store: nothing was wiped (no surviving copy could
        # exist), recovery is just the liveness flip
        return store._replace(alive=store.alive.at[dev].set(True))
    eff = alive & ~sever
    eff[dev] = False

    def first_live_holder(group, exclude):
        for r in range(R):
            h = (group + r + 1) % G
            if h != exclude and eff[h]:
                return r, h
        return None

    # -- 1. hash rebuild for group ``dev`` --------------------------------
    src = first_live_holder(dev, dev)
    hs_like = jax.tree.map(lambda a: a[dev], store.hash)
    if src is not None:
        r, h = src
        srt = jax.tree.map(lambda a: a[r, h], store.bsorted)
        blog = jax.tree.map(lambda a: a[r, h], store.blog)
        if not online:
            srt, blog = _drain_one(srt, blog, cfg)
            store = store._replace(
                bsorted=_set_slice(store.bsorted, srt, (r, h)),
                blog=_set_slice(store.blog, blog, (r, h)))
        keys, addrs, valid = six.items(srt)
        # the valid mask keeps empty sorted-array slots out of the table
        # entirely (no appended-then-tombstoned junk eating chain room)
        new_hash, _ = hix.insert(_fresh_hash_like(hs_like), keys, addrs,
                                 cfg, valid)
        if online:
            new_hash = hix.replay_pending(new_hash, blog, cfg)
    else:
        # every replica holder dead: fall back to the data plane — the
        # keys stored with the values reconstruct (key, addr) for any
        # group (raises RecoveryError with blockers when it can't)
        k_np, a_np = dp.group_items_from_data(store, cfg, dev,
                                              owner_group)
        new_hash = _hash_from_items(hs_like, k_np, a_np, cfg)
    store = store._replace(hash=_set_slice(store.hash, new_hash, dev),
                           plog=_set_slice(
                               store.plog,
                               lg.create(store.plog.keys.shape[1],
                                         store.plog.keys.dtype), dev))
    # -- 2. sorted-replica rebuild for each group hosted on ``dev`` -------
    empty_blog = lg.create(store.plog.keys.shape[1],
                           store.plog.keys.dtype)
    for r2 in range(R):
        g = (dev - r2 - 1) % G
        src2 = first_live_holder(g, dev)
        if src2 is not None:
            r3, h3 = src2
            s_srt = jax.tree.map(lambda a: a[r3, h3], store.bsorted)
            s_blog = jax.tree.map(lambda a: a[r3, h3], store.blog)
            if not online:
                s_srt, s_blog = _drain_one(s_srt, s_blog, cfg)
                store = store._replace(
                    bsorted=_set_slice(store.bsorted, s_srt, (r3, h3)),
                    blog=_set_slice(store.blog, s_blog, (r3, h3)))
            # online: the clone carries the source's pending window; the
            # ordinary apply op streams it into BOTH copies identically
            store = store._replace(
                bsorted=_set_slice(store.bsorted, s_srt, (r2, dev)),
                blog=_set_slice(store.blog, s_blog, (r2, dev)))
        else:
            # no live replica of group g anywhere else: rebuild this
            # copy from the group's surviving authority (primary hash +
            # data-plane keys, else the data-plane scan) instead of the
            # old silent skip that left an empty replica serving scans
            k_np, a_np = _group_authority_items(store, cfg, g, eff)
            store = store._replace(
                bsorted=_set_slice(
                    store.bsorted,
                    _sorted_from_items(
                        jax.tree.map(lambda a: a[r2, dev], store.bsorted),
                        k_np, a_np), (r2, dev)),
                blog=_set_slice(store.blog, empty_blog, (r2, dev)))
    return store._replace(alive=store.alive.at[dev].set(True))


def re_replicate(store: KVStore, cfg) -> tuple:
    """Post-recovery re-replication pass (closes the multi-failure
    window): for every group, verify each LIVE holder's sorted replica
    against the group's authority — the primary's hash when alive, else
    the first live replica — and rebuild any copy that diverged, so R
    valid copies exist again before the next failure.  Verification
    drains COPIES (like parity_report): healthy replicas with pending
    catch-up debt compare clean and are left untouched, so the pass does
    not stop the online catch-up.  Returns (store, n_rebuilt)."""
    import numpy as np

    G = int(store.alive.shape[0])
    R = int(store.blog.tail.shape[0])
    eff = np.asarray(store.alive) & ~np.asarray(store.sever)
    rebuilt = 0
    for g in range(G):
        auth = None      # (keys, addrs) fetched lazily on first mismatch
        if eff[g]:
            hs = jax.tree.map(lambda a: a[g], store.hash)
            n_auth = int(hix.n_items(hs))
        else:
            src = None
            for r in range(R):
                h = (g + r + 1) % G
                if eff[h]:
                    src = (r, h)
                    break
            if src is None:
                continue       # nothing to verify against (recover first)
            srt = jax.tree.map(lambda a: a[src[0], src[1]], store.bsorted)
            blog = jax.tree.map(lambda a: a[src[0], src[1]], store.blog)
            srt, _ = _drain_one(srt, blog, cfg)
            keys, addrs, valid = six.items(srt)
            v = np.asarray(valid)
            auth = (np.asarray(keys)[v], np.asarray(addrs)[v])
            n_auth = len(auth[0])
        for r in range(R):
            h = (g + r + 1) % G
            if not eff[h] or (not eff[g] and src == (r, h)):
                continue
            srt = jax.tree.map(lambda a: a[r, h], store.bsorted)
            blog = jax.tree.map(lambda a: a[r, h], store.blog)
            dsrt, _ = _drain_one(srt, blog, cfg)
            keys, addrs, valid = six.items(dsrt)
            v = np.asarray(valid)
            rk, ra = np.asarray(keys)[v], np.asarray(addrs)[v]
            if eff[g]:
                a_h, f_h, _ = kops.probe(cfg, hs, keys)
                okk = (len(rk) == n_auth
                       and bool(np.asarray(f_h | ~valid).all())
                       and bool(np.asarray((a_h == addrs) | ~valid).all()))
            else:
                okk = (len(rk) == n_auth
                       and bool(np.array_equal(rk, auth[0]))
                       and bool(np.array_equal(ra, auth[1])))
            if okk:
                continue
            if auth is None:
                try:
                    auth = _group_authority_items(store, cfg, g, eff)
                except dp.RecoveryError:
                    break      # unverifiable right now (data shard dead)
            store = store._replace(
                bsorted=_set_slice(store.bsorted,
                                   _sorted_from_items(srt, *auth), (r, h)),
                blog=_set_slice(store.blog,
                                lg.create(store.plog.keys.shape[1],
                                          store.plog.keys.dtype), (r, h)))
            rebuilt += 1
    return store, rebuilt


def parity_report(store: KVStore, cfg, apply_fn=None) -> list:
    """Hash/sorted parity + value-slot audit (test/debug helper, eager).
    For every group g and replica r: drain a COPY of the replica, then
    check the replica's live item count equals the hash table's, every
    replica key is found in the hash, and the addresses agree.  A final
    ``value_slots`` entry audits the data plane's slot accounting (every
    live address allocated, nothing orphaned or double-referenced, no
    free-queue spill — see data_plane.value_slot_audit).  Returns a list
    of dicts with an ``agree`` bool; entries carry ``primary_alive`` /
    ``holder_alive`` — TRUE liveness (a severed-but-undetected server
    reports dead: the report is the omniscient test oracle, not the
    client's view) — so a mid-failure caller can restrict the assertion
    to live structures."""
    import numpy as np

    G = int(store.alive.shape[0])
    R = int(store.blog.tail.shape[0])
    alive = np.asarray(store.alive) & ~np.asarray(store.sever)
    out = []
    for g in range(G):
        hs = jax.tree.map(lambda a: a[g], store.hash)
        n_hash = int(hix.n_items(hs))
        for r in range(R):
            h = (g + r + 1) % G
            srt = jax.tree.map(lambda a: a[r, h], store.bsorted)
            blog = jax.tree.map(lambda a: a[r, h], store.blog)
            srt, _ = _drain_one(srt, blog, cfg)
            keys, addrs, valid = six.items(srt)
            n_sorted = int(valid.sum())
            a_h, f_h, _ = kops.probe(cfg, hs, keys)
            found_ok = bool(np.asarray(f_h | ~valid).all())
            addr_ok = bool(np.asarray((a_h == addrs) | ~valid).all())
            out.append({"group": g, "replica": r, "holder": h,
                        "primary_alive": bool(alive[g]),
                        "holder_alive": bool(alive[h]),
                        "n_hash": n_hash, "n_sorted": n_sorted,
                        "agree": (n_hash == n_sorted) and found_ok
                        and addr_ok})
    out.append(dp.value_slot_audit(store, cfg, apply_fn))
    return out
