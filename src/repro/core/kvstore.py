"""HiStore: the distributed key-value store over index groups.

Topology (one group per device; cfg.groups_per_device generalises):
  device g is the PRIMARY server of group g (hash table + primary log + the
  group's data-server shard) and the BACKUP server for groups g-1 (replica
  0) and g-2 (replica 1): backup arrays use the SHIFTED layout — slice
  [r, p] stores replica r of group (p - r - 1) mod G, so placing slice p on
  device p puts every replica on a different failure domain, and log
  replication is a ppermute by r+1 hops.

Ops (all shard_map'd over the 1-D "kv" mesh axis; see verbs.py for the
RDMA-verb mapping):
  put    — route to owner; owner stores the value on its data shard,
           appends its log, pushes the entries to both backup logs
           (ppermute), updates the hash table, acks.
  get    — one-sided: route, owner-side gather-only probe, value gather,
           reverse route.  Primary dead -> the query is routed to a backup
           holder, which consults its pending log + sorted replica.
  delete — route to owner; owner appends a tombstone to its log, pushes it
           to both backup logs (ppermute), tombstones the hash slot, acks.
           The tombstone compacts out of the sorted replicas on apply.
  scan   — backup-side: every device drains and range-queries the replicas
           it holds, results are all_gathered and merged.
  apply_async — one batched log->sorted merge round on every backup.
  fail / recover — failure-mask protocol validation (SPMD devices cannot
           actually vanish; DESIGN.md §Fault tolerance).

All mutating ops take a ``valid`` lane mask so the client can pad request
batches to fixed shapes (DESIGN.md §Client); invalid lanes are routed
nowhere, consume no exchange capacity, and mutate nothing.  External
callers should not call these ops directly — go through
repro.core.client.HiStoreClient, which adds overflow retry, batch padding
and the async-apply policy.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import hash_index as hix
from repro.core import log as lg
from repro.core import sorted_index as six
from repro.core.hashing import fmix32, key_inf
from repro.core.verbs import (exchange, replicate_shift, route_build,
                              route_return)

I32 = jnp.int32
AXIS = "kv"


class KVStore(NamedTuple):
    hash: hix.HashIndex       # leaves [G, ...]
    plog: lg.UpdateLog        # leaves [G, ...]
    bsorted: six.SortedIndex  # leaves [R, G, ...] (shifted layout)
    blog: lg.UpdateLog        # leaves [R, G, ...]
    dvals: jnp.ndarray        # [G, dcap, W] data-server shard
    dfill: jnp.ndarray        # [G]
    alive: jnp.ndarray        # [G] bool (server up)


def create(mesh, capacity_per_group: int, cfg, key_dt=None) -> KVStore:
    G = mesh.devices.size
    R = cfg.n_backups
    rep = lambda t, n: jax.tree.map(
        lambda a: jnp.broadcast_to(a[None], (n,) + a.shape).copy(), t)
    one_hash = hix.create(capacity_per_group, cfg)
    one_plog = lg.create(cfg.log_capacity, key_dt)
    one_sorted = six.create(capacity_per_group, key_dt)
    one_blog = lg.create(cfg.log_capacity, key_dt)
    store = KVStore(
        hash=rep(one_hash, G),
        plog=rep(one_plog, G),
        bsorted=rep(rep(one_sorted, G), R),
        blog=rep(rep(one_blog, G), R),
        dvals=jnp.zeros((G, capacity_per_group, cfg.value_words), I32),
        dfill=jnp.zeros((G,), I32),
        alive=jnp.ones((G,), bool),
    )
    return jax.device_put(store, store_sharding(mesh))


def store_sharding(mesh):
    from jax.sharding import NamedSharding

    # group axis position differs: hash/plog/dvals shard dim0; bsorted/blog
    # shard dim1; alive replicated.
    return KVStore(
        hash=hix.HashIndex(*[NamedSharding(mesh, P(AXIS))] * 4),
        plog=lg.UpdateLog(*[NamedSharding(mesh, P(AXIS))] * 5),
        bsorted=six.SortedIndex(*[NamedSharding(mesh, P(None, AXIS))] * 3),
        blog=lg.UpdateLog(*[NamedSharding(mesh, P(None, AXIS))] * 5),
        dvals=NamedSharding(mesh, P(AXIS)),
        dfill=NamedSharding(mesh, P(AXIS)),
        alive=NamedSharding(mesh, P()),
    )


def _specs():
    return KVStore(
        hash=hix.HashIndex(*[P(AXIS)] * 4),
        plog=lg.UpdateLog(*[P(AXIS)] * 5),
        bsorted=six.SortedIndex(*[P(None, AXIS)] * 3),
        blog=lg.UpdateLog(*[P(None, AXIS)] * 5),
        dvals=P(AXIS),
        dfill=P(AXIS),
        alive=P(),
    )


def owner_group(keys, G: int):
    """Group routing hash — decorrelated from the bucket hash."""
    from repro.core.hashing import key_mix
    h1, h2 = key_mix(keys)
    return (fmix32(h2 ^ jnp.uint32(0xA5A5A5A5)) % jnp.uint32(G)).astype(I32)


def _first_alive_holder(g, alive):
    """Device to contact for group g: primary g, else backup holders."""
    G = alive.shape[0]
    cand = jnp.stack([g % G, (g + 1) % G, (g + 2) % G])
    ok = alive[cand]
    pick = jnp.argmax(ok)          # first alive in priority order
    return cand[pick]


# ---------------------------------------------------------------------------
# shard_map bodies (one device's view; leading group axis is local size 1)
# ---------------------------------------------------------------------------
def _sq(tree):
    return jax.tree.map(lambda a: a[0], tree)


def _ex(tree, val):
    return jax.tree.map(lambda a, v: a.at[0].set(v), tree, val)


def _route_to_owner(store, keys, valid, G, capacity, extra=None):
    """Shared routing prologue of the mutating ops: invalid (padding) lanes
    get an out-of-range destination, so they occupy no exchange capacity
    and arrive nowhere."""
    dest_g = owner_group(keys, G)
    dest = jax.vmap(lambda g: _first_alive_holder(g, store.alive))(dest_g)
    dest = jnp.where(valid, dest, G)
    payloads = {"k": (keys, 0), "g": (jnp.where(valid, dest_g, -1), -1)}
    if extra:
        payloads.update(extra)
    return route_build(dest, payloads, G, capacity)


def _put_body(cfg, G, capacity, store: KVStore, keys, vals, valid):
    me = jax.lax.axis_index(AXIS)
    bufs, slot, ok_route = _route_to_owner(
        store, keys, valid, G, capacity, {"v": (vals, 0)})
    recv = exchange(bufs, AXIS)
    rk, rv, rg = recv["k"], recv["v"], recv["g"]
    valid = rg >= 0
    # --- owner side: store value on the data shard ----------------------
    dvals = store.dvals[0]
    dfill = store.dfill[0]
    n = valid.shape[0]
    off = jnp.cumsum(valid.astype(I32)) - 1
    slot_d = jnp.where(valid, (dfill + off) % dvals.shape[0], dvals.shape[0])
    dvals = dvals.at[slot_d].set(rv, mode="drop")
    new_dfill = dfill + valid.sum().astype(I32)
    addr = jnp.where(valid, me * dvals.shape[0] + slot_d, -1).astype(I32)
    # --- primary log + hash (only if I am the true primary) -------------
    am_primary = rg == me
    ops = jnp.where(valid & am_primary, six.OP_PUT, 0).astype(jnp.int8)
    plog, ok_p = lg.append(_sq(store.plog), rk, addr, ops,
                           valid & am_primary)
    # the hash update is synchronous, so primary-log entries are applied
    # the moment the batch commits; advancing the prefix keeps the ring's
    # pending window from exhausting (entries stay on disk for recovery).
    plog = plog._replace(applied=plog.tail)
    new_hash, ok_h = hix.insert(_sq(store.hash), rk, addr, cfg,
                                valid & am_primary)
    blog, ok_rep = _replicate_logs(store.blog, rk, addr, ops, valid, rg, me,
                                   G, six.OP_PUT)
    ok_req = (valid & ok_rep
              & ((am_primary & ok_p & ok_h) | ~am_primary)).astype(I32)
    back = route_return({"ok": ok_req, "addr": addr}, slot, AXIS)
    new_store = store._replace(
        hash=_ex(store.hash, new_hash), plog=_ex(store.plog, plog),
        blog=blog, dvals=store.dvals.at[0].set(dvals),
        dfill=store.dfill.at[0].set(new_dfill))
    return new_store, back["ok"].astype(bool) & ok_route, back["addr"]


def _replicate_logs(blog, rk, addr, ops, valid, rg, me, G, opcode):
    """Push an owner-side batch of log entries to the backup logs.
    Returns (blog, ok): ok[i] is False when any backup-log append for
    owner-lane i was rejected (ring full) — ppermuted back to the owner so
    the ack can carry the push-back instead of silently losing replicas.

    Healthy path: replicate the primary's entries (``ops``) to the r+1-hop
    backup holders via ppermute.  Degraded path (paper §4.3): requests
    routed to me as a BACKUP holder (primary dead) — I act as temporary
    primary, append to my backup log for that group, and forward
    replica-0 entries one hop to the replica-1 holder."""
    R = blog.tail.shape[0]
    ok = jnp.ones(rk.shape, bool)
    for r in range(R):
        pk = replicate_shift(rk, r + 1, AXIS)
        pa = replicate_shift(addr, r + 1, AXIS)
        po = replicate_shift(ops, r + 1, AXIS)
        one = jax.tree.map(lambda a: a[r, 0], blog)
        one, okr = lg.append(one, pk, pa, po, po > 0)
        ok = ok & replicate_shift(okr, (G - (r + 1)) % G, AXIS)
        blog = jax.tree.map(lambda full, v, r=r: full.at[r, 0].set(v),
                            blog, one)
    for r in range(R):
        mine_as_backup = valid & (rg == (me - r - 1) % G) & (rg != me)
        opsb = jnp.where(mine_as_backup, opcode, 0).astype(jnp.int8)
        one = jax.tree.map(lambda a: a[r, 0], blog)
        one, okb = lg.append(one, rk, addr, opsb, mine_as_backup)
        ok = ok & okb
        blog = jax.tree.map(lambda full, v, r=r: full.at[r, 0].set(v),
                            blog, one)
    if R >= 2:
        ops0 = jnp.where(valid & (rg == (me - 1) % G) & (rg != me),
                         opcode, 0).astype(jnp.int8)
        fk = replicate_shift(rk, 1, AXIS)
        fa = replicate_shift(addr, 1, AXIS)
        fo = replicate_shift(ops0, 1, AXIS)
        one = jax.tree.map(lambda a: a[1, 0], blog)
        one, okf = lg.append(one, fk, fa, fo, fo > 0)
        ok = ok & replicate_shift(okf, (G - 1) % G, AXIS)
        blog = jax.tree.map(lambda full, v: full.at[1, 0].set(v), blog, one)
    return blog, ok


def _delete_body(cfg, G, capacity, store: KVStore, keys, valid):
    """Distributed DELETE: tombstone through primary log -> backup logs ->
    hash delete, mirroring _put_body minus the data-shard write.  The
    tombstones compact out of the sorted replicas at apply time; the data
    slot is reclaimed on rebuild (the paper's data-server GC)."""
    me = jax.lax.axis_index(AXIS)
    bufs, slot, ok_route = _route_to_owner(store, keys, valid, G, capacity)
    recv = exchange(bufs, AXIS)
    rk, rg = recv["k"], recv["g"]
    valid = rg >= 0
    addr = jnp.full(rk.shape, -1, I32)
    am_primary = rg == me
    ops = jnp.where(valid & am_primary, six.OP_DEL, 0).astype(jnp.int8)
    plog, ok_p = lg.append(_sq(store.plog), rk, addr, ops,
                           valid & am_primary)
    plog = plog._replace(applied=plog.tail)
    new_hash, found = hix.delete(_sq(store.hash), rk, cfg,
                                 valid & am_primary)
    blog, ok_rep = _replicate_logs(store.blog, rk, addr, ops, valid, rg, me,
                                   G, six.OP_DEL)
    ok_req = (valid & ok_rep
              & ((am_primary & ok_p) | ~am_primary)).astype(I32)
    # found is only knowable on the primary path; degraded deletes are
    # acked blindly (the tombstone wins at apply time either way)
    found_req = jnp.where(am_primary, found, valid).astype(I32)
    back = route_return({"ok": ok_req, "found": found_req}, slot, AXIS)
    new_store = store._replace(hash=_ex(store.hash, new_hash),
                               plog=_ex(store.plog, plog), blog=blog)
    return (new_store, back["ok"].astype(bool) & ok_route,
            back["found"].astype(bool))


def _get_body(cfg, G, capacity, store: KVStore, keys, valid):
    me = jax.lax.axis_index(AXIS)
    dest_g = owner_group(keys, G)
    dest = jax.vmap(lambda g: _first_alive_holder(g, store.alive))(dest_g)
    dest = jnp.where(valid, dest, G)   # padding lanes: no capacity consumed
    bufs, slot, ok_route = route_build(
        dest, {"k": (keys, key_inf(keys.dtype))}, G, capacity)
    recv = exchange(bufs, AXIS)
    rk = recv["k"]
    # --- primary path: one-sided probe (gathers only) -------------------
    addr_p, found_p, acc_p = hix.lookup(_sq(store.hash), rk, cfg)
    # --- backup path: pending log + sorted replica (per replica slot) ---
    addr_b = jnp.full_like(addr_p, -1)
    found_b = jnp.zeros_like(found_p)
    acc_b = jnp.zeros_like(acc_p)
    for r in range(store.blog.tail.shape[0]):
        srt = jax.tree.map(lambda a: a[r, 0], store.bsorted)
        blog = jax.tree.map(lambda a: a[r, 0], store.blog)
        a_s, f_s, c_s = six.search(srt, rk, cfg.fanout)
        cap_l = blog.keys.shape[0]
        seq = blog.applied + jnp.arange(cap_l)
        idx = seq % cap_l
        pv = seq < blog.tail
        pk = jnp.where(pv, blog.keys[idx], key_inf(blog.keys.dtype))
        m = pk[None, :] == rk[:, None]
        any_m = m.any(axis=1)
        last = (cap_l - 1) - jnp.argmax(m[:, ::-1], axis=1)
        hit_op = jnp.where(any_m, blog.ops[idx][last], 0)
        hit_addr = jnp.where(any_m & (hit_op == six.OP_PUT),
                             blog.addrs[idx][last], -1)
        a_r = jnp.where(any_m, hit_addr, a_s)
        f_r = jnp.where(any_m, hit_op == six.OP_PUT, f_s)
        sel = (me - r - 1) % G == owner_group(rk, G)
        addr_b = jnp.where(sel & ~(found_b > 0), a_r, addr_b)
        found_b = jnp.where(sel, f_r, found_b)
        acc_b = jnp.where(sel, c_s + 1, acc_b)
    am_primary = owner_group(rk, G) == me
    addr = jnp.where(am_primary, addr_p, addr_b)
    found = jnp.where(am_primary, found_p, found_b)
    acc = jnp.where(am_primary, acc_p, acc_b)
    # --- value gather: one-sided read from the LOCAL data shard ---------
    dcap = store.dvals.shape[1]
    local_slot = jnp.where(found & (addr // dcap == me), addr % dcap, dcap)
    vals = jnp.concatenate(
        [store.dvals[0], jnp.zeros((1,) + store.dvals.shape[2:], I32)]
    )[jnp.clip(local_slot, 0, dcap)]
    # remote addr (value written on a different shard during degraded
    # writes): fetch skipped — flagged for a second-hop read (paper: the
    # client reads the value from the data server given the address).
    back = route_return({"addr": addr, "found": found.astype(I32),
                         "acc": acc, "val": vals}, slot, AXIS)
    # ok_route is reported separately from found: an unrouted lane (queue
    # full) is a push-back the client retries, not a miss
    return (back["addr"], back["found"].astype(bool) & ok_route,
            back["acc"], back["val"], ok_route)


def _apply_body(cfg, batch, store: KVStore):
    blog = store.blog
    bsorted = store.bsorted
    for r in range(store.blog.tail.shape[0]):
        one_log = jax.tree.map(lambda a: a[r, 0], blog)
        one_srt = jax.tree.map(lambda a: a[r, 0], bsorted)
        keys, addrs, ops, one_log = lg.take_pending(one_log, batch)
        one_srt = six.merge(one_srt, keys, addrs, ops)
        blog = jax.tree.map(lambda f, v, r=r: f.at[r, 0].set(v), blog, one_log)
        bsorted = jax.tree.map(lambda f, v, r=r: f.at[r, 0].set(v),
                               bsorted, one_srt)
    return store._replace(blog=blog, bsorted=bsorted)


def _scan_body(cfg, G, limit, store: KVStore, lo, hi):
    me = jax.lax.axis_index(AXIS)
    # drain my replicas, then range-query the ones I should serve
    st = store
    for _ in range(4):
        st = _apply_body(cfg, cfg.async_apply_batch, st)
    outs_k, outs_a = [], []
    for r in range(store.blog.tail.shape[0]):
        srt = jax.tree.map(lambda a: a[r, 0], st.bsorted)
        k, a, n = six.range_query(srt, lo[0], hi[0], limit)
        g = (me - r - 1) % G
        # serve replica r of group g iff I'm alive and (r==0 or the r-1
        # holder (device g+r) is dead)
        holder_prev_ok = store.alive[(g + r) % G] if r > 0 else jnp.array(False)
        serve = store.alive[me] & ((r == 0) | ~holder_prev_ok)
        k = jnp.where(serve, k, key_inf(k.dtype))
        a = jnp.where(serve, a, -1)
        outs_k.append(k)
        outs_a.append(a)
    mk = jnp.stack(outs_k)          # [R, limit]
    ma = jnp.stack(outs_a)
    allk = jax.lax.all_gather(mk, AXIS).reshape(-1)   # [G*R*limit]
    alla = jax.lax.all_gather(ma, AXIS).reshape(-1)
    order = jnp.argsort(allk)
    return allk[order][:limit], alla[order][:limit], st


# ---------------------------------------------------------------------------
# Public API (jit + shard_map wrappers)
# ---------------------------------------------------------------------------
def _shard_map(f, mesh, in_specs, out_specs):
    """shard_map across JAX versions: jax.shard_map (>= 0.6, check_vma)
    with a fallback to jax.experimental.shard_map (0.4.x, check_rep)."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map as _sm
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=False)


def _smap(mesh, f, in_specs, out_specs):
    return jax.jit(_shard_map(f, mesh, in_specs, out_specs))


@functools.lru_cache(maxsize=32)
def make_ops(mesh, cfg, capacity_q: int = 64, scan_limit: int = 128):
    """Build the jitted distributed ops for a mesh.

    put(st, keys, vals, valid)  -> (st, ok, addrs)
    get(st, keys, valid)        -> (addrs, found, accesses, vals, routed)
    delete(st, keys, valid)     -> (st, ok, found)
    apply(st)                   -> st
    scan(st, lo, hi)            -> (keys, addrs, st)
    """
    G = mesh.devices.size
    S = _specs()

    put = _smap(mesh,
                lambda st, k, v, m: _put_body(cfg, G, capacity_q, st, k, v, m),
                (S, P(AXIS), P(AXIS), P(AXIS)),
                (S, P(AXIS), P(AXIS)))
    get = _smap(mesh, lambda st, k, m: _get_body(cfg, G, capacity_q, st, k, m),
                (S, P(AXIS), P(AXIS)),
                (P(AXIS), P(AXIS), P(AXIS), P(AXIS), P(AXIS)))
    delete = _smap(mesh,
                   lambda st, k, m: _delete_body(cfg, G, capacity_q, st, k, m),
                   (S, P(AXIS), P(AXIS)), (S, P(AXIS), P(AXIS)))
    apply_async = _smap(mesh,
                        lambda st: _apply_body(cfg, cfg.async_apply_batch, st),
                        (S,), S)
    scan = _smap(mesh, lambda st, lo, hi: _scan_body(cfg, G, scan_limit,
                                                     st, lo, hi),
                 (S, P(AXIS), P(AXIS)), (P(), P(), S))
    return {"put": put, "get": get, "delete": delete, "apply": apply_async,
            "scan": scan}


def fail_server(store: KVStore, dev: int) -> KVStore:
    return store._replace(alive=store.alive.at[dev].set(False))


def recover_server(store: KVStore, dev: int) -> KVStore:
    return store._replace(alive=store.alive.at[dev].set(True))
