"""RDMA-verb analogues on the TPU interconnect (DESIGN.md §Verb mapping).

The paper's communication primitives map onto jax.lax collectives inside
shard_map:

  one-sided READ   -> capacity-routed all_to_all pair: the client computes
                      the remote address locally (hash), the owner shard
                      executes only gathers (no "server CPU" logic beyond
                      address arithmetic — the DMA analogue), results come
                      back on the reverse all_to_all.  2 hops = 1 RTT.
  two-sided SEND   -> the same routed all_to_all, but the owner runs real
                      per-request logic (log append, index update) before
                      acking — the RPC analogue.
  log replication  -> collective_permute to the next R devices (primary ->
                      backups), matching the shifted backup layout.

Routing is capacity-based (fixed [D, c] exchange buffers, the standard TPU
static-shape dispatch, same machinery as MoE token routing): overflow
entries are reported to the caller, which retries — the analogue of an RPC
queue-full push-back.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.sharding.smap import axis_size  # noqa: F401  (re-export)

I32 = jnp.int32


def route_build(dest, payloads: dict, n_dev: int, capacity: int):
    """Pack per-query payload rows into a [n_dev * capacity, ...] send
    buffer bucketed by destination.  Returns (buffers, slot, ok) where
    ``slot`` is each query's position in the exchange buffer (kept by the
    sender for return routing) and ok=False marks capacity overflow."""
    q = dest.shape[0]
    pos = jnp.arange(q)
    order = jnp.lexsort((pos, dest))
    d_s = dest[order]
    start = jnp.searchsorted(d_s, d_s)
    rank = jnp.arange(q) - start
    ok_s = rank < capacity
    slot_s = jnp.where(ok_s, d_s * capacity + rank, n_dev * capacity)
    bufs = {}
    for name, (arr, fill) in payloads.items():
        shape = (n_dev * capacity,) + arr.shape[1:]
        buf = jnp.full(shape, fill, arr.dtype)
        bufs[name] = buf.at[slot_s].set(arr[order], mode="drop")
    slot = jnp.full((q,), n_dev * capacity, I32).at[order].set(
        slot_s.astype(I32))
    ok = jnp.zeros((q,), bool).at[order].set(ok_s)
    return bufs, slot, ok


def exchange(bufs: dict, axis: str):
    """all_to_all a dict of [n_dev * c, ...] buffers (forward or reverse)."""
    out = {}
    for name, arr in bufs.items():
        n_dev = axis_size(axis)
        c = arr.shape[0] // n_dev
        out[name] = jax.lax.all_to_all(
            arr.reshape((n_dev, c) + arr.shape[1:]), axis,
            split_axis=0, concat_axis=0).reshape(arr.shape)
    return out


def route_return(result_bufs: dict, slot, axis: str):
    """Send per-request results back and gather each query's answer."""
    back = exchange(result_bufs, axis)
    out = {}
    for name, arr in back.items():
        pad = jnp.zeros((1,) + arr.shape[1:], arr.dtype)
        padded = jnp.concatenate([arr, pad], axis=0)
        out[name] = padded[jnp.clip(slot, 0, arr.shape[0])]
    return out


def replicate_shift(x, shift: int, axis: str):
    """collective_permute by +shift along the ring: primary d -> backup
    holder d+shift (the paper's primary->backup log push).  ``x`` may be
    a pytree (ppermute accepts one natively — a dict of payload arrays
    travels as one logical message: value mirroring, degraded-write
    displacement)."""
    n = axis_size(axis)
    perm = [(i, (i + shift) % n) for i in range(n)]
    return jax.lax.ppermute(x, axis, perm)
