"""HiStoreClient: one typed front door over the hybrid index.

The paper's client sees a single KV interface (GET/PUT/DELETE/SCAN) no
matter whether a request lands on the hash table, a skiplist replica, or a
degraded backup path.  This module is that front door for the repro:

    client = HiStoreClient(LocalBackend(4096, cfg))          # one node
    client = HiStoreClient(DistributedBackend(mesh, cfg))    # shard_map'd

    res = client.put(keys, values)       # PutResult(ok, addrs, retries)
    res = client.get(keys)               # GetResult(addrs, found, acc, vals)
    res = client.delete(keys)            # DeleteResult(ok, found, retries)
    res = client.scan(lo, hi, limit)     # ScanResult(keys, addrs, count)

Responsibilities the old per-layer surfaces pushed onto every caller:

  * fixed-shape batching — requests are padded to power-of-two batch sizes
    (and a multiple of the device count for the distributed backend), so
    the jitted ops stop recompiling per batch size; oversize requests are
    split into ``max_batch`` chunks;
  * overflow push-back — capacity overflow (exchange-buffer ok=False, the
    paper's RPC queue-full) becomes a bounded client-side retry loop with
    async-apply drains in between, instead of a silently-surfaced flag;
  * async-apply scheduling — the backups' log->sorted merges run every
    ``apply_every_n_ops`` mutating ops (the paper's worker threads),
    instead of callers hand-invoking drains.

Backends implement the small protocol below; see DESIGN.md §Client API for
the migration table from the old surfaces.
"""
from __future__ import annotations

import functools
from typing import Optional, Protocol, Tuple, runtime_checkable

import jax
import jax.numpy as jnp

from repro.core import index_group as ig
from repro.core import kvstore as kv
from repro.core import log as lg
from repro.core.hashing import key_dtype, key_inf, next_pow2
from repro.core.results import (DeleteResult, GetResult, PutResult,
                                ScanResult)

I32 = jnp.int32


@runtime_checkable
class Backend(Protocol):
    """Fixed-shape batch ops over one store.  All mutating ops take a
    ``valid`` lane mask (padding lanes mutate nothing and consume no
    routing capacity); ``delete`` returns (acked, found) so the client can
    retry push-back without re-deleting."""

    batch_multiple: int   # padded batch sizes must divide by this
    value_words: int      # payload width W of values [Q, W]

    def put(self, keys, vals, valid) -> Tuple[jnp.ndarray, jnp.ndarray]: ...
    def get(self, keys, valid) -> tuple: ...
    def delete(self, keys, valid) -> Tuple[jnp.ndarray, jnp.ndarray]: ...
    def scan(self, lo, hi, limit: int) -> tuple: ...
    def apply_async(self) -> None: ...
    def drain(self) -> None: ...


# ---------------------------------------------------------------------------
# Local backend: one index group + the node's data shard, jitted ops
# ---------------------------------------------------------------------------
@functools.partial(jax.jit, static_argnums=(0,))
def _local_put(cfg, g, dvals, dfill, keys, vals, valid):
    dcap = dvals.shape[0]
    off = jnp.cumsum(valid.astype(I32)) - 1
    slot = jnp.where(valid, (dfill + off) % dcap, dcap)
    dvals = dvals.at[slot].set(vals, mode="drop")
    addrs = jnp.where(valid, slot, -1).astype(I32)
    g, ok = ig.put(g, keys, addrs, cfg, valid)
    return g, dvals, dfill + valid.astype(I32).sum(), ok, addrs


@functools.partial(jax.jit, static_argnums=(0, 5))
def _local_get(cfg, g, dvals, keys, valid, primary_alive):
    addr, found, acc = ig.get(g, keys, cfg, primary_alive=primary_alive)
    found = found & valid
    dcap = dvals.shape[0]
    slot = jnp.where(found & (addr >= 0) & (addr < dcap), addr, dcap)
    padded = jnp.concatenate(
        [dvals, jnp.zeros((1,) + dvals.shape[1:], dvals.dtype)])
    vals = padded[jnp.clip(slot, 0, dcap)]
    return (jnp.where(found, addr, -1).astype(I32), found,
            jnp.where(valid, acc, 0), vals, valid)


@functools.partial(jax.jit, static_argnums=(0,))
def _local_delete(cfg, g, keys, valid):
    g, found = ig.delete(g, keys, cfg, valid)
    return g, found & valid


class LocalBackend:
    """One index group (1 hash + n_backups sorted replicas + logs) plus the
    value shard a single-node deployment owns.  The client's routing hint:
    liveness is tracked host-side (the paper's client knows which servers
    are up), so healthy GETs compile the one-sided hash path only."""

    def __init__(self, capacity: int, cfg, value_words: Optional[int] = None):
        self.cfg = cfg
        self.capacity = capacity
        self.group = ig.create(capacity, cfg)
        self.value_words = value_words or cfg.value_words
        self.dvals = jnp.zeros((capacity, self.value_words), I32)
        self.dfill = jnp.zeros((), I32)
        self.batch_multiple = 1
        self._primary_alive = True

    def _ensure_log_room(self, n: int):
        """Backup logs reject appends when their pending window is full;
        locally we know the fill exactly, so drain up front instead of
        bouncing the batch back through the retry loop."""
        if self.pending_ops() + n > self.cfg.log_capacity:
            self.drain()

    def put(self, keys, vals, valid):
        self._ensure_log_room(int(valid.sum()))
        self.group, self.dvals, self.dfill, ok, addrs = _local_put(
            self.cfg, self.group, self.dvals, self.dfill, keys, vals, valid)
        return ok, addrs

    def get(self, keys, valid):
        hint = True if self._primary_alive else None
        return _local_get(self.cfg, self.group, self.dvals, keys, valid,
                          hint)

    def delete(self, keys, valid):
        self._ensure_log_room(int(valid.sum()))
        self.group, found = _local_delete(self.cfg, self.group, keys, valid)
        # room is guaranteed above, so every valid lane is acked this round
        return valid, found

    def scan(self, lo, hi, limit: int):
        (k, a, n), self.group = ig.scan(self.group, lo, hi, limit, self.cfg)
        return k, a, n

    def apply_async(self):
        self.group = ig.apply_async(self.group, self.cfg)

    def drain(self):
        self.group = ig.drain(self.group, self.cfg)

    def pending_ops(self) -> int:
        return int(lg.pending_count(self.group.blogs).max())

    def fail_server(self, server: int = 0):
        self.group = ig.fail(self.group, server)
        if server == 0:
            self._primary_alive = False

    def recover_server(self, server: int = 0):
        if server == 0:
            self.group = ig.recover_primary(self.group, self.cfg)
            self._primary_alive = True
        else:
            self.group = ig.recover_backup(self.group, server - 1, self.cfg)


# ---------------------------------------------------------------------------
# Distributed backend: the shard_map'd store (one index group per device)
# ---------------------------------------------------------------------------
class DistributedBackend:
    """Wraps the kvstore shard_map ops: routed two-sided PUT/DELETE with
    ppermute log replication, one-sided GET, all_gather'd SCAN."""

    def __init__(self, mesh, cfg, capacity_per_group: int = 4096, *,
                 capacity_q: int = 64, scan_limit: int = 128):
        self.mesh = mesh
        self.cfg = cfg
        self.G = mesh.devices.size
        self.store = kv.create(mesh, capacity_per_group, cfg)
        self.ops = kv.make_ops(mesh, cfg, capacity_q=capacity_q,
                               scan_limit=scan_limit)
        self.capacity_q = capacity_q
        self.scan_limit = scan_limit
        self.batch_multiple = self.G
        self.value_words = cfg.value_words

    def _ensure_log_room(self, n: int):
        # global view of the worst backup-log fill: drain up front when a
        # batch cannot possibly fit, saving retry round-trips (per-lane
        # overflow is still acked honestly and retried by the client)
        if self.pending_ops() + n > self.cfg.log_capacity:
            self.drain()

    def put(self, keys, vals, valid):
        self._ensure_log_room(int(valid.sum()))
        self.store, ok, addrs = self.ops["put"](self.store, keys, vals,
                                                valid)
        return ok, addrs

    def get(self, keys, valid):
        addrs, found, acc, vals, routed = self.ops["get"](self.store, keys,
                                                          valid)
        return addrs, found & valid, acc, vals, routed & valid

    def delete(self, keys, valid):
        self._ensure_log_room(int(valid.sum()))
        self.store, ok, found = self.ops["delete"](self.store, keys, valid)
        return ok, found & valid

    def scan(self, lo, hi, limit: int):
        kd = key_dtype()
        loa = jnp.full((self.G,), lo, kd)
        hia = jnp.full((self.G,), hi, kd)
        # the result width is a static shape: compile (and cache, via
        # make_ops' lru_cache) one scan op per distinct limit so a caller
        # asking for more than the construction-time default is honored
        if limit == self.scan_limit:
            scan_op = self.ops["scan"]
        else:
            scan_op = kv.make_ops(self.mesh, self.cfg,
                                  capacity_q=self.capacity_q,
                                  scan_limit=limit)["scan"]
        k, a, self.store = scan_op(self.store, loa, hia)
        n = (k != key_inf(k.dtype)).sum().astype(I32)
        return k, a, n

    def apply_async(self):
        self.store = self.ops["apply"](self.store)

    def drain(self):
        while self.pending_ops() > 0:
            self.apply_async()

    def pending_ops(self) -> int:
        return int(jnp.max(self.store.blog.tail - self.store.blog.applied))

    def fail_server(self, server: int):
        self.store = kv.fail_server(self.store, server)

    def recover_server(self, server: int):
        self.store = kv.recover_server(self.store, server)


# ---------------------------------------------------------------------------
# The client
# ---------------------------------------------------------------------------
class HiStoreClient:
    """Typed GET/PUT/DELETE/SCAN over a pluggable backend (see module
    docstring).  Thread-compatible with eager callers: all state lives in
    the backend; the client only holds policy."""

    def __init__(self, backend, *, batch_quantum: int = 64,
                 max_batch: int = 16384, max_retries: int = 8,
                 apply_every_n_ops: Optional[int] = None):
        self.backend = backend
        m = max(getattr(backend, "batch_multiple", 1), 1)
        self._multiple = m
        # padded sizes: power-of-two, rounded up to a multiple of the
        # backend's device count (works for non-power-of-two meshes too)
        q0 = next_pow2(max(batch_quantum, 1))
        self.batch_quantum = -(-q0 // m) * m
        self.max_batch = (-(-max(max_batch, self.batch_quantum)
                            // self.batch_quantum) * self.batch_quantum)
        self.max_retries = max_retries
        self.apply_every_n_ops = apply_every_n_ops
        self._mutations_since_apply = 0
        self.stats = {"puts": 0, "gets": 0, "deletes": 0, "scans": 0,
                      "retries": 0, "applies": 0}

    # -- public ops --------------------------------------------------------
    def put(self, keys, values=None) -> PutResult:
        keys = self._as_keys(keys)
        q = keys.shape[0]
        if q == 0:
            return PutResult(jnp.zeros((0,), bool), jnp.zeros((0,), I32), 0)
        vals = self._as_values(values, q)
        oks, addrs, retries = [], [], 0
        for s in range(0, q, self.max_batch):
            o, a, r = self._put_chunk(keys[s:s + self.max_batch],
                                      vals[s:s + self.max_batch])
            oks.append(o)
            addrs.append(a)
            retries = max(retries, r)
        self.stats["puts"] += q
        self._note_mutations(q)
        return PutResult(jnp.concatenate(oks), jnp.concatenate(addrs),
                         retries)

    def get(self, keys) -> GetResult:
        keys = self._as_keys(keys)
        q = keys.shape[0]
        if q == 0:
            W = getattr(self.backend, "value_words", 1)
            return GetResult(jnp.zeros((0,), I32), jnp.zeros((0,), bool),
                             jnp.zeros((0,), I32), jnp.zeros((0, W), I32))
        outs = [self._get_chunk(keys[s:s + self.max_batch])
                for s in range(0, q, self.max_batch)]
        self.stats["gets"] += q
        return GetResult(*[jnp.concatenate(p) for p in zip(*outs)])

    def delete(self, keys) -> DeleteResult:
        keys = self._as_keys(keys)
        q = keys.shape[0]
        if q == 0:
            return DeleteResult(jnp.zeros((0,), bool),
                                jnp.zeros((0,), bool), 0)
        oks, founds, retries = [], [], 0
        for s in range(0, q, self.max_batch):
            o, f, r = self._delete_chunk(keys[s:s + self.max_batch])
            oks.append(o)
            founds.append(f)
            retries = max(retries, r)
        self.stats["deletes"] += q
        self._note_mutations(q)
        return DeleteResult(jnp.concatenate(oks), jnp.concatenate(founds),
                            retries)

    def scan(self, lo, hi, limit: Optional[int] = None) -> ScanResult:
        kd = key_dtype()
        if limit is None:
            limit = getattr(self.backend, "scan_limit", 128)
        if limit <= 0:
            kd_inf = jnp.zeros((0,), kd)
            return ScanResult(kd_inf, jnp.zeros((0,), I32),
                              jnp.zeros((), I32))
        k, a, n = self.backend.scan(jnp.asarray(lo, kd), jnp.asarray(hi, kd),
                                    limit)
        self.stats["scans"] += 1
        lim = min(limit, k.shape[0])
        return ScanResult(k[:lim], a[:lim],
                          jnp.minimum(n, lim).astype(I32))

    def apply(self) -> None:
        """One asynchronous log->sorted merge round on every backup."""
        self.stats["applies"] += 1
        self.backend.apply_async()

    def drain(self) -> None:
        """Apply ALL pending log entries (SCAN serializability barrier)."""
        self.backend.drain()

    def fail_server(self, server: int) -> None:
        self.backend.fail_server(server)

    def recover_server(self, server: int) -> None:
        self.backend.recover_server(server)

    # -- batching / retry internals ---------------------------------------
    def _as_keys(self, keys):
        k = jnp.asarray(keys, key_dtype())
        if k.ndim == 0:
            k = k[None]
        return k

    def _as_values(self, values, q):
        W = getattr(self.backend, "value_words", 1)
        if values is None:
            return jnp.zeros((q, W), I32)
        v = jnp.asarray(values, I32)
        if v.ndim == 0:
            v = v[None]
        if v.ndim == 1:
            v = jnp.tile(v[:, None], (1, W))
        return v

    def _padded_len(self, q: int) -> int:
        p = max(self.batch_quantum, next_pow2(q))
        p = -(-p // self._multiple) * self._multiple
        return min(self.max_batch, p)

    def _pad(self, keys):
        q = keys.shape[0]
        p = self._padded_len(q)
        kp = jnp.zeros((p,), keys.dtype).at[:q].set(keys)
        valid = jnp.zeros((p,), bool).at[:q].set(True)
        return kp, valid

    def _put_chunk(self, keys, vals):
        q = keys.shape[0]
        kp, pending = self._pad(keys)
        vp = jnp.zeros((kp.shape[0], vals.shape[1]), vals.dtype
                       ).at[:q].set(vals)
        ok_all = jnp.zeros_like(pending)
        addr_all = jnp.full(kp.shape, -1, I32)
        retries = 0
        while True:
            ok, addrs = self.backend.put(kp, vp, pending)
            newly = pending & ok
            ok_all = ok_all | newly
            addr_all = jnp.where(newly, addrs, addr_all)
            pending = pending & ~ok
            if not bool(pending.any()) or retries >= self.max_retries:
                break
            retries += 1
            self.stats["retries"] += 1
            # push-back: make room (log->sorted merges) before resending
            self.backend.apply_async()
        return ok_all[:q], addr_all[:q], retries

    def _delete_chunk(self, keys):
        q = keys.shape[0]
        kp, pending = self._pad(keys)
        acked = jnp.zeros_like(pending)
        found_all = jnp.zeros_like(pending)
        retries = 0
        while True:
            ack, found = self.backend.delete(kp, pending)
            newly = pending & ack
            acked = acked | newly
            found_all = found_all | (newly & found)
            pending = pending & ~ack
            if not bool(pending.any()) or retries >= self.max_retries:
                break
            retries += 1
            self.stats["retries"] += 1
            self.backend.apply_async()
        return acked[:q], found_all[:q], retries

    def _get_chunk(self, keys):
        q = keys.shape[0]
        kp, pending = self._pad(keys)
        addr_all = jnp.full(kp.shape, -1, I32)
        found_all = jnp.zeros_like(pending)
        acc_all = jnp.zeros(kp.shape, I32)
        vals_all = None
        retries = 0
        while True:
            addrs, found, acc, vals, routed = self.backend.get(kp, pending)
            if vals_all is None:
                vals_all = jnp.zeros_like(vals)
            newly = pending & routed
            addr_all = jnp.where(newly, addrs, addr_all)
            found_all = found_all | (newly & found)
            acc_all = jnp.where(newly, acc, acc_all)
            vals_all = jnp.where(newly[:, None], vals, vals_all)
            pending = pending & ~routed
            if not bool(pending.any()) or retries >= self.max_retries:
                break
            retries += 1
            self.stats["retries"] += 1
        return addr_all[:q], found_all[:q], acc_all[:q], vals_all[:q]

    def _note_mutations(self, n: int):
        if not self.apply_every_n_ops:
            return
        self._mutations_since_apply += n
        if self._mutations_since_apply >= self.apply_every_n_ops:
            self._mutations_since_apply = 0
            self.apply()
