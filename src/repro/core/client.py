"""HiStoreClient: one typed front door over the hybrid index.

The paper's client sees a single KV interface (GET/PUT/DELETE/SCAN) no
matter whether a request lands on the hash table, a skiplist replica, or a
degraded backup path.  This module is that front door for the repro:

    client = HiStoreClient(LocalBackend(4096, cfg))          # one node
    client = HiStoreClient(DistributedBackend(mesh, cfg))    # shard_map'd

    res = client.put(keys, values)       # PutResult(ok, addrs, retries)
    res = client.get(keys)               # GetResult(addrs, found, acc, vals)
    res = client.delete(keys)            # DeleteResult(ok, found, retries)
    res = client.scan(lo, hi, limit)     # ScanResult(keys, addrs, count)

Responsibilities the old per-layer surfaces pushed onto every caller:

  * fixed-shape batching — requests are padded to power-of-two batch sizes
    (and a multiple of the device count for the distributed backend), so
    the jitted ops stop recompiling per batch size; oversize requests are
    split into ``max_batch`` chunks;
  * overflow push-back — capacity overflow (exchange-buffer ok=False, the
    paper's RPC queue-full) becomes a bounded client-side retry loop with
    async-apply + GC-flush drains in between, instead of a
    silently-surfaced flag;
  * async-apply scheduling — the backups' log->sorted merges run every
    ``apply_every_n_ops`` mutating ops (the paper's worker threads),
    instead of callers hand-invoking drains;
  * migration policy — ``migrate_on_recover`` (default on) runs the
    background value migration after every recovery, restoring one-RTT
    GETs (``GetResult.hops`` back to 1); turn it off to measure the
    second-hop fetch cost the paper's data plane would otherwise pay.

Backends implement the ``Backend`` protocol (core/backend.py — serving
ops + telemetry gauges + lease/fault-injection hooks; re-exported here);
see DESIGN.md §Client API for the migration table from the old surfaces.
"""
from __future__ import annotations

import functools
import threading
import time
import warnings
import weakref
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import data_plane as dpl
from repro.core.backend import Backend  # noqa: F401  (re-export)
from repro.core import index_group as ig
from repro.core import kvstore as kv
from repro.core import log as lg
from repro.core import telemetry as tm
from repro.core.hashing import key_dtype, key_inf, next_pow2
from repro.core.results import (DeleteResult, FailResult, GetResult,
                                PutResult, RecoverResult, ScanResult)

I32 = jnp.int32


# ---------------------------------------------------------------------------
# Local backend: one index group + the node's data shard, jitted ops
# ---------------------------------------------------------------------------
@functools.partial(jax.jit, static_argnums=(0, 7, 8))
def _local_put(cfg, g, vals, used, keys, vs, valid, backups_alive,
               primary_alive):
    dcap = vals.shape[0]
    # one slot per key per batch (last writer wins, like the hash insert);
    # overwrites update their old slot in place — the data-server GC —
    # so the shard reuses capacity instead of wrapping onto live slots
    winner = dpl.winner_mask(keys, valid)
    old_a, old_f = ig.owner_addr_probe(g, keys, cfg, primary_alive)
    inplace = winner & old_f & (old_a >= 0) & (old_a < dcap)
    used, slot, aok = dpl.alloc(used, winner & ~inplace)
    wslot = jnp.where(inplace, old_a, jnp.where(aok, slot, dcap))
    wmask = inplace | aok
    vals = vals.at[jnp.where(wmask, wslot, dcap)].set(vs, mode="drop")
    addr_lane = jnp.where(wmask, wslot, -1).astype(I32)
    addrs = dpl.spread_winner_addr(keys, valid, winner, addr_lane)
    landed = valid & (addrs >= 0)   # shard full -> un-acked, client retries
    g, ok, nrep = ig.put(g, keys, addrs, cfg, landed,
                         backups_alive=backups_alive, with_nrep=True)
    # un-acked fresh allocations roll back ONLY when no backup log
    # recorded the entry (same nrep == 0 rule as the distributed body: a
    # slot a replica log references must never return to the allocator)
    used = dpl.free_slots(used, slot, aok & ~ok & (nrep == 0))
    return g, vals, used, ok & landed, addrs, nrep


@functools.partial(jax.jit, static_argnums=(0, 5))
def _local_get(cfg, g, dvals, keys, valid, primary_alive):
    addr, found, acc = ig.get(g, keys, cfg, primary_alive=primary_alive)
    found = found & valid
    dcap = dvals.shape[0]
    slot = jnp.where(found & (addr >= 0) & (addr < dcap), addr, dcap)
    padded = jnp.concatenate(
        [dvals, jnp.zeros((1,) + dvals.shape[1:], dvals.dtype)])
    vals = padded[jnp.clip(slot, 0, dcap)]
    return (jnp.where(found, addr, -1).astype(I32), found,
            jnp.where(valid, acc, 0), vals, valid,
            valid.astype(I32))      # single shard: every read is one hop


@functools.partial(jax.jit, static_argnums=(0, 5, 6))
def _local_delete(cfg, g, used, keys, valid, backups_alive, primary_alive):
    # data-server GC: a committed DELETE frees its value slot (the
    # returned found is already gated on the log acks; winner-deduped so
    # a double-delete within one batch frees exactly once)
    winner = dpl.winner_mask(keys, valid)
    old_a, old_f = ig.owner_addr_probe(g, keys, cfg, primary_alive)
    dcap = used.shape[0]
    g, found = ig.delete(g, keys, cfg, valid, backups_alive=backups_alive,
                         primary_alive=primary_alive)
    freed = winner & found & old_f & (old_a >= 0) & (old_a < dcap)
    used = dpl.free_slots(used, old_a, freed)
    return g, used, found & valid


class LocalBackend:
    """One index group (1 hash + n_backups sorted replicas + logs) plus the
    value shard a single-node deployment owns — slot-allocated and GC'd by
    the data plane's bitmap (data_plane.alloc/free_slots).  The client's
    routing hint: liveness is tracked host-side (the paper's client knows
    which servers are up), so healthy GETs compile the one-sided hash path
    only."""

    def __init__(self, capacity: int, cfg, value_words: Optional[int] = None):
        self.cfg = cfg
        self.telemetry = tm.Telemetry(getattr(cfg, "telemetry",
                                              "counters"))
        self.capacity = capacity
        self.group = ig.create(capacity, cfg)
        self.value_words = value_words or cfg.value_words
        self.vals = jnp.zeros((capacity, self.value_words), I32)
        self.used = jnp.zeros((capacity,), bool)
        self.batch_multiple = 1
        self.max_mutation_batch = cfg.log_capacity
        self._primary_alive = True
        self._backups_alive = [True] * cfg.n_backups
        self._pending_bound = 0   # host-side upper bound on log pending

    def _ensure_log_room(self, n: int):
        """Backup logs reject appends when their pending window is full;
        the client caps mutation chunks at log_capacity, so draining up
        front guarantees the whole batch fits (no bounced acks).  The
        host-side bound avoids a device sync per mutation; it only ever
        over-estimates, so at worst we drain early."""
        if self._pending_bound + n > self.cfg.log_capacity:
            self.drain()

    def put(self, keys, vals, valid):
        n = int(valid.sum())
        self._ensure_log_room(n)
        self._pending_bound += n
        ba = tuple(self._backups_alive)
        hint = True if self._primary_alive else None
        self.group, self.vals, self.used, ok, addrs, nrep = _local_put(
            self.cfg, self.group, self.vals, self.used, keys, vals, valid,
            ba, hint)
        return ok, addrs, nrep

    def get(self, keys, valid):
        hint = True if self._primary_alive else None
        return _local_get(self.cfg, self.group, self.vals, keys, valid,
                          hint)

    def delete(self, keys, valid):
        n = int(valid.sum())
        self._ensure_log_room(n)
        self._pending_bound += n
        ba = tuple(self._backups_alive)
        hint = True if self._primary_alive else None
        self.group, self.used, found = _local_delete(
            self.cfg, self.group, self.used, keys, valid, ba, hint)
        # room is guaranteed above (chunks capped at log_capacity + the
        # up-front drain), so every valid lane is acked this round
        return valid, found, valid.astype(I32) * sum(ba)

    def scan(self, lo, hi, limit: int):
        (k, a, n), self.group = ig.scan(self.group, lo, hi, limit, self.cfg)
        self._pending_bound = 0          # scan drained the logs
        # single node: the process answering IS the store — a scan that
        # returns at all covered its one group
        return k, a, n, jnp.ones((1,), bool)

    def apply_async(self):
        self.group = ig.apply_async(self.group, self.cfg)
        self._pending_bound = max(
            0, self._pending_bound - self.cfg.async_apply_batch)

    def drain(self):
        self.group = ig.drain(self.group, self.cfg)
        self._pending_bound = 0

    def pending_ops(self) -> int:
        return int(lg.pending_count(self.group.blogs).max())

    def telemetry_gauges(self) -> dict:
        """Snapshot-time gauges: the single node's liveness is host-side
        and its one shard has no free queue, so only the pending-log
        depth needs a device fetch."""
        return {
            "live_index_servers": (int(self._primary_alive)
                                   + sum(map(int, self._backups_alive))),
            "live_data_servers": 1,
            "pending_log_ops": self.pending_ops(),
            "freeq_pending": 0,
            "fq_spill": 0,
        }

    def migrate_values(self) -> int:
        return 0   # one shard: every value is already home

    def lease_stalled(self) -> bool:
        return False   # liveness is host-side: no leases to stall

    def fail_data_server(self, server: int = 0):
        raise NotImplementedError(
            "LocalBackend owns a single unreplicated value shard — no "
            "surviving copy could exist; data-server failures are "
            "modelled by DistributedBackend (cfg.n_value_replicas)")

    recover_data_server = fail_data_server

    def sever_server(self, server: int = 0):
        raise NotImplementedError(
            "heartbeat severing needs the distributed backend's "
            "lease detector; LocalBackend liveness is host-side")

    def sever_data_server(self, server: int = 0):
        raise NotImplementedError(
            "data-server heartbeat severing needs the distributed "
            "backend's lease detector; LocalBackend owns a single "
            "unreplicated shard")

    def fail_server(self, server: int = 0):
        self.group = ig.fail(self.group, server)
        if server == 0:
            self._primary_alive = False
        else:
            self._backups_alive[server - 1] = False
        self.telemetry.count("index_demotions")
        self.telemetry.span({"event": "demote", "plane": "index",
                             "server": server, "detected": False})

    def recover_server(self, server: int = 0, online: bool = True):
        if server == 0:
            self.group = ig.recover_primary(self.group, self.cfg,
                                            online=online)
            self._primary_alive = True
        else:
            self.group = ig.recover_backup(self.group, server - 1,
                                           self.cfg, online=online)
            self._backups_alive[server - 1] = True
        self.telemetry.count("index_recoveries")
        self.telemetry.span({"event": "recover", "plane": "index",
                             "server": server, "online": online})


# ---------------------------------------------------------------------------
# Distributed backend: the shard_map'd store (one index group per device)
# ---------------------------------------------------------------------------
def _lease_ticker_loop(ref, stop: threading.Event) -> None:
    """Background ticker body (module-level: the thread must only hold a
    WEAK reference to the backend).  Polls at a fraction of the idle
    interval so a tick lands within one interval of the threshold being
    crossed; ``stop`` is this thread's own event, so a ticker orphaned
    by a timed-out stop_ticker() stays stopped even after
    start_ticker() installs a replacement; a garbage-collected backend
    ends the loop at the next wake-up."""
    fails = 0
    while True:
        be = ref()
        if be is None:
            return
        quantum = max(be.lease_interval_s / 5.0, 0.01)
        interval = be.lease_interval_s
        be = None                      # never hold the ref across a wait
        if stop.wait(quantum):
            return
        be = ref()
        if be is None:
            return
        try:
            if time.monotonic() - be._last_traffic_t < interval:
                continue
            with be._mu:
                # re-check under the lock: a foreground op may have
                # just run (its _lease_tick refreshed the timestamp)
                if time.monotonic() - be._last_traffic_t < interval:
                    continue
                be._lease_tick(bump=True)
            be.telemetry.count("ticker_rounds")
            fails = 0
        except Exception as e:   # noqa: BLE001 — a daemon thread must
            # not die silently on a transient dispatch error:
            # idle-client detection would be disabled with no signal
            fails += 1
            be.telemetry.count("ticker_errors")
            warnings.warn(
                f"lease ticker tick failed ({e!r}); "
                f"{'giving up' if fails >= 3 else 'retrying'}",
                RuntimeWarning)
            if fails >= 3:
                # a dead ticker means idle detection is OFF: latch the
                # give-up so start_ticker() stops claiming one is
                # running and the counters carry the signal
                be._ticker_gave_up = True
                be.telemetry.count("ticker_gave_up")
                return
        finally:
            be = None


class DistributedBackend:
    """Wraps the kvstore shard_map ops: routed two-sided PUT/DELETE with
    ppermute log replication, one-sided GET with second-hop fetch,
    all_gather'd SCAN, plus the value plane's GC flush and migration."""

    def __init__(self, mesh, cfg, capacity_per_group: int = 4096, *,
                 capacity_q: int = 64, scan_limit: int = 128):
        self.mesh = mesh
        self.cfg = cfg
        # the telemetry plane this backend (and the client over it)
        # reports through; validates cfg.telemetry before any device work
        self.telemetry = tm.Telemetry(getattr(cfg, "telemetry",
                                              "counters"))
        self.G = mesh.devices.size
        self.store = kv.create(mesh, capacity_per_group, cfg)
        self.ops = kv.make_ops(mesh, cfg, capacity_q=capacity_q,
                               scan_limit=scan_limit)
        self.capacity_q = capacity_q
        self.scan_limit = scan_limit
        self.batch_multiple = self.G
        self.value_words = cfg.value_words
        self.max_mutation_batch = cfg.log_capacity
        self._dead: set[int] = set()        # index servers masked dead
        self._data_dead: set[int] = set()   # data servers masked dead
        self._pending_bound = 0        # host-side upper bound, no dev sync
        # --- lease-based failure detection (paper §5) --------------------
        # every routed op bumps per-device heartbeat counters on the mesh
        # for BOTH planes (index hb + data hb); the client ages them here
        # and demotes a server to degraded routing once its lease expires
        # — no oracle fail_server/fail_data_server call anywhere in that
        # path.  Two clocks: "wall" (default — elapsed monotonic time
        # since the counter last advanced exceeds cfg.lease_timeout_s)
        # and "rounds" (the deterministic test mode: cfg.lease_misses
        # stalled observation rounds).  lease_misses == 0 disables
        # detection entirely in either mode.
        self.lease_misses = int(getattr(cfg, "lease_misses", 0) or 0)
        self.lease_clock = str(getattr(cfg, "lease_clock", "rounds"))
        self.lease_timeout_s = float(getattr(cfg, "lease_timeout_s", 0.0))
        self.lease_interval_s = float(
            getattr(cfg, "lease_interval_s", 0.0) or 0.25)
        # misconfiguration must fail loudly: silently-disabled detection
        # is the exact availability gap the liveness plane closes
        if self.lease_clock not in ("wall", "rounds"):
            raise ValueError(
                f"cfg.lease_clock must be 'wall' or 'rounds', got "
                f"{self.lease_clock!r}")
        if (self.lease_misses > 0 and self.lease_clock == "wall"
                and self.lease_timeout_s <= 0):
            raise ValueError(
                "wall-clock leases need cfg.lease_timeout_s > 0 "
                "(set lease_misses=0 to disable detection instead)")
        self._severed: set[int] = set()     # injector-crashed index srvs
        self._data_severed: set[int] = set()  # injector-crashed data srvs
        now = time.monotonic()
        self._last_hb = np.zeros((self.G,), np.int64)
        self._hb_misses = np.zeros((self.G,), np.int64)
        self._hb_t = np.full((self.G,), now, np.float64)   # last advance
        self._last_data_hb = np.zeros((self.G,), np.int64)
        self._data_hb_misses = np.zeros((self.G,), np.int64)
        self._data_hb_t = np.full((self.G,), now, np.float64)
        self.detected: list[int] = []       # index demotions the detector
        self.detected_data: list[int] = []  # data demotions the detector
        # the store and the lease state are shared with the background
        # ticker thread: one reentrant lock serializes every op
        self._mu = threading.RLock()
        self._last_traffic_t = now
        self._ticker: Optional[threading.Thread] = None
        self._ticker_stop: Optional[threading.Event] = None
        self._ticker_gave_up = False   # the loop died on repeated errors

    def _ensure_log_room(self, n: int):
        # drain up front when a batch might not fit the worst backup log
        # (chunks are capped at log_capacity, so after a drain the whole
        # batch is guaranteed to land; per-lane exchange overflow is still
        # acked honestly and retried by the client)
        if self._pending_bound + n > self.cfg.log_capacity:
            self.drain()

    def _degraded(self) -> bool:
        return bool(self._dead or self._data_dead)

    # -- lease detector ----------------------------------------------------
    def _lease_expired(self, misses: np.ndarray, last_t: np.ndarray,
                       g: int, now: float) -> bool:
        """One server's lease verdict after a stalled observation: rounds
        mode counts stalled rounds against ``lease_misses``; wall mode
        measures elapsed monotonic time since the counter last advanced
        against ``lease_timeout_s`` (the paper's §5 semantics)."""
        if self.lease_clock == "wall":
            return now - last_t[g] >= self.lease_timeout_s
        return misses[g] >= self.lease_misses

    def _lease_tick(self, bump: bool = False):
        """Age the leases of BOTH planes after an observation round: a
        server whose heartbeat counter did not advance accumulates a
        stalled round (and its wall-clock stall timer keeps running); an
        expired lease demotes it to degraded routing.  ``bump`` runs the
        heartbeat-only tick op first — read-only rounds (GET) and the
        idle ticker age leases through it, mutating ops bump in-body."""
        if self.lease_misses <= 0:
            return
        self.telemetry.count("lease_ticks")
        if bump:
            self.store = self.ops["tick"](self.store)
        now = time.monotonic()
        self._last_traffic_t = now
        # one combined device->host fetch for both planes' counters (a
        # second sequential sync would double the per-op detection tax)
        hb, dhb = jax.device_get((self.store.hb, self.store.data.hb))
        self._age_plane(hb, self._last_hb, self._hb_misses, self._hb_t,
                        self._dead, self._demote, now)
        self._age_plane(dhb, self._last_data_hb, self._data_hb_misses,
                        self._data_hb_t, self._data_dead,
                        self._demote_data, now)
        self._last_hb = hb
        self._last_data_hb = dhb

    def _age_plane(self, hb, last, misses, last_t, dead, demote,
                   now: float):
        """Age ONE plane's leases against its freshly-read counters —
        the single aging body both planes share, so every lease-state
        invariant (renewal resets, stall accounting, expiry) applies to
        index and data servers by construction."""
        for g in range(self.G):
            if g in dead:
                continue
            if hb[g] != last[g]:
                misses[g] = 0
                last_t[g] = now
            else:
                misses[g] += 1
                if self._lease_expired(misses, last_t, g, now):
                    demote(g, detected=True)

    def _demote(self, g: int, detected: bool = False):
        """Degraded routing for index server ``g`` — the client-side half
        of a failure, with no oracle call and no state wipe (whatever
        state the server lost, it lost when it crashed)."""
        self.store = self.store._replace(
            alive=self.store.alive.at[g].set(False))
        self._dead.add(g)
        self._hb_misses[g] = 0   # a demoted server no longer "stalls"
        if detected:
            self.detected.append(g)
        self.telemetry.count("index_demotions")
        self.telemetry.span({"event": "demote", "plane": "index",
                             "server": g, "detected": detected})

    def _demote_data(self, g: int, detected: bool = False):
        """Degraded routing for DATA server ``g``: GETs of its shard fail
        over to mirror-served fetches, PUTs displace one hop (the
        degraded put variant compiles in) — the value-plane half of the
        unified liveness view, again with no oracle call."""
        self.store = self.store._replace(data=self.store.data._replace(
            alive=self.store.data.alive.at[g].set(False)))
        self._data_dead.add(g)
        self._data_hb_misses[g] = 0
        if detected:
            self.detected_data.append(g)
        self.telemetry.count("data_demotions")
        self.telemetry.span({"event": "demote", "plane": "data",
                             "server": g, "detected": detected})

    def lease_stalled(self) -> bool:
        """Did the last observation round see a not-yet-demoted server's
        heartbeat stalled (either plane)?  The client's wall-mode retry
        pacing keys on this, so healthy push-back retries — capacity
        overflow with every heartbeat advancing — never pay the
        lease-timeout tax."""
        return bool((self._hb_misses > 0).any()
                    or (self._data_hb_misses > 0).any())

    # -- background ticker (idle-client wall-clock detection) --------------
    def start_ticker(self) -> bool:
        """Start the client-side background ticker thread: whenever no
        foreground traffic has run for ``cfg.lease_interval_s`` it issues
        a heartbeat-only tick round, so wall-clock leases expire (and
        failures are detected) with ZERO foreground ops.  No-op when
        detection is disabled.  Returns True if a ticker is running —
        and False when a previous ticker GAVE UP after repeated tick
        errors (``ticker_gave_up`` in the metrics): pretending one is
        running would silently disable idle detection.  ``stop_ticker()``
        clears the latch for an explicit restart."""
        if self.lease_misses <= 0:
            return False
        if self._ticker_gave_up:
            return False
        if self._ticker is not None and self._ticker.is_alive():
            return True
        stop = threading.Event()
        self._ticker_stop = stop
        # the thread holds only a WEAK reference to this backend (and a
        # finalizer sets its stop event): a client dropped without
        # stop_ticker() must not pin the device-resident store nor keep
        # dispatching tick ops for the rest of the process lifetime
        self._ticker = threading.Thread(
            target=_lease_ticker_loop, args=(weakref.ref(self), stop),
            name="histore-lease-ticker", daemon=True)
        weakref.finalize(self, stop.set)
        self._ticker.start()
        return True

    def stop_ticker(self) -> None:
        # an explicit stop also clears the give-up latch: the operator
        # acknowledged the dead ticker, a fresh start_ticker() may retry
        self._ticker_gave_up = False
        if self._ticker is None:
            return
        self._ticker_stop.set()
        self._ticker.join(timeout=60.0)
        if self._ticker.is_alive():
            # still inside a long first-tick jit compile; its own stop
            # event is set, so it exits at the next loop check — and a
            # fresh start_ticker() gets a NEW event, so the straggler
            # can never be revived by it
            warnings.warn("lease ticker still draining a tick in flight "
                          "(exits at the next loop check)", RuntimeWarning)
        self._ticker = None
        self._ticker_stop = None

    # (the ticker body lives in the module-level _lease_ticker_loop so
    # the thread never holds a strong reference to the backend)

    def put(self, keys, vals, valid):
        with self._mu:
            n = int(valid.sum())
            self._ensure_log_room(n)
            self._pending_bound += n
            # healthy cluster -> the lean variant; any masked-dead server
            # -> the variant with the old-slot replica probe (frees stay
            # exact at temporary primaries) and the off-dead-shard value
            # displacement
            op = self.ops["put_degraded" if self._degraded() else "put"]
            self.store, ok, addrs, nrep = op(self.store, keys, vals, valid)
            self._lease_tick()
            return ok, addrs, nrep

    def get(self, keys, valid):
        with self._mu:
            addrs, found, acc, vals, routed, val_ok = self.ops["get"](
                self.store, keys, valid)
            found = found & valid
            hops = valid.astype(I32)
            # second hop (paper: the client reads the value from the data
            # server given the address): values written on another shard
            # during a degraded write — or homed on a crashed data server
            # — are fetched by address from the first effective-alive
            # holder (mirror failover); a fetch-overflow lane re-enters
            # the client's retry loop as un-routed
            need = found & ~val_ok
            if bool(need.any()):
                self.store, fvals, fok = self.ops["fetch"](
                    self.store, addrs, need)
                vals = jnp.where(need[:, None], fvals, vals)
                routed = routed & (~need | fok)
                hops = hops + need.astype(I32)
            self._lease_tick(bump=True)
            return addrs, found, acc, vals, routed & valid, hops

    def delete(self, keys, valid):
        with self._mu:
            n = int(valid.sum())
            self._ensure_log_room(n)
            self._pending_bound += n
            # healthy cluster -> probe-free variant (all requests land on
            # true primaries); any masked-dead server -> the degraded
            # variant that answers found at temporary primaries via the
            # replica probe
            op = self.ops[
                "delete_degraded" if self._degraded() else "delete"]
            self.store, ok, found, nrep = op(self.store, keys, valid)
            self._lease_tick()
            return ok, found & valid, nrep

    def scan(self, lo, hi, limit: int):
        with self._mu:
            kd = key_dtype()
            loa = jnp.full((self.G,), lo, kd)
            hia = jnp.full((self.G,), hi, kd)
            # the result width is a static shape: compile (and cache, via
            # make_ops' lru_cache) one scan op per distinct limit so a
            # caller asking for more than the construction-time default
            # is honored
            if limit == self.scan_limit:
                scan_op = self.ops["scan"]
            else:
                scan_op = kv.make_ops(self.mesh, self.cfg,
                                      capacity_q=self.capacity_q,
                                      scan_limit=limit)["scan"]
            k, a, covered, self.store = scan_op(self.store, loa, hia)
            n = (k != key_inf(k.dtype)).sum().astype(I32)
            self._pending_bound = 0          # scan drained the logs
            self._lease_tick()
            return k, a, n, covered

    def apply_async(self):
        with self._mu:
            self.store = self.ops["apply"](self.store)
            self._pending_bound = max(
                0, self._pending_bound - self.cfg.async_apply_batch)
            self._lease_tick()

    def gc_round(self):
        """One routed flush of the pending free queues (slots freed on a
        remote shard travel home and become allocatable)."""
        with self._mu:
            self.store = self.ops["gc"](self.store)
            self._lease_tick()

    def pending_frees(self) -> int:
        with self._mu:
            return int(lg.pending_count(self.store.data.freeq).sum())

    def drain(self):
        with self._mu:
            while self.pending_ops() > 0:
                self.apply_async()
            self._pending_bound = 0
            # flush the free queues until empty or stuck (frees addressed
            # to a masked-dead data shard stay queued; the recovery sweep
            # reclaims them if the queue itself is lost)
            prev = -1
            while True:
                cur = self.pending_frees()
                if cur == 0 or cur == prev:
                    break
                prev = cur
                self.gc_round()

    def pending_ops(self) -> int:
        with self._mu:
            return int(jnp.max(self.store.blog.tail
                               - self.store.blog.applied))

    def migrate_values(self) -> int:
        """Background value migration (host-side): move degraded-write
        strays home and patch index addresses; the pass's log barrier
        runs as incremental shard_map'd apply rounds.  Returns values
        moved."""
        with self._mu:
            self.store, moved = kv.migrate_values(
                self.store, self.cfg, apply_fn=self.ops["apply"])
            return moved

    def _wipe_capability(self, what: str) -> bool:
        # wiping needs a surviving copy to exist; a 1-device mesh folds
        # every replica onto the failing device, so the failure degrades
        # to mask-only there — surfaced explicitly (FailResult.wiped +
        # warning) instead of silently weaker semantics
        if self.G > 1:
            return True
        warnings.warn(
            f"single-device mesh: {what} degrades to mask-only (every "
            "replica lives on the failing device, so no surviving copy "
            "could exist; state is masked, NOT wiped)", RuntimeWarning,
            stacklevel=3)
        return False

    def fail_server(self, server: int) -> FailResult:
        with self._mu:
            wiped = self._wipe_capability("fail_server")
            self.store = kv.fail_server(self.store, server, wipe=wiped)
            self._dead.add(server)
            # a known-dead server no longer "stalls": stale misses must
            # not latch lease_stalled() and tax healthy retries
            self._hb_misses[server] = 0
            self._hb_t[server] = time.monotonic()
            return FailResult(server, wiped)

    def sever_server(self, server: int) -> FailResult:
        """Crash ``server`` WITHOUT updating the routing view: its
        heartbeats stop and its state is destroyed, but ``alive`` still
        says up — only the lease detector (or an operator-initiated
        recovery) brings the client's view back in line.  This is the
        fault injector's kill switch for detector schedules; the oracle
        ``fail_server`` stays for tests that want instant masking."""
        with self._mu:
            wiped = self._wipe_capability("sever_server")
            self.store = kv.sever_server(self.store, server, wipe=wiped)
            self._severed.add(server)
            return FailResult(server, wiped)

    def recover_server(self, server: int, online: bool = True,
                       re_replicate: bool = True) -> RecoverResult:
        """Rebuild ``server`` and re-admit it.  ``online`` (default)
        snapshot-clones and lets the pending-log delta stream into the
        rebuilt replicas through the ordinary apply rounds while
        foreground traffic continues; ``re_replicate`` then verifies
        every live holder against the group authorities and rebuilds
        divergent copies (the multi-failure window closer)."""
        with self._mu:
            if server in self._severed and server not in self._dead:
                # operator-initiated recovery implies the failure is
                # known: align routing even if the lease had not expired
                self._demote(server)
            # a RecoveryError propagates with the host-side sever/dead
            # tracking untouched (kv.recover_server is functional, so the
            # store is unchanged too): the server stays routed-dead and
            # severed until a recovery actually succeeds
            self.store = kv.recover_server(self.store, server, self.cfg,
                                           online=online)
            n_reb = 0
            if re_replicate:
                self.store, n_reb = kv.re_replicate(self.store, self.cfg)
            self._severed.discard(server)
            self._dead.discard(server)
            self._hb_misses[server] = 0
            self._hb_t[server] = time.monotonic()
            self.telemetry.count("index_recoveries")
            self.telemetry.span({"event": "recover", "plane": "index",
                                 "server": server, "online": online})
            return RecoverResult(server, online, n_reb, self.pending_ops())

    def fail_data_server(self, server: int) -> FailResult:
        with self._mu:
            wiped = self._wipe_capability("fail_data_server")
            self.store = kv.fail_data_server(self.store, server,
                                             wipe=wiped)
            self._data_dead.add(server)
            self._data_hb_misses[server] = 0   # see fail_server
            self._data_hb_t[server] = time.monotonic()
            return FailResult(server, wiped)

    def sever_data_server(self, server: int) -> FailResult:
        """Crash ``server``'s DATA server WITHOUT updating the routing
        view — the value plane's counterpart of ``sever_server``: its
        data heartbeats stop and its shard state is destroyed, but
        ``data.alive`` still says up.  Reads of its shard fail over to
        the mirrors per-op at once; writes nack and retry until the
        lease detector demotes it (mirror-served GETs + displaced PUTs,
        zero oracle kills)."""
        with self._mu:
            wiped = self._wipe_capability("sever_data_server")
            self.store = kv.sever_data_server(self.store, server,
                                              wipe=wiped)
            self._data_severed.add(server)
            return FailResult(server, wiped)

    def recover_data_server(self, server: int):
        """Rebuild ``server``'s data shard from its mirrors and re-admit
        it — works the same from the oracle-masked and the lease-DETECTED
        state (the detector found the failure; re-provisioning the
        machine is the operator's move)."""
        with self._mu:
            if server in self._data_severed and \
                    server not in self._data_dead:
                # operator recovery implies the failure is known: align
                # the routing view even if the lease had not expired yet
                self._demote_data(server)
            self.store = kv.recover_data_server(
                self.store, server, self.cfg, apply_fn=self.ops["apply"])
            self._data_severed.discard(server)
            self._data_dead.discard(server)
            self._data_hb_misses[server] = 0
            self._data_hb_t[server] = time.monotonic()
            self.telemetry.count("data_recoveries")
            self.telemetry.span({"event": "recover", "plane": "data",
                                 "server": server})

    def telemetry_gauges(self) -> dict:
        """Snapshot-time gauges for ``client.metrics()``: the store's
        device-resident counters (live servers per plane, pending-log
        depth, free-queue occupancy, ``fq_spill``) fetched in one go —
        this is the ONLY place telemetry touches the device, so enabling
        it adds no sync to any op body."""
        with self._mu:
            return kv.device_counters(self.store)


# ---------------------------------------------------------------------------
# The client
# ---------------------------------------------------------------------------
class HiStoreClient:
    """Typed GET/PUT/DELETE/SCAN over a pluggable backend (see module
    docstring).  Thread-compatible with eager callers: all state lives in
    the backend; the client only holds policy."""

    def __init__(self, backend: Backend, *, batch_quantum: int = 64,
                 max_batch: int = 16384, max_retries: int = 8,
                 apply_every_n_ops: Optional[int] = None,
                 migrate_on_recover: bool = True):
        self.backend = backend
        m = max(getattr(backend, "batch_multiple", 1), 1)
        self._multiple = m
        # padded sizes: power-of-two, rounded up to a multiple of the
        # backend's device count (works for non-power-of-two meshes too)
        q0 = next_pow2(max(batch_quantum, 1))
        self.batch_quantum = -(-q0 // m) * m
        self.max_batch = (-(-max(max_batch, self.batch_quantum)
                            // self.batch_quantum) * self.batch_quantum)
        # mutation chunks must fit the backup-log ring after a drain, or
        # the backends' room guarantee (and the acks) would be a lie
        cap = getattr(backend, "max_mutation_batch", None)
        if cap:
            cap = max(self.batch_quantum,
                      cap // self.batch_quantum * self.batch_quantum)
            self.max_batch = min(self.max_batch, cap)
        self.max_retries = max_retries
        self.apply_every_n_ops = apply_every_n_ops
        self.migrate_on_recover = migrate_on_recover
        self._mutations_since_apply = 0
        self.stats = {"puts": 0, "gets": 0, "deletes": 0, "scans": 0,
                      "retries": 0, "applies": 0, "migrated": 0}
        # the backend OWNS the telemetry plane (constructed from its
        # cfg.telemetry knob) so detector/ticker events and client op
        # metrics land in one snapshot; lease-less custom backends
        # without one get an inert "off" instance
        self.telemetry = (getattr(backend, "telemetry", None)
                          or tm.Telemetry("off"))

    # -- public ops --------------------------------------------------------
    def put(self, keys, values=None) -> PutResult:
        keys = self._as_keys(keys)
        q = keys.shape[0]
        if q == 0:
            return PutResult(jnp.zeros((0,), bool), jnp.zeros((0,), I32), 0,
                             jnp.zeros((0,), I32))
        vals = self._as_values(values, q)
        t0 = time.perf_counter()
        oks, addrs, reps, retries = [], [], [], 0
        for s in range(0, q, self.max_batch):
            o, a, rep, r = self._put_chunk(keys[s:s + self.max_batch],
                                           vals[s:s + self.max_batch])
            oks.append(o)
            addrs.append(a)
            reps.append(rep)
            retries = max(retries, r)
        self.stats["puts"] += q
        tel = self.telemetry
        if tel.enabled:
            tel.count("put_ops", q)
            tel.observe("put", time.perf_counter() - t0)
        self._note_mutations(q)
        return PutResult(jnp.concatenate(oks), jnp.concatenate(addrs),
                         retries, jnp.concatenate(reps))

    def get(self, keys) -> GetResult:
        keys = self._as_keys(keys)
        q = keys.shape[0]
        if q == 0:
            W = getattr(self.backend, "value_words", 1)
            return GetResult(jnp.zeros((0,), I32), jnp.zeros((0,), bool),
                             jnp.zeros((0,), I32), jnp.zeros((0, W), I32),
                             jnp.zeros((0,), bool), jnp.zeros((0,), I32))
        t0 = time.perf_counter()
        outs = [self._get_chunk(keys[s:s + self.max_batch])
                for s in range(0, q, self.max_batch)]
        self.stats["gets"] += q
        res = GetResult(*[jnp.concatenate(p) for p in zip(*outs)])
        tel = self.telemetry
        if tel.enabled:
            tel.count("get_ops", q)
            tel.observe("get", time.perf_counter() - t0)
            # hops == 2: reads served by the second-hop value fetch
            # (degraded-write strays / mirror failover) — the paper's
            # extra RTT the migration pass exists to elide.  The hops
            # lanes are already resolved (the retry loop synced), so
            # this is a cheap host transfer, not an extra dispatch.
            tel.count("hops2_gets",
                      int((np.asarray(res.hops) == 2).sum()))
        return res

    def delete(self, keys) -> DeleteResult:
        keys = self._as_keys(keys)
        q = keys.shape[0]
        if q == 0:
            return DeleteResult(jnp.zeros((0,), bool),
                                jnp.zeros((0,), bool), 0,
                                jnp.zeros((0,), I32))
        t0 = time.perf_counter()
        oks, founds, reps, retries = [], [], [], 0
        for s in range(0, q, self.max_batch):
            o, f, rep, r = self._delete_chunk(keys[s:s + self.max_batch])
            oks.append(o)
            founds.append(f)
            reps.append(rep)
            retries = max(retries, r)
        self.stats["deletes"] += q
        tel = self.telemetry
        if tel.enabled:
            tel.count("delete_ops", q)
            tel.observe("delete", time.perf_counter() - t0)
        self._note_mutations(q)
        return DeleteResult(jnp.concatenate(oks), jnp.concatenate(founds),
                            retries, jnp.concatenate(reps))

    def scan(self, lo, hi, limit: Optional[int] = None) -> ScanResult:
        kd = key_dtype()
        if limit is None:
            limit = getattr(self.backend, "scan_limit", 128)
        if limit <= 0:
            kd_inf = jnp.zeros((0,), kd)
            return ScanResult(kd_inf, jnp.zeros((0,), I32),
                              jnp.zeros((), I32), True, ())
        t0 = time.perf_counter()
        k, a, n, covered = self.backend.scan(
            jnp.asarray(lo, kd), jnp.asarray(hi, kd), limit)
        self.stats["scans"] += 1
        # scan-completeness retry: a group with zero live, unsevered
        # holders answered nothing.  Each rescan is an observation round
        # — paced by _retry_pause under wall-clock leases — so the
        # bounded retries let the lease detector demote the crashed
        # holders (the routing view aligns — retry-AFTER-detection);
        # coverage itself only returns once the operator recovers them,
        # so afterwards we report honestly instead of looping
        budget = min(self.max_retries,
                     max(getattr(self.backend, "lease_misses", 0), 0) + 1)
        tries = 0
        while (not bool(np.asarray(covered).all())) and tries < budget:
            # rescans only help while the detector is still watching a
            # stalled heartbeat; once detection settles (holders already
            # demoted — or oracle-failed), coverage can only return via
            # recovery, so report honestly after ONE round, not five
            if not self.backend.lease_stalled():
                break
            tries += 1
            self.stats["retries"] += 1
            self.telemetry.count("retries")
            self._retry_pause(budget)
            k, a, n, covered = self.backend.scan(
                jnp.asarray(lo, kd), jnp.asarray(hi, kd), limit)
        cov = np.asarray(covered)
        missing = tuple(int(g) for g in np.nonzero(~cov)[0].tolist())
        tel = self.telemetry
        if tel.enabled:
            tel.count("scan_ops")
            tel.observe("scan", time.perf_counter() - t0)
            if missing:
                tel.count("incomplete_scans")
            tel.span({"op": "scan", "limit": limit, "retries": tries,
                      "seconds": time.perf_counter() - t0,
                      "missing_groups": list(missing)})
        lim = min(limit, k.shape[0])
        return ScanResult(k[:lim], a[:lim],
                          jnp.minimum(n, lim).astype(I32),
                          not missing, missing)

    def apply(self) -> None:
        """One asynchronous log->sorted merge round on every backup."""
        self.stats["applies"] += 1
        self.backend.apply_async()

    def drain(self) -> None:
        """Apply ALL pending log entries (SCAN serializability barrier)."""
        self.backend.drain()

    def migrate(self) -> int:
        """Run the background value migration now (degraded-write strays
        move home; GETs drop back to hops == 1).  Returns values moved."""
        moved = self.backend.migrate_values()
        self.stats["migrated"] += moved
        return moved

    def fail_server(self, server: int):
        return self.backend.fail_server(server)

    def sever_server(self, server: int):
        """Crash a server the lease detector must DISCOVER (heartbeats
        severed, routing view untouched) — the fault injector's switch
        for oracle-free failure schedules; LocalBackend raises (its
        liveness is host-side)."""
        return self.backend.sever_server(server)

    def recover_server(self, server: int, **kw):
        """Rebuild + re-admit a server.  Keyword knobs are forwarded to
        the backend (``online=False`` for stop-the-world recovery,
        ``re_replicate=False`` to skip the post-recovery verify on the
        distributed backend)."""
        r = self.backend.recover_server(server, **kw)
        if self.migrate_on_recover:
            self.migrate()
        return r

    def fail_data_server(self, server: int):
        return self.backend.fail_data_server(server)

    def sever_data_server(self, server: int):
        """Crash a DATA server the lease detector must DISCOVER (data
        heartbeats severed, routing view untouched) — the fault
        injector's value-plane switch for oracle-free failure schedules
        schedules; LocalBackend raises (single unreplicated shard)."""
        return self.backend.sever_data_server(server)

    def recover_data_server(self, server: int) -> None:
        self.backend.recover_data_server(server)
        if self.migrate_on_recover:
            self.migrate()

    def start_ticker(self) -> bool:
        """Start the backend's background lease ticker (idle-client
        wall-clock failure detection).  Returns True when one is
        running; False for backends without leases (LocalBackend tracks
        liveness host-side)."""
        fn = getattr(self.backend, "start_ticker", None)
        return bool(fn()) if fn else False

    def stop_ticker(self) -> None:
        fn = getattr(self.backend, "stop_ticker", None)
        if fn:
            fn()

    # -- telemetry ---------------------------------------------------------
    def metrics(self) -> tm.MetricsSnapshot:
        """Typed point-in-time snapshot of the telemetry plane: op
        counters, per-op latency percentiles, and the backend's
        device-side gauges (live servers, pending-log depth, free-queue
        occupancy, ``fq_spill``).  The gauge fetch is the only device
        access telemetry ever makes — and only here, never per op."""
        gauges = {}
        fn = getattr(self.backend, "telemetry_gauges", None)
        if fn is not None and self.telemetry.enabled:
            gauges = fn()
        return self.telemetry.snapshot(gauges=gauges)

    def metrics_text(self) -> str:
        """The snapshot in Prometheus text exposition format."""
        return tm.render_text(self.metrics())

    def dump_trace(self, path) -> None:
        """Write the op-trace ring (``cfg.telemetry="trace"``) as JSON;
        an empty list in the other modes."""
        self.telemetry.dump_trace(path)

    # -- batching / retry internals ---------------------------------------
    def _as_keys(self, keys):
        k = jnp.asarray(keys, key_dtype())
        if k.ndim == 0:
            k = k[None]
        return k

    def _as_values(self, values, q):
        W = getattr(self.backend, "value_words", 1)
        if values is None:
            return jnp.zeros((q, W), I32)
        v = jnp.asarray(values, I32)
        if v.ndim == 0:
            v = v[None]
        if v.ndim == 1:
            v = jnp.tile(v[:, None], (1, W))
        return v

    def _padded_len(self, q: int) -> int:
        p = max(self.batch_quantum, next_pow2(q))
        p = -(-p // self._multiple) * self._multiple
        return min(self.max_batch, p)

    def _pad(self, keys):
        q = keys.shape[0]
        p = self._padded_len(q)
        kp = jnp.zeros((p,), keys.dtype).at[:q].set(keys)
        valid = jnp.zeros((p,), bool).at[:q].set(True)
        return kp, valid

    def _make_room(self):
        """Push-back response between retry rounds: one log->sorted merge
        (frees backup-log ring room) and one GC flush (frees value slots
        still queued on a remote shard)."""
        self.backend.apply_async()
        gc = getattr(self.backend, "gc_round", None)
        if gc:
            gc()

    def _retry_pause(self, budget: Optional[int] = None):
        """Wall-clock leases expire by ELAPSED TIME, not retry count: on
        fast hardware an unpaced retry loop would exhaust max_retries in
        milliseconds, long before a crashed server's lease can expire —
        returning failures the rounds clock used to recover.  Pace the
        loop (the RPC client's backoff) so its remaining retry budget
        spans at least one lease timeout, keeping detection-within-the-
        loop true in BOTH clock modes.  Paces ONLY while the detector is
        actually watching a stalled heartbeat — a healthy push-back
        retry (capacity overflow) stays millisecond-fast.  No-op in
        rounds mode, with detection off, and for lease-less backends."""
        be = self.backend
        if getattr(be, "lease_clock", "") != "wall":
            return
        if getattr(be, "lease_misses", 0) <= 0:
            return
        if not be.lease_stalled():
            return
        # the first stalled round goes unpaced (the stall is only
        # observable after it), so spread the timeout over budget-1
        n = max(budget if budget is not None else self.max_retries, 2)
        time.sleep(be.lease_timeout_s / (n - 1))

    def _put_chunk(self, keys, vals):
        tel = self.telemetry
        tr = tel.tracing
        t0 = time.perf_counter()
        q = keys.shape[0]
        kp, pending = self._pad(keys)
        vp = jnp.zeros((kp.shape[0], vals.shape[1]), vals.dtype
                       ).at[:q].set(vals)
        ev = ([{"phase": "route", "seconds": time.perf_counter() - t0}]
              if tr else None)
        ok_all = jnp.zeros_like(pending)
        addr_all = jnp.full(kp.shape, -1, I32)
        rep_all = jnp.zeros(kp.shape, I32)
        retries = 0
        while True:
            td = time.perf_counter()
            ok, addrs, nrep = self.backend.put(kp, vp, pending)
            newly = pending & ok
            ok_all = ok_all | newly
            addr_all = jnp.where(newly, addrs, addr_all)
            rep_all = jnp.where(newly, nrep, rep_all)
            pending = pending & ~ok
            if tr:
                ev.append({"phase": "dispatch", "try": retries,
                           "seconds": time.perf_counter() - td})
            if not bool(pending.any()) or retries >= self.max_retries:
                break
            retries += 1
            self.stats["retries"] += 1
            tel.count("retries")
            tel.count("pushbacks")   # capacity push-back on a mutation
            self._retry_pause()
            self._make_room()
        if tr:
            tel.span({"op": "put", "n": q, "retries": retries,
                      "seconds": time.perf_counter() - t0, "events": ev})
        return ok_all[:q], addr_all[:q], rep_all[:q], retries

    def _delete_chunk(self, keys):
        tel = self.telemetry
        tr = tel.tracing
        t0 = time.perf_counter()
        q = keys.shape[0]
        kp, pending = self._pad(keys)
        ev = ([{"phase": "route", "seconds": time.perf_counter() - t0}]
              if tr else None)
        acked = jnp.zeros_like(pending)
        found_all = jnp.zeros_like(pending)
        rep_all = jnp.zeros(kp.shape, I32)
        retries = 0
        while True:
            td = time.perf_counter()
            ack, found, nrep = self.backend.delete(kp, pending)
            newly = pending & ack
            acked = acked | newly
            found_all = found_all | (newly & found)
            rep_all = jnp.where(newly, nrep, rep_all)
            pending = pending & ~ack
            if tr:
                ev.append({"phase": "dispatch", "try": retries,
                           "seconds": time.perf_counter() - td})
            if not bool(pending.any()) or retries >= self.max_retries:
                break
            retries += 1
            self.stats["retries"] += 1
            tel.count("retries")
            tel.count("pushbacks")
            self._retry_pause()
            self._make_room()
        if tr:
            tel.span({"op": "delete", "n": q, "retries": retries,
                      "seconds": time.perf_counter() - t0, "events": ev})
        return acked[:q], found_all[:q], rep_all[:q], retries

    def _get_chunk(self, keys):
        tel = self.telemetry
        tr = tel.tracing
        t0 = time.perf_counter()
        q = keys.shape[0]
        kp, pending = self._pad(keys)
        ev = ([{"phase": "route", "seconds": time.perf_counter() - t0}]
              if tr else None)
        addr_all = jnp.full(kp.shape, -1, I32)
        found_all = jnp.zeros_like(pending)
        acc_all = jnp.zeros(kp.shape, I32)
        hops_all = jnp.zeros(kp.shape, I32)
        vals_all = None
        retries = 0
        while True:
            td = time.perf_counter()
            addrs, found, acc, vals, routed, hops = self.backend.get(
                kp, pending)
            if vals_all is None:
                vals_all = jnp.zeros_like(vals)
            newly = pending & routed
            addr_all = jnp.where(newly, addrs, addr_all)
            found_all = found_all | (newly & found)
            acc_all = jnp.where(newly, acc, acc_all)
            hops_all = jnp.where(newly, hops, hops_all)
            vals_all = jnp.where(newly[:, None], vals, vals_all)
            pending = pending & ~routed
            if tr:
                ev.append({"phase": "dispatch", "try": retries,
                           "seconds": time.perf_counter() - td})
            if not bool(pending.any()) or retries >= self.max_retries:
                break
            retries += 1
            self.stats["retries"] += 1
            tel.count("retries")
            self._retry_pause()
        if tr:
            tel.span({"op": "get", "n": q, "retries": retries,
                      "seconds": time.perf_counter() - t0, "events": ev})
        # lanes still pending exhausted the retry budget: reported as
        # un-routed so push-back is distinguishable from a genuine miss
        return (addr_all[:q], found_all[:q], acc_all[:q], vals_all[:q],
                (~pending)[:q], hops_all[:q])

    def _note_mutations(self, n: int):
        if not self.apply_every_n_ops:
            return
        self._mutations_since_apply += n
        if self._mutations_since_apply >= self.apply_every_n_ops:
            self._mutations_since_apply = 0
            self.apply()
