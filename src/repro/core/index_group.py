"""Index group: the unit of hybrid index (paper §3.2).

One group = one hash table (primary server) + ``n_backups`` sorted-index
replicas (backup servers), plus the primary's append-only log and one log
per backup.  Default replication is the paper's choice (Fig. 6b): 1 hash +
2 skiplists.

Write path (§3.2.2): record in the primary log -> replicate the entries to
every backup log -> apply synchronously to the hash table -> (later)
backups apply their logs to the sorted replicas asynchronously, in batches.
SCAN drains the chosen replica's log first (serializability).

Failure handling (§4.3): ``alive`` masks servers.  Primary down -> GETs are
served from a live sorted replica *after consulting its pending log*
(degraded); backup down -> SCANs use the other replica; recovery rebuilds
a hash table from a sorted replica or a sorted replica from the hash table.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import hash_index as hi
from repro.core import log as lg
from repro.core import sorted_index as si
from repro.core.hashing import key_inf
from repro.core.sorted_index import OP_DEL, OP_PUT

I32 = jnp.int32


class IndexGroup(NamedTuple):
    hash: hi.HashIndex          # primary
    plog: lg.UpdateLog          # primary's log
    sorted: si.SortedIndex      # stacked [R, ...] replicas
    blogs: lg.UpdateLog         # stacked [R, ...] backup logs
    alive: jnp.ndarray          # bool [1 + R]: primary, backup_0..R-1


def create(capacity: int, cfg) -> IndexGroup:
    R = cfg.n_backups
    one_sorted = si.create(capacity)
    one_log = lg.create(cfg.log_capacity)
    stack = lambda t: jax.tree.map(
        lambda a: jnp.broadcast_to(a[None], (R,) + a.shape).copy(), t)
    return IndexGroup(
        hash=hi.create(capacity, cfg),
        plog=lg.create(cfg.log_capacity),
        sorted=stack(one_sorted),
        blogs=stack(one_log),
        alive=jnp.ones((1 + R,), bool),
    )


# ---------------------------------------------------------------------------
# Writes
# ---------------------------------------------------------------------------
def put(g: IndexGroup, keys, addrs, cfg, valid=None,
        backups_alive: tuple | None = None) -> tuple:
    """PUT/UPDATE batch.  Mirrors the paper's ordering: primary log ->
    backup logs (the distributed layer does this via collective_permute;
    here the replication is the stacked write) -> hash table update.

    ``backups_alive`` is a static liveness hint: the primary skips pushing
    log entries to dead backups (the paper's observation that PUT speeds
    up under a backup failure); recovery re-syncs from a live replica.
    Returns (group, ok)."""
    q = keys.shape[0]
    if valid is None:
        valid = jnp.ones((q,), bool)
    ops = jnp.where(valid, OP_PUT, 0).astype(jnp.int8)
    plog, ok_log = lg.append(g.plog, keys, addrs, ops, valid)
    # the hash update below is synchronous, so primary-log entries are
    # applied as soon as the batch commits; advancing the prefix keeps the
    # ring's pending window from ever exhausting (entries are retained for
    # recovery/replication, which read positions, not the window).
    plog = plog._replace(applied=plog.tail)
    if backups_alive is None:
        blogs, bok = jax.vmap(
            lambda l: lg.append(l, keys, addrs, ops, valid))(g.blogs)
        ok_rep = bok.all(axis=0)
    else:
        blogs = g.blogs
        ok_rep = jnp.ones_like(valid)
        for r, live in enumerate(backups_alive):
            if not live:
                continue
            one = jax.tree.map(lambda a: a[r], blogs)
            one, okr = lg.append(one, keys, addrs, ops, valid)
            ok_rep = ok_rep & okr
            blogs = jax.tree.map(lambda f, v, r=r: f.at[r].set(v), blogs, one)
    new_hash, ok_hash = hi.insert(g.hash, keys, addrs, cfg, valid)
    # a write is complete only if logged EVERYWHERE and indexed — a full
    # backup log rejects the ack, so the caller (client) drains and retries
    # instead of the replica silently missing the entry
    ok = ok_log & ok_hash & ok_rep & valid
    return g._replace(hash=new_hash, plog=plog, blogs=blogs), ok


def delete(g: IndexGroup, keys, cfg, valid=None) -> tuple:
    q = keys.shape[0]
    if valid is None:
        valid = jnp.ones((q,), bool)
    ops = jnp.where(valid, OP_DEL, 0).astype(jnp.int8)
    addrs = jnp.full((q,), -1, I32)
    plog, ok_log = lg.append(g.plog, keys, addrs, ops, valid)
    plog = plog._replace(applied=plog.tail)  # hash delete is synchronous
    blogs, bok = jax.vmap(lambda l: lg.append(l, keys, addrs, ops, valid))(g.blogs)
    new_hash, found = hi.delete(g.hash, keys, cfg, valid)
    return (g._replace(hash=new_hash, plog=plog, blogs=blogs),
            found & ok_log & bok.all(axis=0))


# ---------------------------------------------------------------------------
# Asynchronous apply (the backup "worker threads")
# ---------------------------------------------------------------------------
def apply_async(g: IndexGroup, cfg, batch: int | None = None) -> IndexGroup:
    """Apply up to ``batch`` pending log entries to every sorted replica."""
    batch = batch or cfg.async_apply_batch

    def one(srt, blog):
        keys, addrs, ops, blog2 = lg.take_pending(blog, batch)
        return si.merge(srt, keys, addrs, ops), blog2

    srt, blogs = jax.vmap(one)(g.sorted, g.blogs)
    return g._replace(sorted=srt, blogs=blogs)


def drain(g: IndexGroup, cfg, max_rounds: int | None = None) -> IndexGroup:
    """Apply ALL pending entries (used before SCAN for serializability).

    Eager callers (max_rounds=None) early-exit as soon as every log is
    empty; traced/SPMD callers pass a fixed round count."""
    if max_rounds is None:
        for _ in range(1 << 16):
            if int(lg.pending_count(g.blogs).max()) == 0:
                break
            g = apply_async(g, cfg)
        return g
    for _ in range(max_rounds):
        g = apply_async(g, cfg)
    return g


# ---------------------------------------------------------------------------
# Reads
# ---------------------------------------------------------------------------
def get(g: IndexGroup, keys, cfg, *, primary_alive: bool | None = None):
    """GET batch.  Primary alive: one-sided hash probe.  Primary down:
    degraded read from the first live sorted replica — pending log entries
    are consulted first (newest wins), then the sorted index.

    ``primary_alive`` is a STATIC routing hint: real clients know server
    liveness (the paper's client routes to the primary or a backup), so
    eager callers skip the unused path entirely; None keeps the branchless
    both-paths select for traced/SPMD use.
    Returns (addr, found, n_accesses)."""
    if primary_alive is True:
        return hi.lookup(g.hash, keys, cfg)
    addr_h, found_h, acc_h = hi.lookup(g.hash, keys, cfg)

    # degraded path via replica 0/1 (vectorised; selected by alive mask)
    rep = jnp.argmax(g.alive[1:])                # first live backup
    srt = jax.tree.map(lambda a: a[rep], g.sorted)
    blog = jax.tree.map(lambda a: a[rep], g.blogs)
    addr_s, found_s, acc_s = si.search(srt, keys, cfg.fanout)
    # pending log scan (newest wins): entries [applied, tail)
    cap = blog.keys.shape[0]
    sl = jnp.arange(cap)
    seq = blog.applied + sl                      # scan window in order
    idx = seq % cap
    pend_valid = seq < blog.tail
    pk = jnp.where(pend_valid, blog.keys[idx], key_inf(blog.keys.dtype))
    po = jnp.where(pend_valid, blog.ops[idx], 0)
    pa = blog.addrs[idx]
    m = pk[None, :] == keys[:, None]             # [Q, cap]
    any_m = m.any(axis=1)
    last = (cap - 1) - jnp.argmax(m[:, ::-1], axis=1)
    hit_op = jnp.where(any_m, po[last], 0)
    hit_addr = jnp.where(any_m & (hit_op == OP_PUT), pa[last], -1)
    addr_d = jnp.where(any_m, hit_addr, addr_s)
    found_d = jnp.where(any_m, hit_op == OP_PUT, found_s)

    if primary_alive is False:
        return addr_d, found_d, acc_s + 1
    primary_ok = g.alive[0]
    addr = jnp.where(primary_ok, addr_h, addr_d)
    found = jnp.where(primary_ok, found_h, found_d)
    acc = jnp.where(primary_ok, acc_h, acc_s + 1)
    return addr, found, acc


def scan(g: IndexGroup, lo, hi_key, limit: int, cfg):
    """SCAN [lo, hi].  Serves from a live sorted replica after draining its
    log (paper: 'worker threads make sure no index updates remain').
    Returns (keys [limit], addrs [limit], count)."""
    g = drain(g, cfg)
    rep = jnp.argmax(g.alive[1:])
    srt = jax.tree.map(lambda a: a[rep], g.sorted)
    return si.range_query(srt, lo, hi_key, limit), g


# ---------------------------------------------------------------------------
# Failures & recovery (§4.3)
# ---------------------------------------------------------------------------
def fail(g: IndexGroup, server: int) -> IndexGroup:
    return g._replace(alive=g.alive.at[server].set(False))


def recover_primary(g: IndexGroup, cfg) -> IndexGroup:
    """Rebuild the hash table from a live sorted replica (drained first)."""
    g = drain(g, cfg)
    rep = jnp.argmax(g.alive[1:])
    srt = jax.tree.map(lambda a: a[rep], g.sorted)
    keys, addrs, valid = si.items(srt)
    fresh = hi.create(srt.keys.shape[0], cfg)
    # insert only valid items: invalid keys hash to garbage buckets but are
    # masked by routing them to an out-of-range bucket via valid gating
    # placeholders: unique NEGATIVE keys (application keys are >= 0)
    junk = -(jnp.arange(keys.shape[0], dtype=keys.dtype) + 2)
    safe_keys = jnp.where(valid, keys, junk)
    new_hash, _ = hi.insert(fresh, safe_keys, jnp.where(valid, addrs, -1), cfg)
    new_hash, _ = hi.delete(new_hash, jnp.where(valid, -1, junk), cfg)
    return g._replace(hash=new_hash, alive=g.alive.at[0].set(True))


def recover_backup(g: IndexGroup, which: int, cfg) -> IndexGroup:
    """Rebuild a sorted replica from the primary's hash table."""
    # the hash index stores (sig, fp, addr) but not the key itself; the
    # paper rebuilds a skiplist by fetching the hash table *and its keys*
    # from the data items.  In the core layer the authoritative key set
    # lives in the surviving replica / log; distributed rebuild fetches it
    # from the kvstore data servers (see kvstore.recover).  Here we copy
    # from a live replica (drained), which is the same data.
    g = drain(g, cfg)
    src = jnp.argmax(g.alive[1:] & (jnp.arange(g.alive.shape[0] - 1) != which))
    srt_src = jax.tree.map(lambda a: a[src], g.sorted)
    new_sorted = jax.tree.map(
        lambda all_r, one: all_r.at[which].set(one), g.sorted, srt_src)
    blog_src = jax.tree.map(lambda a: a[src], g.blogs)
    new_blogs = jax.tree.map(
        lambda all_r, one: all_r.at[which].set(one), g.blogs, blog_src)
    return g._replace(sorted=new_sorted, blogs=new_blogs,
                      alive=g.alive.at[1 + which].set(True))
