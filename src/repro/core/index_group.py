"""Index group: the unit of hybrid index (paper §3.2).

One group = one hash table (primary server) + ``n_backups`` sorted-index
replicas (backup servers), plus the primary's append-only log and one log
per backup.  Default replication is the paper's choice (Fig. 6b): 1 hash +
2 skiplists.

Write path (§3.2.2): record in the primary log -> replicate the entries to
every backup log -> apply synchronously to the hash table -> (later)
backups apply their logs to the sorted replicas asynchronously, in batches.
SCAN drains the chosen replica's log first (serializability).

Failure handling (§4.3): ``alive`` masks servers.  Primary down -> GETs are
served from a live sorted replica *after consulting its pending log*
(degraded); backup down -> SCANs use the other replica; recovery rebuilds
a hash table from a sorted replica or a sorted replica from the hash table.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import hash_index as hi
from repro.core import log as lg
from repro.core import sorted_index as si
from repro.core.hashing import key_inf
from repro.core.sorted_index import OP_DEL, OP_PUT
from repro.kernels import ops as kops

I32 = jnp.int32


class IndexGroup(NamedTuple):
    hash: hi.HashIndex          # primary
    plog: lg.UpdateLog          # primary's log
    sorted: si.SortedIndex      # stacked [R, ...] replicas
    blogs: lg.UpdateLog         # stacked [R, ...] backup logs
    alive: jnp.ndarray          # bool [1 + R]: primary, backup_0..R-1


def create(capacity: int, cfg) -> IndexGroup:
    R = cfg.n_backups
    one_sorted = si.create(capacity)
    one_log = lg.create(cfg.log_capacity)
    stack = lambda t: jax.tree.map(
        lambda a: jnp.broadcast_to(a[None], (R,) + a.shape).copy(), t)
    return IndexGroup(
        hash=hi.create(capacity, cfg),
        plog=lg.create(cfg.log_capacity),
        sorted=stack(one_sorted),
        blogs=stack(one_log),
        alive=jnp.ones((1 + R,), bool),
    )


# ---------------------------------------------------------------------------
# Writes
# ---------------------------------------------------------------------------
def _append_live_blogs(blogs, keys, addrs, ops, valid,
                       backups_alive: tuple | None):
    """Replicate a batch to the backup logs.  ``backups_alive=None`` means
    all-alive (vmapped); otherwise dead backups are skipped — the paper's
    degraded write path — and recovery re-syncs them from a live replica.
    Returns (blogs, ok_rep, nrep): nrep counts the logs that actually
    recorded each lane — the rollback predicate (a slot an existing log
    entry references must never return to the allocator)."""
    if backups_alive is None:
        blogs, bok = jax.vmap(
            lambda l: lg.append(l, keys, addrs, ops, valid))(blogs)
        nrep = (bok & valid[None, :]).sum(axis=0).astype(jnp.int32)
        return blogs, bok.all(axis=0), nrep
    ok_rep = jnp.ones_like(valid)
    nrep = jnp.zeros(valid.shape, jnp.int32)
    for r, live in enumerate(backups_alive):
        if not live:
            continue
        one = jax.tree.map(lambda a: a[r], blogs)
        one, okr = lg.append(one, keys, addrs, ops, valid)
        ok_rep = ok_rep & okr
        nrep = nrep + (okr & valid).astype(jnp.int32)
        blogs = jax.tree.map(lambda f, v, r=r: f.at[r].set(v), blogs, one)
    return blogs, ok_rep, nrep


def put(g: IndexGroup, keys, addrs, cfg, valid=None,
        backups_alive: tuple | None = None, with_nrep: bool = False
        ) -> tuple:
    """PUT/UPDATE batch.  Mirrors the paper's ordering: primary log ->
    backup logs (the distributed layer does this via collective_permute;
    here the replication is the stacked write) -> hash table update.

    ``backups_alive`` is a static liveness hint: the primary skips pushing
    log entries to dead backups (the paper's observation that PUT speeds
    up under a backup failure); recovery re-syncs from a live replica.
    Returns (group, ok) — or (group, ok, nrep) with ``with_nrep``, where
    nrep counts the backup logs that recorded each lane (the data plane's
    rollback predicate and the honest replication report)."""
    q = keys.shape[0]
    if valid is None:
        valid = jnp.ones((q,), bool)
    ops = jnp.where(valid, OP_PUT, 0).astype(jnp.int8)
    plog, ok_log = lg.append(g.plog, keys, addrs, ops, valid)
    # the hash update below is synchronous, so primary-log entries are
    # applied as soon as the batch commits; advancing the prefix keeps the
    # ring's pending window from ever exhausting (entries are retained for
    # recovery/replication, which read positions, not the window).
    plog = plog._replace(applied=plog.tail)
    blogs, ok_rep, nrep = _append_live_blogs(g.blogs, keys, addrs, ops,
                                             valid, backups_alive)
    new_hash, ok_hash = hi.insert(g.hash, keys, addrs, cfg, valid)
    # a write is complete only if logged EVERYWHERE and indexed — a full
    # backup log rejects the ack, so the caller (client) drains and retries
    # instead of the replica silently missing the entry
    ok = ok_log & ok_hash & ok_rep & valid
    g = g._replace(hash=new_hash, plog=plog, blogs=blogs)
    return (g, ok, nrep) if with_nrep else (g, ok)


def delete(g: IndexGroup, keys, cfg, valid=None,
           backups_alive: tuple | None = None,
           primary_alive: bool | None = None) -> tuple:
    """DELETE batch.  ``primary_alive`` is the same STATIC routing hint as
    GET's: True compiles the hash-only path; False/None also run the
    replica probe so ``found`` stays honest while the primary is down."""
    q = keys.shape[0]
    if valid is None:
        valid = jnp.ones((q,), bool)
    ops = jnp.where(valid, OP_DEL, 0).astype(jnp.int8)
    addrs = jnp.full((q,), -1, I32)
    if primary_alive is not True:
        # existence check BEFORE this batch's tombstones land: with the
        # primary down, found comes from the replica + pending log (honest
        # degraded report, same as the distributed temporary-primary path)
        _, found_d, _ = replica_probe(g, keys, cfg)
    plog, ok_log = lg.append(g.plog, keys, addrs, ops, valid)
    plog = plog._replace(applied=plog.tail)  # hash delete is synchronous
    blogs, ok_rep, _ = _append_live_blogs(g.blogs, keys, addrs, ops, valid,
                                          backups_alive)
    new_hash, found_h = hi.delete(g.hash, keys, cfg, valid)
    if primary_alive is True:
        found = found_h
    elif primary_alive is False:
        found = found_d & valid
    else:
        found = jnp.where(g.alive[0], found_h, found_d & valid)
    return (g._replace(hash=new_hash, plog=plog, blogs=blogs),
            found & ok_log & ok_rep)


# ---------------------------------------------------------------------------
# Asynchronous apply (the backup "worker threads")
# ---------------------------------------------------------------------------
def apply_async(g: IndexGroup, cfg, batch: int | None = None) -> IndexGroup:
    """Apply up to ``batch`` pending log entries to every sorted replica."""
    batch = batch or cfg.async_apply_batch

    def one(srt, blog):
        keys, addrs, ops, blog2 = lg.take_pending(blog, batch)
        return kops.merge(cfg, srt, keys, addrs, ops), blog2

    srt, blogs = jax.vmap(one)(g.sorted, g.blogs)
    return g._replace(sorted=srt, blogs=blogs)


def drain(g: IndexGroup, cfg, max_rounds: int | None = None) -> IndexGroup:
    """Apply ALL pending entries (used before SCAN for serializability).

    Eager callers (max_rounds=None) early-exit as soon as every log is
    empty; traced/SPMD callers pass a fixed round count."""
    if max_rounds is None:
        for _ in range(1 << 16):
            if int(lg.pending_count(g.blogs).max()) == 0:
                break
            g = apply_async(g, cfg)
        return g
    for _ in range(max_rounds):
        g = apply_async(g, cfg)
    return g


# ---------------------------------------------------------------------------
# Reads
# ---------------------------------------------------------------------------
def replica_probe(g: IndexGroup, keys, cfg):
    """Degraded lookup via the first live sorted replica: pending log
    entries are consulted first (newest wins), then the sorted index.
    Returns (addr, found, n_accesses)."""
    rep = jnp.argmax(g.alive[1:])                # first live backup
    R = g.alive.shape[0] - 1
    rep_sel = jnp.broadcast_to(
        (jnp.arange(R, dtype=I32)[None, :] == rep).astype(I32),
        (keys.shape[0], R))
    return kops.backup_probe(cfg, g.sorted, g.blogs, keys, rep_sel)


def owner_addr_probe(g: IndexGroup, keys, cfg,
                     primary_alive: bool | None = None):
    """Pre-batch (addr, found) of each key — the value slot a PUT
    overwrite or DELETE must free (the data-server GC's input).
    ``primary_alive=True`` compiles the hash-only path; otherwise the
    hash answer is combined with the replica + pending-log probe, so the
    old slot is still found while the primary's table is wiped (writes
    issued after the failure land in the hash, earlier ones only in the
    replicas — prefer the hash when it knows the key)."""
    a_h, f_h, _ = kops.probe(cfg, g.hash, keys)
    if primary_alive is True:
        return a_h, f_h
    a_d, f_d, _ = replica_probe(g, keys, cfg)
    return jnp.where(f_h, a_h, a_d), f_h | f_d


def get(g: IndexGroup, keys, cfg, *, primary_alive: bool | None = None):
    """GET batch.  Primary alive: one-sided hash probe.  Primary down:
    degraded read from the first live sorted replica — pending log entries
    are consulted first (newest wins), then the sorted index.

    ``primary_alive`` is a STATIC routing hint: real clients know server
    liveness (the paper's client routes to the primary or a backup), so
    eager callers skip the unused path entirely; None keeps the branchless
    both-paths select for traced/SPMD use.
    Returns (addr, found, n_accesses)."""
    if primary_alive is True:
        return kops.probe(cfg, g.hash, keys)
    addr_h, found_h, acc_h = kops.probe(cfg, g.hash, keys)
    addr_d, found_d, acc_d = replica_probe(g, keys, cfg)
    if primary_alive is False:
        return addr_d, found_d, acc_d
    primary_ok = g.alive[0]
    addr = jnp.where(primary_ok, addr_h, addr_d)
    found = jnp.where(primary_ok, found_h, found_d)
    acc = jnp.where(primary_ok, acc_h, acc_d)
    return addr, found, acc


def scan(g: IndexGroup, lo, hi_key, limit: int, cfg):
    """SCAN [lo, hi].  Serves from a live sorted replica after draining its
    log (paper: 'worker threads make sure no index updates remain').
    Returns (keys [limit], addrs [limit], count)."""
    g = drain(g, cfg)
    rep = jnp.argmax(g.alive[1:])
    srt = jax.tree.map(lambda a: a[rep], g.sorted)
    return kops.range_query(cfg, srt, lo, hi_key, limit), g


# ---------------------------------------------------------------------------
# Failures & recovery (§4.3)
# ---------------------------------------------------------------------------
def fail(g: IndexGroup, server: int, wipe: bool = True) -> IndexGroup:
    """Mask a server dead.  ``wipe`` (default) also destroys the index
    state it held — hash + primary log for server 0, the sorted replica +
    backup log for server 1+r — so recovery must genuinely rebuild from
    surviving copies rather than resurrect masked state."""
    g = g._replace(alive=g.alive.at[server].set(False))
    if not wipe:
        return g
    if server == 0:
        h, p = g.hash, g.plog
        return g._replace(
            hash=hi.HashIndex(sig=jnp.zeros_like(h.sig),
                              fp=jnp.zeros_like(h.fp),
                              addr=jnp.full_like(h.addr, -1),
                              fill=jnp.zeros_like(h.fill)),
            plog=lg.UpdateLog(keys=jnp.zeros_like(p.keys),
                              addrs=jnp.full_like(p.addrs, -1),
                              ops=jnp.zeros_like(p.ops),
                              tail=jnp.zeros_like(p.tail),
                              applied=jnp.zeros_like(p.applied)))
    r = server - 1
    s, b = g.sorted, g.blogs
    return g._replace(
        sorted=si.SortedIndex(
            keys=s.keys.at[r].set(key_inf(s.keys.dtype)),
            addrs=s.addrs.at[r].set(-1), size=s.size.at[r].set(0)),
        blogs=lg.UpdateLog(
            keys=b.keys.at[r].set(0), addrs=b.addrs.at[r].set(-1),
            ops=b.ops.at[r].set(0), tail=b.tail.at[r].set(0),
            applied=b.applied.at[r].set(0)))


def recover_primary(g: IndexGroup, cfg, online: bool = True) -> IndexGroup:
    """Rebuild the hash table from a live sorted replica.

    ``online`` (default) rebuilds from an UNDRAINED snapshot plus a
    replay of the replica's pending-log window into the hash (the hash
    is synchronous by contract) — the replica itself catches up through
    the ordinary incremental applies while foreground traffic continues.
    ``online=False`` keeps the stop-the-world drain-first rebuild."""
    if not online:
        g = drain(g, cfg)
    rep = jnp.argmax(g.alive[1:])
    srt = jax.tree.map(lambda a: a[rep], g.sorted)
    keys, addrs, valid = si.items(srt)
    fresh = hi.create(srt.keys.shape[0], cfg)
    # the valid mask keeps empty sorted-array slots out of the table
    # entirely (no appended-then-tombstoned junk eating chain headroom)
    new_hash, _ = hi.insert(fresh, keys, addrs, cfg, valid)
    if online:
        blog = jax.tree.map(lambda a: a[int(rep)], g.blogs)
        new_hash = hi.replay_pending(new_hash, blog, cfg)
    return g._replace(hash=new_hash, alive=g.alive.at[0].set(True))


def recover_backup(g: IndexGroup, which: int, cfg,
                   online: bool = True) -> IndexGroup:
    """Rebuild a sorted replica from the primary's hash table."""
    # the hash index stores (sig, fp, addr) but not the key itself; the
    # paper rebuilds a skiplist by fetching the hash table *and its keys*
    # from the data items.  In the core layer the authoritative key set
    # lives in the surviving replica / log; distributed rebuild fetches it
    # from the kvstore data servers (see kvstore.recover).  Here we copy
    # from a live replica — online as an undrained snapshot WITH its
    # pending log (both copies then stream the same catch-up delta
    # through the ordinary applies), else drained first.
    if not online:
        g = drain(g, cfg)
    src = jnp.argmax(g.alive[1:] & (jnp.arange(g.alive.shape[0] - 1) != which))
    srt_src = jax.tree.map(lambda a: a[src], g.sorted)
    new_sorted = jax.tree.map(
        lambda all_r, one: all_r.at[which].set(one), g.sorted, srt_src)
    blog_src = jax.tree.map(lambda a: a[src], g.blogs)
    new_blogs = jax.tree.map(
        lambda all_r, one: all_r.at[which].set(one), g.blogs, blog_src)
    return g._replace(sorted=new_sorted, blogs=new_blogs,
                      alive=g.alive.at[1 + which].set(True))
