import os
if "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
"""Distributed KV-store self-test: runs the full protocol battery on an
8-device host mesh (spawned as a subprocess by tests/test_kvstore_dist.py
so the main pytest process keeps its single-device view).

Checks: routed PUT/GET roundtrip, value payload integrity, distributed
DELETE round-trip (PUT -> DELETE -> GET miss -> SCAN excludes), SCAN after
async-apply drains, degraded GET under primary failure, degraded PUT via
temporary primary, overflow push-back absorbed by the client's retry loop.
The raw shard_map ops are exercised first, then the same protocol through
HiStoreClient/DistributedBackend (the surface everything else uses).
"""
import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.histore import scaled
from repro.core import kvstore as kv
from repro.core.client import DistributedBackend, HiStoreClient
from repro.core.hashing import key_dtype


def main() -> int:
    cfg = scaled(log_capacity=512, async_apply_batch=128)
    n = len(jax.devices())
    mesh = jax.make_mesh((n,), (kv.AXIS,))
    KD = key_dtype()
    G = n
    store = kv.create(mesh, 4096, cfg)
    ops = kv.make_ops(mesh, cfg, capacity_q=64, scan_limit=128)

    rng = np.random.RandomState(0)
    Q = 32 * G
    keys = jnp.asarray(rng.choice(10 ** 6, Q, replace=False) + 1, KD)
    vals = jnp.tile(jnp.arange(Q, dtype=jnp.int32)[:, None],
                    (1, cfg.value_words))
    all_valid = jnp.ones((Q,), bool)

    # --- PUT roundtrip ----------------------------------------------------
    store, ok, addrs, nrep = ops["put"](store, keys, vals, all_valid)
    assert bool(np.asarray(ok).all()), "put ok"
    assert bool((np.asarray(nrep) == cfg.n_backups).all()), \
        "healthy puts must reach every replica log"
    # --- GET hits with value payloads --------------------------------------
    addr, found, acc, val, routed, vok = ops["get"](store, keys, all_valid)
    assert bool(np.asarray(routed).all()), "get routed"
    assert bool(np.asarray(found).all()), "get found"
    assert bool(np.asarray(vok).all()), "healthy values are owner-local"
    np.testing.assert_array_equal(np.asarray(val)[:, 0], np.arange(Q))
    assert int(np.asarray(acc).max()) <= cfg.max_chain, "one-sided accesses"
    # --- GET misses --------------------------------------------------------
    _, found_m, _, _, _, _ = ops["get"](store, keys + 10 ** 7, all_valid)
    assert not bool(np.asarray(found_m).any()), "get miss"
    # --- valid-mask padding lanes mutate nothing ---------------------------
    half = jnp.arange(Q) < Q // 2
    pad_keys = jnp.where(half, keys + 3 * 10 ** 7, keys)
    store, ok_h, _, _ = ops["put"](store, pad_keys, vals, half)
    assert bool(np.asarray(ok_h)[: Q // 2].all()), "masked put ok"
    _, found_h, _, _, _, _ = ops["get"](store, keys + 3 * 10 ** 7, all_valid)
    assert not bool(np.asarray(found_h)[Q // 2:].any()), \
        "invalid lanes must not be written"
    # --- SCAN (drains logs) -------------------------------------------------
    lo = jnp.full((Q,), 0, KD)
    hi = jnp.full((Q,), 10 ** 7, KD)
    sk, sa, cov, store = ops["scan"](store, lo, hi)
    sk = np.asarray(sk)
    want = np.sort(np.asarray(keys))[:128]
    np.testing.assert_array_equal(sk, want)
    assert bool(np.asarray(cov).all()), "healthy scan must cover all groups"
    print("scan ok")

    # --- distributed DELETE round-trip --------------------------------------
    del_mask = jnp.arange(Q) < G  # drop one key per device's worth
    store, ok_d, found_d, _ = ops["delete"](store, keys, del_mask)
    assert bool(np.asarray(ok_d)[:G].all()), "delete acked"
    assert bool(np.asarray(found_d)[:G].all()), "delete found"
    _, found_after, _, _, _, _ = ops["get"](store, keys, all_valid)
    fa = np.asarray(found_after)
    assert not fa[:G].any(), "deleted keys must miss"
    assert fa[G:].all(), "surviving keys must hit"
    sk2, _, _, store = ops["scan"](store, lo, hi)
    deleted = set(int(k) for k in np.asarray(keys[:G]))
    assert not (set(np.asarray(sk2).tolist()) & deleted), \
        "scan must exclude deleted keys"
    print("delete ok")

    # --- failure: server 2 down (index state WIPED — must rebuild) ---------
    store = kv.fail_server(store, 2)
    assert int(store.hash.fill[2].sum()) == 0, "dead hash must be wiped"
    addr2, found2, acc2, _, _, _ = ops["get"](store, keys[G:],
                                              all_valid[G:])
    assert bool(np.asarray(found2).all()), "degraded get found"
    # degraded lookups of group-2 keys go through the sorted replica + its
    # pending log: their access count is exactly the directory depth + 1,
    # strictly above the single-sub-bucket hash read of healthy groups
    from repro.core import sorted_index as six
    degraded_cost = six.directory_levels(4096, cfg.fanout) + 1
    own = np.asarray(kv.owner_group(keys[G:], G))
    assert int(np.asarray(acc2)[own == 2].min()) == degraded_cost, \
        "degraded reads must pay the sorted+log path"
    assert int(np.asarray(acc2)[own != 2].max()) < degraded_cost, \
        "healthy reads must stay on the one-sided hash path"
    # --- degraded PUT (temporary primary) ----------------------------------
    nk = jnp.asarray(rng.choice(10 ** 6, 64, replace=False) + 2 * 10 ** 7, KD)
    nv = jnp.tile(jnp.arange(64, dtype=jnp.int32)[:, None],
                  (1, cfg.value_words))
    nvalid = jnp.ones((64,), bool)
    store, ok3, _, nrep3 = ops["put"](store, nk, nv, nvalid)
    assert bool(np.asarray(ok3).all()), "degraded put ok"
    # groups whose replica holder (or temporary primary chain) includes the
    # dead device report honestly-reduced replication
    own3 = np.asarray(kv.owner_group(nk, G))
    nrep3 = np.asarray(nrep3)
    hit = np.isin(own3, [0, 1])   # dev 2 holds replica 1 of g0, replica 0 of g1
    assert (nrep3[hit] == cfg.n_backups - 1).all(), \
        "writes touching the dead holder must report reduced replication"
    assert (nrep3[own3 == 2] == cfg.n_backups).all(), \
        "temporary primary still reaches both surviving replica logs"
    assert (nrep3[~hit & (own3 != 2)] == cfg.n_backups).all(), \
        "unaffected groups keep full replication"
    addr3, found3, _, _, _, _ = ops["get"](store, nk, nvalid)
    assert bool(np.asarray(found3).all()), "degraded put visible to get"
    # --- scans still complete under failure ---------------------------------
    sk3, _, cov3, store = ops["scan"](store, lo, hi)
    np.testing.assert_array_equal(np.asarray(sk3), np.asarray(sk2))
    assert bool(np.asarray(cov3).all()), \
        "a single failure leaves every group >= 1 live holder: covered"
    # --- recovery: rebuild hash from replica, re-clone replicas -------------
    store = kv.recover_server(store, 2, cfg)
    assert int(store.hash.fill[2].sum()) > 0, "recovery must rebuild hash"
    addr4, found4, acc4, _, _, _ = ops["get"](store, keys[G:],
                                              all_valid[G:])
    assert bool(np.asarray(found4).all()), "post-recovery get"
    assert all(p["agree"] for p in kv.parity_report(store, cfg)), \
        "hash/sorted parity must hold after recovery"
    print("raw ops ok")

    # ------------------------------------------------------------------
    # The same protocol through the unified client (what callers use)
    # ------------------------------------------------------------------
    client = HiStoreClient(
        DistributedBackend(mesh, cfg, 4096, capacity_q=2, scan_limit=128),
        batch_quantum=8 * G, max_retries=32)
    ck = rng.choice(10 ** 6, 300, replace=False) + 4 * 10 ** 7
    res = client.put(ck, np.arange(300))
    # capacity_q=2 (2 slots per sender/destination pair) with ~5 requests
    # per pair forces exchange overflow -> client-side retry rounds
    assert res.all_ok, "client put all acked under overflow"
    assert res.retries > 0, "overflow must have engaged the retry loop"
    g = client.get(ck)
    assert g.all_found, "client get"
    np.testing.assert_array_equal(np.asarray(g.values)[:, 0], np.arange(300))
    d = client.delete(ck[:50])
    assert bool(d.ok.all()) and bool(d.found.all()), "client delete"
    g2 = client.get(ck[:50])
    assert not bool(g2.found.any()), "client get-after-delete miss"
    s = client.scan(4 * 10 ** 7, 10 ** 8)
    got = set(np.asarray(s.keys[: int(s.count)]).tolist())
    assert not (got & set(int(k) for k in ck[:50])), "client scan excludes"
    client.fail_server(1)
    g3 = client.get(ck[50:])
    assert g3.all_found, "client degraded get"
    np.testing.assert_array_equal(np.asarray(g3.values)[:, 0],
                                  np.arange(300)[50:],
                                  "degraded reads fetch values by address")
    # writes during the failure: reduced replication is reported honestly
    wk = rng.choice(10 ** 6, 200, replace=False) + 6 * 10 ** 7
    w = client.put(wk, np.arange(200))
    assert w.all_ok
    wown = np.asarray(kv.owner_group(jnp.asarray(wk, KD), G))
    wrep = np.asarray(w.replicas)
    whit = np.isin(wown, [7, 0])  # dev 1 holds replica 0 of g0, replica 1 of g7
    assert (wrep[whit] == cfg.n_backups - 1).all(), "reduced replication"
    assert (wrep[~whit & (wown != 1)] == cfg.n_backups).all()
    client.recover_server(1)
    g4 = client.get(np.concatenate([ck[50:], wk]))
    assert g4.all_found, "post-recovery client get"
    np.testing.assert_array_equal(
        np.asarray(g4.values)[:, 0],
        np.concatenate([np.arange(300)[50:], np.arange(200)]))
    assert all(p["agree"]
               for p in kv.parity_report(client.backend.store, cfg)), \
        "client-side recovery must restore parity"
    print("client ops ok")

    # --- R=3 scan serve-duty: alive-dead-alive must not double-serve --------
    # with three sorted replicas per group, killing the MIDDLE holder
    # leaves replicas 0 and 2 alive; exactly one may serve (the
    # regression: serve-duty only checked the immediately-lower holder,
    # so the ladder emitted the group's keys twice and inflated count)
    cfg3 = scaled(log_capacity=512, async_apply_batch=128, n_backups=3,
                  lease_clock="rounds")
    client3 = HiStoreClient(
        DistributedBackend(mesh, cfg3, 512, capacity_q=64,
                           scan_limit=512), batch_quantum=4 * G)
    k3 = np.random.RandomState(3).choice(10 ** 6, 12 * G,
                                         replace=False) + 1
    assert client3.put(k3, np.arange(12 * G)).all_ok
    client3.drain()
    import warnings as _w
    with _w.catch_warnings():
        _w.simplefilter("ignore")
        client3.sever_server(3)      # middle holder of group 1 (2,3,4)
    s3 = client3.scan(0, 10 ** 7, limit=512)
    ks3 = np.asarray(s3.keys)[: int(s3.count)]
    assert len(set(ks3.tolist())) == len(ks3), \
        "R=3 alive-dead-alive scan emitted duplicate keys"
    assert int(s3.count) == 12 * G, \
        f"R=3 scan count {int(s3.count)} != {12 * G}"
    assert s3.complete is True, "one live holder per group -> complete"
    print("R=3 scan serve-duty ok (no double-serve, count exact)")

    print("DIST-SELFTEST-OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
