import os
if "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
"""Distributed KV-store self-test: runs the full protocol battery on an
8-device host mesh (spawned as a subprocess by tests/test_kvstore_dist.py
so the main pytest process keeps its single-device view).

Checks: routed PUT/GET roundtrip, value payload integrity, SCAN after
async-apply drains, degraded GET under primary failure, degraded PUT via
temporary primary, replication layout (replica logs land on the right
devices), overflow push-back.
"""
import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.histore import scaled
from repro.core import kvstore as kv
from repro.core.hashing import key_dtype


def main() -> int:
    cfg = scaled(log_capacity=512, async_apply_batch=128)
    n = len(jax.devices())
    mesh = jax.make_mesh((n,), (kv.AXIS,))
    KD = key_dtype()
    G = n
    store = kv.create(mesh, 4096, cfg)
    ops = kv.make_ops(mesh, cfg, capacity_q=64, scan_limit=128)

    rng = np.random.RandomState(0)
    Q = 32 * G
    keys = jnp.asarray(rng.choice(10 ** 6, Q, replace=False) + 1, KD)
    vals = jnp.tile(jnp.arange(Q, dtype=jnp.int32)[:, None],
                    (1, cfg.value_words))
    zero_addr = jnp.zeros((Q,), jnp.int32)

    # --- PUT roundtrip ----------------------------------------------------
    store, ok, addrs = ops["put"](store, keys, zero_addr, vals)
    assert bool(np.asarray(ok).all()), "put ok"
    # --- GET hits with value payloads --------------------------------------
    addr, found, acc, val = ops["get"](store, keys)
    assert bool(np.asarray(found).all()), "get found"
    np.testing.assert_array_equal(np.asarray(val)[:, 0], np.arange(Q))
    assert int(np.asarray(acc).max()) <= cfg.max_chain, "one-sided accesses"
    # --- GET misses --------------------------------------------------------
    _, found_m, _, _ = ops["get"](store, keys + 10 ** 7)
    assert not bool(np.asarray(found_m).any()), "get miss"
    # --- SCAN (drains logs) -------------------------------------------------
    lo = jnp.full((Q,), 0, KD)
    hi = jnp.full((Q,), 10 ** 7, KD)
    sk, sa, store = ops["scan"](store, lo, hi)
    sk = np.asarray(sk)
    want = np.sort(np.asarray(keys))[:128]
    np.testing.assert_array_equal(sk, want)
    print("scan ok")

    # --- failure: primary of device 2 down ---------------------------------
    store = kv.fail_server(store, 2)
    addr2, found2, acc2, _ = ops["get"](store, keys)
    assert bool(np.asarray(found2).all()), "degraded get found"
    # degraded lookups of group 2 keys cost more accesses (sorted+log path)
    own = np.asarray(kv.owner_group(keys, G))
    assert int(np.asarray(acc2)[own == 2].min()) > int(
        np.asarray(acc2)[own != 2].max() and 0), "degraded acc"
    # --- degraded PUT (temporary primary) ----------------------------------
    nk = jnp.asarray(rng.choice(10 ** 6, 64, replace=False) + 2 * 10 ** 7, KD)
    nv = jnp.tile(jnp.arange(64, dtype=jnp.int32)[:, None],
                  (1, cfg.value_words))
    store, ok3, _ = ops["put"](store, nk, jnp.zeros((64,), jnp.int32), nv)
    assert bool(np.asarray(ok3).all()), "degraded put ok"
    addr3, found3, _, _ = ops["get"](store, nk)
    assert bool(np.asarray(found3).all()), "degraded put visible to get"
    # --- scans still complete under failure ---------------------------------
    sk2, _, store = ops["scan"](store, lo, hi)
    np.testing.assert_array_equal(np.asarray(sk2), want)
    # --- recovery ------------------------------------------------------------
    store = kv.recover_server(store, 2)
    addr4, found4, acc4, _ = ops["get"](store, keys)
    assert bool(np.asarray(found4).all()), "post-recovery get"

    print("DIST-SELFTEST-OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
