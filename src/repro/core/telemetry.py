"""Ops telemetry plane: latency histograms, counters, gauges, op traces.

The paper's core claim is a latency decomposition (indexing is up to 74%
of op latency; §6 reports percentiles, not means), yet the repro could
only report mean wall-clock per figure script and had no visibility into
how often the degraded paths actually fire (retries, second-hop GETs,
lease demotions).  This module is the one low-overhead plane the whole
stack reports through:

  * ``LatencyHistogram`` — log2-bucketed (1 µs granularity floor) with a
    fixed numpy bucket array: ``record()`` is allocation-free on the hot
    path (one integer bit-length + three scalar updates), percentiles
    (p50/p95/p99/max) are extracted at snapshot time;
  * ``Telemetry`` — counters + per-op histograms + a bounded ring-buffer
    op-trace recorder, keyed on ``cfg.telemetry``:
        "off"       record/observe/span are no-ops; a snapshot taken
                    before equals one taken after any workload;
        "counters"  counters + latency histograms (the default);
        "trace"     counters + histograms + per-op spans
                    (route → dispatch → retries → detection events) in a
                    ring buffer dumpable to JSON for forensics;
  * ``MetricsSnapshot`` — the typed result of ``client.metrics()``, with
    ``render_text`` producing Prometheus text exposition format for
    ``client.metrics_text()``.

Gauges (pending-log depth, free-queue occupancy, live servers,
``fq_spill``) are NOT sampled on the hot path: backends surface them
lazily at snapshot time via ``telemetry_gauges()`` (one device fetch),
so enabling telemetry never adds a device sync to an op body.
"""
from __future__ import annotations

import json
import math
import threading
from typing import NamedTuple, Optional

import numpy as np

MODES = ("off", "counters", "trace")

# log2 buckets over microseconds: bucket 0 is < 1 µs, bucket i >= 1 is
# [2^(i-1), 2^i) µs; 48 buckets reach ~1.6e8 s — any op fits
N_BUCKETS = 48


class LatencySnapshot(NamedTuple):
    """Percentile summary of one op's latency histogram (seconds)."""
    count: int
    total: float
    mean: float
    p50: float
    p95: float
    p99: float
    max: float


class LatencyHistogram:
    """Log-bucketed latency histogram with an allocation-free record
    path: a preallocated int64 bucket array plus three scalars.  NOT
    thread-safe on its own — ``Telemetry`` serializes access."""

    __slots__ = ("buckets", "n", "total", "max")

    def __init__(self):
        self.buckets = np.zeros((N_BUCKETS,), np.int64)
        self.n = 0
        self.total = 0.0
        self.max = 0.0

    def record(self, seconds: float) -> None:
        us = seconds * 1e6
        i = int(us).bit_length() if us >= 1.0 else 0
        if i >= N_BUCKETS:
            i = N_BUCKETS - 1
        self.buckets[i] += 1
        self.n += 1
        self.total += seconds
        if seconds > self.max:
            self.max = seconds

    def percentile(self, q: float) -> float:
        """Upper bucket edge containing the q-quantile (conservative:
        never under-reports), clipped to the exact observed max."""
        if self.n == 0:
            return 0.0
        target = max(1, math.ceil(q * self.n))
        c = 0
        for i in range(N_BUCKETS):
            c += int(self.buckets[i])
            if c >= target:
                return min(2.0 ** i * 1e-6, self.max)
        return self.max

    def snapshot(self) -> LatencySnapshot:
        n = self.n
        return LatencySnapshot(
            count=n, total=self.total,
            mean=self.total / n if n else 0.0,
            p50=self.percentile(0.50), p95=self.percentile(0.95),
            p99=self.percentile(0.99), max=self.max)


class OpTrace:
    """Bounded ring buffer of op spans (plain dicts): the newest
    ``capacity`` spans survive, the oldest are overwritten — forensics
    memory stays O(capacity) no matter how long the client runs."""

    __slots__ = ("capacity", "_buf", "_next", "_n")

    def __init__(self, capacity: int = 256):
        self.capacity = max(int(capacity), 1)
        self._buf: list = [None] * self.capacity
        self._next = 0
        self._n = 0

    def record(self, span: dict) -> None:
        self._buf[self._next] = span
        self._next = (self._next + 1) % self.capacity
        self._n = min(self._n + 1, self.capacity)

    def spans(self) -> list:
        """Oldest-to-newest list of recorded spans."""
        if self._n < self.capacity:
            return [s for s in self._buf[:self._n]]
        return self._buf[self._next:] + self._buf[:self._next]

    def __len__(self) -> int:
        return self._n


class MetricsSnapshot(NamedTuple):
    """Typed result of ``client.metrics()``: a point-in-time copy —
    mutating the live telemetry after a snapshot never changes it."""
    mode: str
    counters: dict
    gauges: dict
    latency: dict          # op name -> LatencySnapshot
    trace_len: int

    def to_dict(self) -> dict:
        return {"mode": self.mode, "counters": dict(self.counters),
                "gauges": dict(self.gauges),
                "latency": {k: s._asdict() for k, s in
                            sorted(self.latency.items())},
                "trace_len": self.trace_len}


class Telemetry:
    """The per-backend metrics plane.  All mutators early-return in
    "off" mode before touching any state, so the off-mode hot path is a
    single attribute load + branch and a snapshot can never drift."""

    __slots__ = ("mode", "enabled", "tracing", "_lock", "_counters",
                 "_hists", "_trace")

    def __init__(self, mode: str = "counters",
                 trace_capacity: int = 256):
        if mode not in MODES:
            raise ValueError(
                f"cfg.telemetry must be one of {MODES}, got {mode!r}")
        self.mode = mode
        self.enabled = mode != "off"
        self.tracing = mode == "trace"
        self._lock = threading.Lock()   # ticker thread vs foreground
        self._counters: dict[str, int] = {}
        self._hists: dict[str, LatencyHistogram] = {}
        self._trace = OpTrace(trace_capacity) if self.tracing else None

    # -- hot-path mutators -------------------------------------------------
    def count(self, name: str, n: int = 1) -> None:
        if not self.enabled or n == 0:
            return
        with self._lock:
            self._counters[name] = self._counters.get(name, 0) + int(n)

    def observe(self, op: str, seconds: float) -> None:
        if not self.enabled:
            return
        with self._lock:
            h = self._hists.get(op)
            if h is None:
                h = self._hists[op] = LatencyHistogram()
            h.record(seconds)

    def span(self, span: dict) -> None:
        """Record one op-trace span (trace mode only).  Spans are plain
        dicts; the client records {op, n, retries, seconds, events} and
        backends append detection events through the same ring."""
        if not self.tracing:
            return
        with self._lock:
            self._trace.record(span)

    # -- read side ---------------------------------------------------------
    def counter(self, name: str) -> int:
        with self._lock:
            return self._counters.get(name, 0)

    def snapshot(self, gauges: Optional[dict] = None) -> MetricsSnapshot:
        with self._lock:
            return MetricsSnapshot(
                mode=self.mode, counters=dict(self._counters),
                gauges=dict(gauges or {}),
                latency={k: h.snapshot() for k, h in self._hists.items()},
                trace_len=len(self._trace) if self._trace else 0)

    def trace_spans(self) -> list:
        with self._lock:
            return self._trace.spans() if self._trace else []

    def dump_trace(self, path) -> None:
        with open(path, "w") as f:
            json.dump(self.trace_spans(), f, indent=2, default=str)


def render_text(snap: MetricsSnapshot) -> str:
    """Prometheus text exposition format for a snapshot: counters as
    ``histore_<name>_total``, gauges as ``histore_<name>``, latency
    histograms as one summary family with per-op labels."""
    lines = [f"# histore telemetry (mode={snap.mode})"]
    for name in sorted(snap.counters):
        lines.append(f"# TYPE histore_{name}_total counter")
        lines.append(f"histore_{name}_total {snap.counters[name]}")
    for name in sorted(snap.gauges):
        lines.append(f"# TYPE histore_{name} gauge")
        lines.append(f"histore_{name} {snap.gauges[name]}")
    if snap.latency:
        lines.append("# TYPE histore_op_latency_seconds summary")
        for op in sorted(snap.latency):
            s = snap.latency[op]
            for q, v in (("0.5", s.p50), ("0.95", s.p95),
                         ("0.99", s.p99)):
                lines.append(f'histore_op_latency_seconds'
                             f'{{op="{op}",quantile="{q}"}} {v:.9g}')
            lines.append(f'histore_op_latency_seconds_count'
                         f'{{op="{op}"}} {s.count}')
            lines.append(f'histore_op_latency_seconds_sum'
                         f'{{op="{op}"}} {s.total:.9g}')
    return "\n".join(lines) + "\n"


def dump_metrics(snap: MetricsSnapshot, path) -> None:
    """Write a snapshot as JSON — the batteries drop one into
    ``test-logs/`` so a hung or failed 8-device run ships its counter
    state with the CI failure artifacts."""
    with open(path, "w") as f:
        json.dump(snap.to_dict(), f, indent=2, default=str)
