"""Typed result objects of the unified HiStoreClient API (DESIGN.md
§Client API).

All array leaves are trimmed to the caller's request length Q — the client
pads batches to fixed shapes internally, and padding lanes never leak out.
These are NamedTuples, so they are pytrees (jax.block_until_ready and
jax.tree.map work on them) and remain positionally compatible with the old
raw tuples: GetResult unpacks as (addrs, found, accesses, ...) exactly like
the previous ``index_group.get`` return, and ScanResult as (keys, addrs,
count) like ``sorted_index.range_query``.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax.numpy as jnp


class PutResult(NamedTuple):
    ok: jnp.ndarray       # bool [Q]: acknowledged (logged + indexed)
    addrs: jnp.ndarray    # int32 [Q]: value address assigned by the store
    retries: int          # overflow-retry rounds this batch needed
    replicas: Optional[jnp.ndarray] = None
    # int32 [Q]: replica logs that recorded the entry; < n_backups is the
    # honest report of reduced replication under a backup failure (§4.3)

    @property
    def all_ok(self) -> bool:
        return bool(self.ok.all())


class GetResult(NamedTuple):
    addrs: jnp.ndarray     # int32 [Q]: value address (-1 on miss)
    found: jnp.ndarray     # bool [Q]
    accesses: jnp.ndarray  # int32 [Q]: index-side memory reads (Fig. 3)
    values: jnp.ndarray    # int32 [Q, value_words]: payload (zeros on miss)
    routed: Optional[jnp.ndarray] = None
    # bool [Q]: the request reached its server within max_retries; a
    # False lane is exchange push-back, NOT an authoritative miss
    hops: Optional[jnp.ndarray] = None
    # int32 [Q]: index-server round-trips the value read took — 1 on the
    # one-sided fast path, 2 when a second-hop fetch chased the value to
    # another shard (degraded-write stray / dead data server).  The
    # measurable cost background value migration removes (DESIGN.md
    # §Data plane); benchmarks read it instead of inferring fetch rates.

    @property
    def all_found(self) -> bool:
        return bool(self.found.all())

    @property
    def one_rtt(self) -> bool:
        """True when every found value was served without a second hop."""
        if self.hops is None:
            return True
        return bool((jnp.asarray(self.hops) <= 1).all())


class DeleteResult(NamedTuple):
    ok: jnp.ndarray       # bool [Q]: tombstone recorded
    found: jnp.ndarray    # bool [Q]: key existed in the primary index (or,
                          # degraded, in the temporary primary's replica)
    retries: int
    replicas: Optional[jnp.ndarray] = None   # as PutResult.replicas


class ScanResult(NamedTuple):
    keys: jnp.ndarray     # [limit] ascending; key_inf-padded past ``count``
    addrs: jnp.ndarray    # int32 [limit]
    count: jnp.ndarray    # int32 scalar: live entries in [lo, hi]
    complete: Optional[bool] = None
    # False when some group had ZERO live, unsevered holders during the
    # scan — its range silently contributed nothing, so ``keys``/``count``
    # under-report.  The client retries a few observation rounds first
    # (so the lease detector aligns the routing view), then reports
    # honestly instead of pretending the store answered.  None on legacy
    # constructions that carry no coverage information.
    missing_groups: tuple = ()
    # the group ids a False ``complete`` names (empty when complete)

    @property
    def is_complete(self) -> bool:
        """True unless the scan is KNOWN to have missed a group."""
        return self.complete is not False


class FailResult(NamedTuple):
    """Outcome of a fail/sever kill switch — surfaces the capability the
    backend actually exercised instead of diverging silently."""
    server: int
    wiped: bool           # False on a 1-device mesh: every replica lives
    # on the failing device, so no surviving copy could exist and the
    # failure degrades to mask-only (state intact) — explicit, and also
    # warned about, rather than silently weaker semantics


class RecoverResult(NamedTuple):
    """Outcome of a recovery: how it rebuilt and what else it repaired."""
    server: int
    online: bool          # snapshot-clone + streamed log catch-up (True)
    #                       vs stop-the-world drain-then-clone
    re_replicated: int    # replica copies the post-recovery
    #                       re-replication pass rebuilt (multi-failure
    #                       window closed before the next failure)
    catch_up_pending: int  # log entries still streaming into the rebuilt
    #                       replicas when recovery returned (0 for
    #                       offline recovery: the drain already ran)
