"""The Backend protocol: the one typed contract between HiStoreClient
and a store implementation.

The client (core/client.py) types against THIS protocol only — it never
imports LocalBackend/DistributedBackend internals; both implement every
member below, so client-side ``getattr`` feature probes are gone.  A
custom backend that provides these members (``isinstance(be, Backend)``
— the protocol is runtime-checkable) plugs straight into HiStoreClient.

Three member groups:

  * serving ops — fixed-shape batch ``put``/``get``/``delete``/``scan``
    plus the async-apply hooks (``apply_async``/``drain``) and the
    background value migration (``migrate_values``);
  * observability — ``telemetry_gauges`` (device-side gauge snapshot)
    and ``lease_stalled`` (did the last observation round see a
    not-yet-demoted server's heartbeat stalled?  LocalBackend liveness
    is host-side, so it simply returns False);
  * fault injection / recovery — ``fail_*`` (detected failures: the
    routing view updates immediately), ``sever_*`` (crashes the lease
    detector must DISCOVER; backends without a lease detector raise
    NotImplementedError), ``recover_*``.
"""
from __future__ import annotations

from typing import Protocol, Tuple, runtime_checkable

import jax.numpy as jnp


@runtime_checkable
class Backend(Protocol):
    """Fixed-shape batch ops over one store.  All mutating ops take a
    ``valid`` lane mask (padding lanes mutate nothing and consume no
    routing capacity).  ``put`` returns (acked, addrs, replicas) and
    ``delete`` (acked, found, replicas) so the client can retry push-back
    without re-writing and report replication honestly; ``get`` returns
    (addrs, found, accesses, vals, routed, hops); ``scan`` returns
    (keys, addrs, count, covered) where covered[g] is False for a group
    with zero live, unsevered holders (the scan-completeness flag)."""

    batch_multiple: int   # padded batch sizes must divide by this
    value_words: int      # payload width W of values [Q, W]

    # -- serving ops -------------------------------------------------------
    def put(self, keys, vals, valid) -> Tuple[
        jnp.ndarray, jnp.ndarray, jnp.ndarray]: ...
    def get(self, keys, valid) -> tuple: ...
    def delete(self, keys, valid) -> Tuple[
        jnp.ndarray, jnp.ndarray, jnp.ndarray]: ...
    def scan(self, lo, hi, limit: int) -> tuple: ...
    def apply_async(self) -> None: ...
    def drain(self) -> None: ...
    def migrate_values(self) -> int: ...

    # -- observability -----------------------------------------------------
    def telemetry_gauges(self) -> dict: ...
    def lease_stalled(self) -> bool: ...

    # -- fault injection / recovery ---------------------------------------
    def fail_server(self, server: int): ...
    def sever_server(self, server: int): ...
    def recover_server(self, server: int, **kw): ...
    def fail_data_server(self, server: int): ...
    def sever_data_server(self, server: int): ...
    def recover_data_server(self, server: int): ...
