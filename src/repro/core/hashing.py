"""Key hashing for the hybrid index.

Keys are int64 (the paper's 16 B string keys are handled by the data layer's
key codec — see DESIGN.md §Key codec).  All mixing is 32-bit (murmur3
fmix32 over the two int32 halves) so the same hash runs unchanged inside
the Pallas TPU kernels (TPU int64 support is limited).

A slot stores a 31-bit odd signature (never 0 = empty, never -1 =
tombstone) plus an independent 32-bit fingerprint; together they stand in
for the paper's {1 B signature + exact-key check} with a ~2^-62 per-slot
false-positive rate (adaptation noted in DESIGN.md).
"""
from __future__ import annotations

import jax.numpy as jnp

U32 = jnp.uint32
I32 = jnp.int32


def key_dtype():
    """Canonical key dtype: int64 when x64 is enabled (full 16 B-key codec
    realism, used by the benchmarks), else int32 (default JAX x32 mode —
    unit tests and the serving page-table, which packs (seq, page) into
    int32)."""
    import jax
    return jnp.int64 if jax.config.jax_enable_x64 else jnp.int32


def key_inf(dtype=None):
    """Max key value, reserved as the 'empty' sentinel of sorted indexes.
    Application keys must be non-negative and < key_inf."""
    return jnp.iinfo(dtype or key_dtype()).max


def fmix32(x):
    """murmur3 finalizer; x: uint32 array."""
    x = x.astype(U32)
    x = x ^ (x >> 16)
    x = x * jnp.uint32(0x85EBCA6B)
    x = x ^ (x >> 13)
    x = x * jnp.uint32(0xC2B2AE35)
    x = x ^ (x >> 16)
    return x


def key_mix(keys):
    """keys: int64 or int32 -> (h1, h2) uint32 mixes."""
    if keys.dtype == jnp.int64:
        k = keys.astype(jnp.uint64)
        lo = (k & jnp.uint64(0xFFFFFFFF)).astype(U32)
        hi = (k >> jnp.uint64(32)).astype(U32)
    else:
        lo = keys.astype(U32)
        hi = jnp.zeros_like(lo)
    h1 = fmix32(lo ^ fmix32(hi ^ jnp.uint32(0x9E3779B9)))
    h2 = fmix32(hi ^ fmix32(lo ^ jnp.uint32(0x85EBCA77)))
    return h1, h2


def bucket_of(keys, n_buckets: int):
    """n_buckets must be a power of two."""
    h1, _ = key_mix(keys)
    return (h1 & jnp.uint32(n_buckets - 1)).astype(I32)


def sig_fp_of(keys):
    """(signature, fingerprint): sig is positive odd int32 (!=0, !=-1)."""
    h1, h2 = key_mix(keys)
    sig = (((h1 >> 1) | jnp.uint32(1)) & jnp.uint32(0x7FFFFFFF)).astype(I32)
    fp = h2.astype(I32)
    return sig, fp


def next_pow2(n: int) -> int:
    p = 1
    while p < n:
        p <<= 1
    return p


def pad_pow2(arr, fill):
    """Pad a 1-D host array to the next power of two (bounds eager-jit
    recompiles of the recovery batches to log2 distinct shapes).
    Returns (padded jnp array, valid mask)."""
    import numpy as np

    arr = np.asarray(arr)
    n = len(arr)
    p = next_pow2(max(n, 1))
    out = np.full((p,), fill, arr.dtype)
    out[:n] = arr
    return jnp.asarray(out), jnp.asarray(np.arange(p) < n)
