"""Append-only update log (paper §3.2.2).

Each entry is {key, value address, op}; the paper's per-entry "isApplied"
mark is realised as the ``applied`` prefix pointer (entries are applied to
the sorted index strictly in order, so a prefix pointer is equivalent and
cheaper — noted in DESIGN.md).  The log is a ring: capacity bounds the
number of *pending* (appended-but-unapplied) entries; the engine forces an
apply when a batch would overflow.
"""
from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp

from repro.core.hashing import key_dtype, key_inf

I32 = jnp.int32


class UpdateLog(NamedTuple):
    keys: jnp.ndarray     # int64 [cap]
    addrs: jnp.ndarray    # int32 [cap]
    ops: jnp.ndarray      # int8  [cap]   (0 invalid / 1 PUT / 2 DEL)
    tail: jnp.ndarray     # int32 scalar: total appended
    applied: jnp.ndarray  # int32 scalar: prefix applied to the sorted index


def create(capacity: int, dtype=None) -> UpdateLog:
    return UpdateLog(
        keys=jnp.zeros((capacity,), dtype or key_dtype()),
        addrs=jnp.full((capacity,), -1, I32),
        ops=jnp.zeros((capacity,), jnp.int8),
        tail=jnp.zeros((), I32),
        applied=jnp.zeros((), I32),
    )


def append(log: UpdateLog, keys, addrs, ops, valid=None) -> tuple:
    """Append a batch.  Returns (log, ok): ok=False entries were rejected
    because the pending window would overflow (engine must drain first)."""
    cap = log.keys.shape[0]
    q = keys.shape[0]
    if valid is None:
        valid = jnp.ones((q,), bool)
    offsets = jnp.cumsum(valid.astype(I32)) - 1
    pending = log.tail - log.applied
    fits = valid & (pending + offsets + 1 <= cap)
    slot = jnp.where(fits, (log.tail + offsets) % cap, cap)
    new = UpdateLog(
        keys=log.keys.at[slot].set(keys, mode="drop"),
        addrs=log.addrs.at[slot].set(addrs, mode="drop"),
        ops=log.ops.at[slot].set(jnp.where(fits, ops, 0), mode="drop"),
        tail=log.tail + fits.sum().astype(I32),
        applied=log.applied,
    )
    return new, fits | ~valid


def clear(log: UpdateLog) -> UpdateLog:
    """Empty-like log (same shapes/dtypes): the wipe primitive used when a
    server's state is destroyed on failure."""
    return UpdateLog(
        keys=jnp.zeros_like(log.keys),
        addrs=jnp.full_like(log.addrs, -1),
        ops=jnp.zeros_like(log.ops),
        tail=jnp.zeros_like(log.tail),
        applied=jnp.zeros_like(log.applied),
    )


def pending_count(log: UpdateLog):
    return log.tail - log.applied


def pending_lookup(log: UpdateLog, keys):
    """Newest-wins lookup over the pending window [applied, tail) — the
    degraded-read primitive (a backup holder consults its log before the
    sorted replica).  Returns (hit [Q] bool, op [Q], addr [Q]): op/addr
    are the LAST pending entry for each hit key; the caller interprets op
    (PUT -> addr wins, DEL -> deleted)."""
    cap = log.keys.shape[0]
    seq = log.applied + jnp.arange(cap)          # window in append order
    idx = seq % cap
    pv = seq < log.tail
    pk = jnp.where(pv, log.keys[idx], key_inf(log.keys.dtype))
    m = pk[None, :] == keys[:, None]             # [Q, cap]
    hit = m.any(axis=1)
    last = (cap - 1) - jnp.argmax(m[:, ::-1], axis=1)
    op = jnp.where(hit, log.ops[idx][last], 0)
    addr = log.addrs[idx][last]
    return hit, op, addr


def pending_entries_np(log: UpdateLog):
    """Host view of the pending window [applied, tail) in append order —
    the recovery control plane's read (keys, addrs, ops as numpy)."""
    import numpy as np

    cap = int(log.keys.shape[0])
    applied, tail = int(log.applied), int(log.tail)
    idx = (applied + np.arange(tail - applied)) % cap
    return (np.asarray(log.keys)[idx], np.asarray(log.addrs)[idx],
            np.asarray(log.ops)[idx])


def take_pending(log: UpdateLog, batch: int):
    """Gather up to ``batch`` oldest pending entries (static shape).
    Returns (keys, addrs, ops(0 for empty), new_log with applied advanced)."""
    cap = log.keys.shape[0]
    n = jnp.minimum(pending_count(log), batch)
    idx = (log.applied + jnp.arange(batch)) % cap
    live = jnp.arange(batch) < n
    keys = jnp.where(live, log.keys[idx], 0)
    addrs = jnp.where(live, log.addrs[idx], -1)
    ops = jnp.where(live, log.ops[idx], 0).astype(jnp.int8)
    new = log._replace(applied=log.applied + n)
    return keys, addrs, ops, new
