"""Data-server subsystem: the value plane of the store (paper §2).

HiStore deliberately separates index servers from data servers: the index
plane (hash table + sorted replicas + logs, `index_group.py`/`kvstore.py`)
answers *where* a value lives, the data plane owns the bytes.  This module
is the data plane, end to end:

  * **Slot allocator + GC** — every data shard tracks its slots with a
    ``used`` bitmap (fixed-shape JAX state, shard_map-safe).  PUT allocates
    the lowest free slots; DELETE and overwrite free the old slot (the
    paper's data-server GC), so a long-running store reuses capacity
    instead of wrap-corrupting once cumulative puts exceed it.  Frees that
    target a *remote* shard (values written on a temporary primary during
    a degraded write) are queued in a per-device free queue — an
    `UpdateLog` ring reusing the log machinery — and flushed home by the
    routed ``gc`` op.
  * **Value replication** — each shard is mirrored on the next
    ``cfg.n_value_replicas`` devices (shifted layout, exactly like the
    index backup logs: ``mirror[r, p]`` holds the copy of shard
    ``(p - r - 1) mod G``).  `fail_data_server` wipes a device's shard +
    hosted mirrors, making the value plane a genuine failure domain
    symmetric to the index one; `recover_data_server` rebuilds from a
    surviving mirror and mark-sweeps the allocator against the live index.
  * **Background value migration** — `migrate_values` moves values written
    off-home during degraded writes back to their owner group's shard and
    patches the index addresses (hash + every sorted replica), restoring
    one-RTT GETs after recovery (second-hop fetch elision):
    ``GetResult.hops`` drops from 2 back to 1.

The shard_map-side helpers (`alloc`, `free_slots`, the mirror push) are
called from the kvstore op bodies; the control-plane passes
(`fail_data_server` / `recover_data_server` / `migrate_values` / `sweep` /
`value_slot_audit`) are host-side and eager, mirroring the index plane's
failure protocol.  This module never imports `kvstore` — it only touches
the store pytree's fields — so the dependency points one way.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import hash_index as hix
from repro.core import log as lg
from repro.core import sorted_index as six
from repro.kernels import ops as kops

I32 = jnp.int32


class RecoveryError(RuntimeError):
    """Typed, actionable recovery failure: ``group`` names the lost
    structure, ``searched`` the copies that were checked, ``blockers``
    what would have to be recovered first (e.g. a dead data shard whose
    keys are needed for the data-plane fallback rebuild).  Raised only
    when NO live copy of any kind exists — the callers fall back through
    sorted replicas, then the hash + data-plane keys, before giving up."""

    def __init__(self, group: int, searched: list, blockers: list):
        self.group = group
        self.searched = list(searched)
        self.blockers = list(blockers)
        msg = (f"group {group}: no live copy to rebuild from "
               f"(searched {', '.join(map(str, searched))})")
        if blockers:
            msg += f"; recover {', '.join(map(str, blockers))} first"
        super().__init__(msg)


class DataPlane(NamedTuple):
    vals: jnp.ndarray    # [G, dcap, W]     primary copy of each shard
    used: jnp.ndarray    # [G, dcap] bool   slot allocator bitmap
    mirror: jnp.ndarray  # [Rv, G, dcap, W] shifted layout: mirror[r, p]
    #                      holds the copy of shard (p - r - 1) mod G
    freeq: lg.UpdateLog  # leaves [G, fq]   pending remote frees (addr ring)
    alive: jnp.ndarray   # [G] bool         data-server liveness
    keys: jnp.ndarray    # [G, dcap]        key stored with each slot (the
    #                      paper's data item carries the full KV record, so
    #                      an index rebuild can fetch keys from the data
    #                      servers — the multi-failure fallback authority)
    kmirror: jnp.ndarray  # [Rv, G, dcap]   key copies, shifted like mirror
    fq_spill: jnp.ndarray  # [G] int32      frees REJECTED by a full free
    #                      queue (push-back makes this unreachable on the
    #                      op paths; any non-zero count fails the audit)
    hb: jnp.ndarray      # [G] int32        data-server heartbeat counters —
    #                      bumped by every routed op body (same _bump_hb as
    #                      the index plane) unless severed; the client ages
    #                      them host-side, so data-server leases expire with
    #                      no oracle involvement
    sever: jnp.ndarray   # [G] bool         data server crashed but the
    #                      client has not noticed: heartbeats stop, local
    #                      value writes are rejected (lanes nack for a
    #                      client retry), reads fail over to the mirrors


def create(G: int, dcap: int, cfg, key_dt=None) -> DataPlane:
    from repro.core.hashing import key_dtype
    kd = key_dt or key_dtype()
    rep = lambda t, n: jax.tree.map(
        lambda a: jnp.broadcast_to(a[None], (n,) + a.shape).copy(), t)
    return DataPlane(
        vals=jnp.zeros((G, dcap, cfg.value_words), I32),
        used=jnp.zeros((G, dcap), bool),
        mirror=jnp.zeros((cfg.n_value_replicas, G, dcap, cfg.value_words),
                         I32),
        freeq=rep(lg.create(cfg.log_capacity, key_dt), G),
        alive=jnp.ones((G,), bool),
        keys=jnp.zeros((G, dcap), kd),
        kmirror=jnp.zeros((cfg.n_value_replicas, G, dcap), kd),
        fq_spill=jnp.zeros((G,), I32),
        hb=jnp.zeros((G,), I32),
        sever=jnp.zeros((G,), bool),
    )


def sharding(mesh, axis: str):
    from jax.sharding import NamedSharding
    from jax.sharding import PartitionSpec as P

    return DataPlane(
        vals=NamedSharding(mesh, P(axis)),
        used=NamedSharding(mesh, P(axis)),
        mirror=NamedSharding(mesh, P(None, axis)),
        freeq=lg.UpdateLog(*[NamedSharding(mesh, P(axis))] * 5),
        alive=NamedSharding(mesh, P()),
        keys=NamedSharding(mesh, P(axis)),
        kmirror=NamedSharding(mesh, P(None, axis)),
        fq_spill=NamedSharding(mesh, P(axis)),
        hb=NamedSharding(mesh, P(axis)),
        sever=NamedSharding(mesh, P()),
    )


def specs(axis: str):
    from jax.sharding import PartitionSpec as P

    return DataPlane(
        vals=P(axis), used=P(axis), mirror=P(None, axis),
        freeq=lg.UpdateLog(*[P(axis)] * 5), alive=P(),
        keys=P(axis), kmirror=P(None, axis), fq_spill=P(axis),
        hb=P(axis), sever=P())


# ---------------------------------------------------------------------------
# Slot allocator (single-shard, fixed-shape; shard_map-safe)
# ---------------------------------------------------------------------------
def alloc(used, want):
    """Allocate one slot per ``want`` lane from the lowest free indices.
    Returns (used', slot [n] int32 — cap on failure, ok [n]).  ok=False
    means the shard is full: the caller must NOT record the write (the
    push-back the client retries after a drain/GC round)."""
    cap = used.shape[0]
    nfree = (~used).sum()
    order = jnp.argsort(used, stable=True)       # free slot indices first
    rank = jnp.cumsum(want.astype(I32)) - 1
    ok = want & (rank < nfree)
    slot = jnp.where(ok, order[jnp.clip(rank, 0, cap - 1)], cap)
    return used.at[slot].set(True, mode="drop"), slot.astype(I32), ok


def free_slots(used, slots, mask):
    """Clear the allocator bits of ``slots`` where ``mask`` (local free)."""
    cap = used.shape[0]
    return used.at[jnp.where(mask, slots, cap)].set(False, mode="drop")


def winner_mask(keys, valid):
    """Last-occurrence-per-key dedupe over a batch: exactly one slot is
    allocated (and one old slot freed) per key per batch; batch order is
    arrival order, so the winner is the sequential last writer — the same
    last-writer-wins rule `hash_index.insert` applies internally."""
    return hix.dedupe_last_valid(keys, valid)


def spread_winner_addr(rk, valid, winner, addr_lane):
    """Give every lane of a duplicate-key group its winner's address, so
    superseded lanes ack/log the same (key, addr) the index keeps
    (last-writer-wins, matching `hash_index.insert`'s in-batch dedupe).
    Lanes whose winner failed allocation get -1 (the whole group retries
    together).  O(n^2) on the exchange-buffer width — small by design."""
    same = (rk[None, :] == rk[:, None]) & valid[None, :] & valid[:, None]
    pick = same & (winner & (addr_lane >= 0))[None, :]
    cand = jnp.where(pick, addr_lane[None, :], -1)
    return jnp.where(valid, cand.max(axis=1), -1).astype(I32)


# ---------------------------------------------------------------------------
# Host-side control plane (eager, like kvstore's failure protocol)
# ---------------------------------------------------------------------------
def effective_alive(data) -> np.ndarray:
    """TRUE data-server liveness for the omniscient control plane: a
    severed-but-undetected server is dead (its shard, hosted mirrors and
    free queue were destroyed in the crash), whatever the client's
    routing view still says."""
    return np.asarray(data.alive) & ~np.asarray(data.sever)


def device_counters(data: DataPlane) -> dict:
    """Surface the value plane's device-resident counters as host ints
    for the telemetry snapshot (one combined fetch, snapshot-time only —
    never on an op hot path): live data servers, heartbeat total, frees
    rejected by a full free queue (``fq_spill``), and the free queues'
    pending occupancy."""
    alive, hb, spill, pend = jax.device_get(
        (data.alive, data.hb, data.fq_spill, lg.pending_count(data.freeq)))
    return {
        "live_data_servers": int(np.asarray(alive).sum()),
        "data_heartbeats": int(np.asarray(hb).sum()),
        "fq_spill": int(np.asarray(spill).sum()),
        "freeq_pending": int(np.asarray(pend).sum()),
    }


def drain_pair(srt, blog, cfg):
    """Eagerly apply ALL pending entries of one (sorted, log) pair — THE
    drain primitive every control-plane pass shares (kvstore's recovery
    and parity audit delegate here too, so the semantics cannot drift)."""
    while int(lg.pending_count(blog)) > 0:
        keys, addrs, ops, blog = lg.take_pending(blog, cfg.async_apply_batch)
        srt = kops.merge(cfg, srt, keys, addrs, ops)
    return srt, blog


def drain_all_logs(store, cfg, apply_fn=None):
    """Apply every pending backup-log entry of every replica — the
    serializability barrier in front of every control-plane pass (audit,
    sweep, migrate, recover).

    ``apply_fn`` (store -> store), when given, is the mesh's jitted
    incremental apply op: the catch-up then runs as batched shard_map'd
    merge rounds (every device advances its logs together, one dispatch
    per ``async_apply_batch`` round) instead of the eager per-slot
    Python drain — the same incremental op foreground traffic interleaves
    with, so a control-plane pass no longer needs its own stop-the-world
    drain machinery."""
    if int(jnp.max(lg.pending_count(store.blog))) == 0:
        return store        # already drained: one sync instead of R*G
    if apply_fn is not None:
        rounds = max(1, -(-cfg.log_capacity // cfg.async_apply_batch))
        for _ in range(rounds):
            store = apply_fn(store)
            if int(jnp.max(lg.pending_count(store.blog))) == 0:
                break
        return store
    R = int(store.blog.tail.shape[0])
    G = int(store.alive.shape[0])
    bsorted, blog = store.bsorted, store.blog
    for r in range(R):
        for h in range(G):
            srt = jax.tree.map(lambda a: a[r, h], bsorted)
            one = jax.tree.map(lambda a: a[r, h], blog)
            srt, one = drain_pair(srt, one, cfg)
            bsorted = jax.tree.map(
                lambda f, v, r=r, h=h: f.at[r, h].set(v), bsorted, srt)
            blog = jax.tree.map(
                lambda f, v, r=r, h=h: f.at[r, h].set(v), blog, one)
    return store._replace(bsorted=bsorted, blog=blog)


def _group_items(store, cfg, g: int):
    """Live (keys, addrs) of group ``g`` from the authoritative structure:
    the hash table when g's index server is alive, else the first live
    (drained) sorted replica.  Call on a drained store.  Liveness here is
    TRUE liveness (alive minus severed): a crashed-but-undetected server
    must not be treated as an authority."""
    G = int(store.alive.shape[0])
    R = int(store.blog.tail.shape[0])
    alive = np.asarray(store.alive) & ~np.asarray(store.sever)
    srt0 = None
    for r in range(R):
        h = (g + r + 1) % G
        if alive[h] or G == 1:
            srt0 = jax.tree.map(lambda a: a[r, h], store.bsorted)
            break
    if alive[g]:
        hs = jax.tree.map(lambda a: a[g], store.hash)
        if srt0 is not None:
            keys, addrs, valid = six.items(srt0)
            k = np.asarray(keys)[np.asarray(valid)]
            a_h, f_h, _ = kops.probe(cfg, hs, keys)
            a = np.asarray(a_h)[np.asarray(valid)]
            # replica keys + hash addrs: keys for migration patching,
            # addresses straight from the authority
            if int(hix.n_items(hs)) == len(k) and np.asarray(f_h)[
                    np.asarray(valid)].all():
                return k, a
        # replicas lost or out of sync: fall back to the raw hash slots
        # (addresses only — no keys recoverable)
        vm = np.asarray(hix.valid_mask(hs))
        return None, np.asarray(hs.addr)[vm]
    if srt0 is None:
        return np.zeros((0,), np.int64), np.zeros((0,), np.int32)
    keys, addrs, valid = six.items(srt0)
    v = np.asarray(valid)
    return np.asarray(keys)[v], np.asarray(addrs)[v]


def _pending_free_addrs(freeq) -> np.ndarray:
    """All addresses sitting in the per-device free queues (host view)."""
    keys = np.asarray(freeq.keys)
    addrs = np.asarray(freeq.addrs)
    tail = np.asarray(freeq.tail)
    applied = np.asarray(freeq.applied)
    cap = keys.shape[1]
    out = []
    for d in range(keys.shape[0]):
        n = int(tail[d] - applied[d])
        idx = (int(applied[d]) + np.arange(n)) % cap
        out.append(addrs[d][idx])
    return np.concatenate(out) if out else np.zeros((0,), np.int32)


def keys_for_addrs(store, addrs: np.ndarray) -> np.ndarray:
    """Fetch the key stored with each address from the data plane — the
    paper's 'rebuild the index by fetching keys from the data items'.
    Reads the live shard's key column, else a surviving key mirror.
    Raises RecoveryError when an address's every data holder is dead."""
    G = int(store.alive.shape[0])
    dcap = int(store.data.vals.shape[1])
    Rv = int(store.data.kmirror.shape[0])
    dalive = effective_alive(store.data)
    dkeys = np.asarray(store.data.keys)
    kmir = np.asarray(store.data.kmirror)
    out = np.zeros((len(addrs),), dkeys.dtype)
    for i, a in enumerate(np.asarray(addrs, np.int64)):
        s, j = int(a) // dcap, int(a) % dcap
        if dalive[s]:
            out[i] = dkeys[s, j]
            continue
        for r in range(Rv):
            h = (s + r + 1) % G
            if h != s and dalive[h]:
                out[i] = kmir[r, h, j]
                break
        else:
            raise RecoveryError(group=-1, searched=[f"data shard {s}",
                                                    "key mirrors"],
                                blockers=[f"data server {s}"])
    return out


def group_items_from_data(store, cfg, g: int, owner_group_fn):
    """Last-resort rebuild authority: enumerate every allocated slot on
    every LIVE data shard, read its stored key, and keep the (key, addr)
    pairs owned by group ``g`` (``owner_group_fn`` is the routing hash,
    injected to keep this module independent of kvstore).  Slots whose
    free is still pending in a queue are logically dead and excluded.
    Raises RecoveryError when a dead data shard could be hiding slots
    (its allocator bitmap is lost until data recovery)."""
    G = int(store.alive.shape[0])
    dcap = int(store.data.vals.shape[1])
    dalive = effective_alive(store.data)
    dead_shards = [int(s) for s in range(G) if not dalive[s]]
    if dead_shards:
        raise RecoveryError(
            group=g,
            searched=["sorted replicas", "hash", "data-plane slots"],
            blockers=[f"data server {s}" for s in dead_shards])
    used = np.asarray(store.data.used)
    dkeys = np.asarray(store.data.keys)
    pend = set(int(a) for a in _pending_free_addrs(store.data.freeq))
    ks, ads = [], []
    for s in range(G):
        idx = np.nonzero(used[s])[0]
        for j in idx:
            a = s * dcap + int(j)
            if a in pend:
                continue
            ks.append(dkeys[s, int(j)])
            ads.append(a)
    if not ks:
        return (np.zeros((0,), dkeys.dtype), np.zeros((0,), np.int32))
    ks = np.asarray(ks)
    ads = np.asarray(ads, np.int32)
    own = np.asarray(owner_group_fn(jnp.asarray(ks), G))
    sel = own == g
    return ks[sel], ads[sel]


def value_slot_audit(store, cfg, apply_fn=None) -> dict:
    """Value-slot accounting audit (test/debug helper, eager):

      * every live index address maps to an allocated slot on its shard
        (``missing`` counts violations; shards masked data-dead are
        skipped — their bitmap is lost until recovery);
      * no address is referenced by two live index entries (``double``);
      * no allocated slot is orphaned — unreferenced by any live entry
        and not pending in a free queue (``orphaned``);
      * no free was ever rejected by a full free queue (``fq_spill`` —
        the op paths push back instead, so any spill is a bug).
    """
    st = drain_all_logs(store, cfg, apply_fn)
    G = int(st.alive.shape[0])
    dcap = int(st.data.vals.shape[1])
    dalive = effective_alive(st.data)
    used = np.asarray(st.data.used)
    refs = []
    for g in range(G):
        _, addrs = _group_items(st, cfg, g)
        refs.append(np.asarray(addrs, np.int64))
    refs = np.concatenate(refs) if refs else np.zeros((0,), np.int64)
    refs = refs[refs >= 0]
    uniq, counts = np.unique(refs, return_counts=True)
    double = int((counts > 1).sum())
    shard = uniq // dcap
    slot = uniq % dcap
    live_shard = dalive[shard]
    missing = int((~used[shard[live_shard], slot[live_shard]]).sum())
    pending = set(int(a) for a in _pending_free_addrs(st.data.freeq))
    referenced = set(int(a) for a in uniq)
    orphaned = 0
    for s in range(G):
        if not dalive[s]:
            continue
        for j in np.nonzero(used[s])[0]:
            a = s * dcap + int(j)
            if a not in referenced and a not in pending:
                orphaned += 1
    spill = int(np.asarray(st.data.fq_spill).sum())
    return {"group": -1, "replica": -1, "holder": -1, "kind": "value_slots",
            "live": int(len(uniq)), "pending_free": len(pending),
            "double": double, "missing": missing, "orphaned": orphaned,
            "fq_spill": spill,
            "agree": double == 0 and missing == 0 and orphaned == 0
            and spill == 0}


def _wipe_data_state(data: DataPlane, dev: int) -> DataPlane:
    """Destroy the data-plane state device ``dev`` held: its shard, every
    mirror it hosts, and its pending free queue (the crash's data loss)."""
    fq = data.freeq
    empty = lg.clear(jax.tree.map(lambda a: a[dev], fq))
    return data._replace(
        vals=data.vals.at[dev].set(0),
        used=data.used.at[dev].set(False),
        mirror=data.mirror.at[:, dev].set(0),
        keys=data.keys.at[dev].set(0),
        kmirror=data.kmirror.at[:, dev].set(0),
        freeq=jax.tree.map(lambda f, v: f.at[dev].set(v), fq, empty))


def fail_data_server(store, dev: int, wipe: bool = True):
    """ORACLE kill switch for the value plane: mask device ``dev``'s DATA
    server dead with the client told immediately — a failure domain
    separate from the index server (paper §2).  ``wipe`` (default)
    destroys the shard, the mirrors it hosts, and its pending free queue,
    so recovery must rebuild from surviving mirrors; leaked frees are
    reclaimed by the recovery mark-sweep.  For failures the client must
    DISCOVER via its leases, use ``sever_data_server`` instead."""
    data = store.data._replace(alive=store.data.alive.at[dev].set(False))
    if wipe:
        data = _wipe_data_state(data, dev)
    return store._replace(data=data)


def sever_data_server(store, dev: int, wipe: bool = True):
    """Crash device ``dev``'s DATA server WITHOUT telling the client: its
    shard state is destroyed (``wipe``) and its heartbeats stop, but
    ``data.alive`` — the client's routing view — still says up.  Local
    value writes there are rejected (lanes nack for a client retry),
    reads fail over to the surviving mirrors per-op (the RPC-timeout
    failover), and the client's lease detector demotes the device once
    its data heartbeat stalls past the lease — the paper's §5 detection
    story applied to the value plane, with no oracle fail_data_server
    call anywhere."""
    data = store.data._replace(sever=store.data.sever.at[dev].set(True))
    if wipe:
        data = _wipe_data_state(data, dev)
    return store._replace(data=data)


def sweep(store, cfg, apply_fn=None):
    """Mark-sweep GC reconciliation: on every live data shard, ``used``
    becomes exactly the slot set referenced by live index entries; the
    free queues are superseded and cleared.  Fixes slot leaks from free
    queues lost in a data-server crash."""
    st = drain_all_logs(store, cfg, apply_fn)
    G = int(st.alive.shape[0])
    dcap = int(st.data.vals.shape[1])
    dalive = effective_alive(st.data)
    used = np.asarray(st.data.used).copy()
    marked = np.zeros_like(used)
    for g in range(G):
        _, addrs = _group_items(st, cfg, g)
        addrs = np.asarray(addrs, np.int64)
        addrs = addrs[addrs >= 0]
        marked[addrs // dcap, addrs % dcap] = True
    for s in range(G):
        if dalive[s]:
            used[s] = marked[s]
    data = st.data._replace(used=jnp.asarray(used),
                            freeq=lg.clear(st.data.freeq))
    return st._replace(data=data)


def recover_data_server(store, dev: int, cfg, apply_fn=None):
    """Recover device ``dev``'s data server (host-side control plane):

      1. restore the shard from the first surviving mirror copy;
      2. re-clone every mirror ``dev`` hosts from the live shard (or a
         surviving mirror) of the same group;
      3. mark-sweep the allocator bitmaps against the live index (also
         reclaims frees leaked when the crash dropped ``dev``'s queue);
      4. flip ``data.alive[dev]`` (and clear a severed heartbeat, so the
         recovered server leases normally again — recovery works the same
         whether the failure was oracle-masked or lease-DETECTED).
    """
    G = int(store.alive.shape[0])
    Rv = int(store.data.mirror.shape[0])
    dalive = effective_alive(store.data)
    if bool(dalive[dev]):
        return store
    # the recovered server heartbeats again; rebuild below reads TRUE
    # liveness, so a severed-but-undetected sibling is never a source
    store = store._replace(data=store.data._replace(
        sever=store.data.sever.at[dev].set(False)))
    dalive = dalive.copy()
    dalive[dev] = False
    data = store.data
    if G > 1:
        src = None
        for r in range(Rv):
            h = (dev + r + 1) % G
            if h != dev and dalive[h]:
                src = (r, h)
                break
        if src is None:
            raise RecoveryError(group=dev,
                                searched=[f"mirror {r} on device "
                                          f"{(dev + r + 1) % G}"
                                          for r in range(Rv)],
                                blockers=[])
        data = data._replace(
            vals=data.vals.at[dev].set(data.mirror[src[0], src[1]]),
            keys=data.keys.at[dev].set(data.kmirror[src[0], src[1]]))
        for r in range(Rv):
            s = (dev - r - 1) % G
            if s == dev:
                continue
            if dalive[s]:
                data = data._replace(
                    mirror=data.mirror.at[r, dev].set(data.vals[s]),
                    kmirror=data.kmirror.at[r, dev].set(data.keys[s]))
            else:
                for r2 in range(Rv):
                    h2 = (s + r2 + 1) % G
                    if h2 != dev and dalive[h2]:
                        data = data._replace(
                            mirror=data.mirror.at[r, dev].set(
                                data.mirror[r2, h2]),
                            kmirror=data.kmirror.at[r, dev].set(
                                data.kmirror[r2, h2]))
                        break
    data = data._replace(alive=data.alive.at[dev].set(True))
    return sweep(store._replace(data=data), cfg, apply_fn)


def migrate_values(store, cfg, owner_group_fn, apply_fn=None):
    """Background value migration (second-hop fetch elision): move values
    that live off their owner group's shard — stranded there by degraded
    writes — back home, free the old slots, and patch the index addresses
    (hash + every sorted replica).  Post-migration GETs are one-RTT again
    (``GetResult.hops == 1``).

    ``owner_group_fn(keys, G)`` is the routing hash (injected to keep this
    module independent of kvstore); ``apply_fn`` the mesh's jitted apply
    op — the barrier then runs as incremental shard_map'd catch-up
    rounds rather than the eager per-slot drain.  Host-side; run it
    after recovery or on a maintenance schedule.  Returns (store,
    n_moved)."""
    st = drain_all_logs(store, cfg, apply_fn)
    G = int(st.alive.shape[0])
    R = int(st.blog.tail.shape[0])
    dcap = int(st.data.vals.shape[1])
    Rv = int(st.data.mirror.shape[0])
    dalive = effective_alive(st.data)
    data = st.data
    # flush pending frees first so their slots are reusable for homing
    used = np.asarray(data.used).copy()
    kept_frees = []
    for a in _pending_free_addrs(data.freeq):
        s = int(a) // dcap
        if dalive[s]:
            used[s, int(a) % dcap] = False
        else:
            kept_frees.append(int(a))
    freeq = lg.clear(data.freeq)
    vals = np.asarray(data.vals).copy()
    mirror = np.asarray(data.mirror).copy()
    dkeys = np.asarray(data.keys).copy()
    kmir = np.asarray(data.kmirror).copy()
    hash_t = st.hash
    bsorted = st.bsorted
    moved = 0
    for g in range(G):
        if not dalive[g]:
            continue                     # home shard down: nothing to do yet
        keys, addrs = _group_items(st, cfg, g)
        if keys is None or len(keys) == 0:
            continue
        keys = np.asarray(keys)
        addrs = np.asarray(addrs, np.int64)
        own = np.asarray(owner_group_fn(jnp.asarray(keys), G))
        stale = (addrs >= 0) & (addrs // dcap != g) & (own == g)
        if not stale.any():
            continue
        mk, ma = keys[stale], addrs[stale]
        # read each stranded value (shard copy, else a surviving mirror)
        vv, okv = [], []
        for a in ma:
            s, j = int(a) // dcap, int(a) % dcap
            if dalive[s]:
                vv.append(vals[s, j])
                okv.append(True)
                continue
            got = False
            for r in range(Rv):
                h = (s + r + 1) % G
                if dalive[h]:
                    vv.append(mirror[r, h, j])
                    okv.append(True)
                    got = True
                    break
            if not got:
                vv.append(np.zeros((vals.shape[-1],), vals.dtype))
                okv.append(False)        # unreachable: leave it in place
        okv = np.asarray(okv)
        free_home = np.nonzero(~used[g])[0]
        n = min(int(okv.sum()), len(free_home))
        take = np.nonzero(okv)[0][:n]    # partial migration if home is full
        if n == 0:
            continue
        new_slots = free_home[:n]
        mk, ma = mk[take], ma[take]
        vv = np.stack([vv[i] for i in take])
        vals[g, new_slots] = vv
        dkeys[g, new_slots] = mk
        used[g, new_slots] = True
        for r in range(Rv):
            h = (g + r + 1) % G
            if dalive[h]:
                mirror[r, h, new_slots] = vv
                kmir[r, h, new_slots] = mk
        for a in ma:
            s = int(a) // dcap
            if dalive[s]:
                used[s, int(a) % dcap] = False
            else:
                kept_frees.append(int(a))
        new_addrs = jnp.asarray(g * dcap + new_slots, I32)
        mkj = jnp.asarray(mk)
        if bool(np.asarray(st.alive)[g]):
            hs = jax.tree.map(lambda a: a[g], hash_t)
            hs, _ = hix.insert(hs, mkj, new_addrs, cfg)   # in-place update
            hash_t = jax.tree.map(lambda f, v: f.at[g].set(v), hash_t, hs)
        for r in range(R):
            h = (g + r + 1) % G
            srt = jax.tree.map(lambda a: a[r, h], bsorted)
            pos = jnp.searchsorted(srt.keys, mkj)
            hit = srt.keys[jnp.clip(pos, 0, srt.keys.shape[0] - 1)] == mkj
            tgt = jnp.where(hit, pos, srt.keys.shape[0])
            srt = srt._replace(
                addrs=srt.addrs.at[tgt].set(new_addrs, mode="drop"))
            bsorted = jax.tree.map(
                lambda f, v, r=r, h=h: f.at[r, h].set(v), bsorted, srt)
        moved += n
    if kept_frees:
        ka = jnp.asarray(kept_frees, I32)
        fq0 = jax.tree.map(lambda a: a[0], freeq)
        fq0, _ = lg.append(fq0, jnp.zeros_like(ka, freeq.keys.dtype), ka,
                           jnp.ones_like(ka, jnp.int8))
        freeq = jax.tree.map(lambda f, v: f.at[0].set(v), freeq, fq0)
    data = data._replace(vals=jnp.asarray(vals), used=jnp.asarray(used),
                         mirror=jnp.asarray(mirror), freeq=freeq,
                         keys=jnp.asarray(dkeys), kmirror=jnp.asarray(kmir))
    return st._replace(hash=hash_t, bsorted=bsorted, data=data), moved
