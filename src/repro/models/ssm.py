"""State-space blocks: Mamba-1 (selective scan) and Mamba-2 (SSD).

TPU adaptation: the CUDA "hardware-aware" fused scan of the Mamba papers is
re-thought as a *chunked* formulation — within a chunk the recurrence is
computed with associative_scan (Mamba-1) or in matmul form (Mamba-2 SSD,
MXU-friendly); a lax.scan over chunks carries the [B, ..., N] state.  Chunk
length cfg.ssm_chunk bounds the materialised state tensor so it fits VMEM-
scale working sets.  Both scans accept ``unroll`` for exact cost analysis.

Tensor-parallel layout: the fused in_proj of the reference CUDA code is
split into per-segment projections (in_x / in_z / in_B / in_C / in_dt) so
that every weight shards cleanly on the model axis (segment boundaries of a
fused projection do not align with shard boundaries).

Decode is the single-step recurrence over carried (conv_state, ssm_state).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import rmsnorm, rmsnorm_init

F32 = jnp.float32


def _causal_conv(x, w, b):
    """Depthwise causal conv.  x: [B,S,C]; w: [k,C]; b: [C]."""
    k = w.shape[0]
    out = jnp.zeros_like(x, dtype=F32)
    for j in range(k):
        shift = k - 1 - j
        xs = jnp.pad(x, ((0, 0), (shift, 0), (0, 0)))[:, :x.shape[1]]
        out = out + xs.astype(F32) * w[j].astype(F32)
    return (out + b.astype(F32)).astype(x.dtype)


def _conv_step(conv_state, x_t, w, b):
    """One decode step of the causal conv.  conv_state: [B,k-1,C] (last k-1
    inputs); x_t: [B,C].  Returns (y_t, new_state)."""
    window = jnp.concatenate([conv_state, x_t[:, None]], axis=1)  # [B,k,C]
    y = jnp.einsum("bkc,kc->bc", window.astype(F32), w.astype(F32)) + b.astype(F32)
    return y.astype(x_t.dtype), window[:, 1:]


# ===========================================================================
# Mamba-1
# ===========================================================================
def mamba1_dims(cfg):
    d_inner = cfg.ssm_expand * cfg.d_model
    dt_rank = max(1, cfg.d_model // 16)
    return d_inner, dt_rank


def mamba1_init(cfg, key) -> dict:
    dt = cfg.param_dtype
    D, N, k = cfg.d_model, cfg.ssm_state, cfg.ssm_conv
    di, R = mamba1_dims(cfg)
    ks = jax.random.split(key, 7)
    s = D ** -0.5
    a = jnp.tile(jnp.arange(1, N + 1, dtype=F32)[None, :], (di, 1))
    return {
        "in_x": (jax.random.normal(ks[0], (D, di), F32) * s).astype(dt),
        "in_z": (jax.random.normal(ks[1], (D, di), F32) * s).astype(dt),
        "conv_w": (jax.random.normal(ks[2], (k, di), F32) * 0.2).astype(dt),
        "conv_b": jnp.zeros((di,), dt),
        "x_proj": (jax.random.normal(ks[3], (di, R + 2 * N), F32) * di ** -0.5).astype(dt),
        "dt_proj": (jax.random.normal(ks[4], (R, di), F32) * R ** -0.5).astype(dt),
        "dt_bias": jnp.full((di,), -4.6, F32),   # softplus^-1(~0.01)
        "A_log": jnp.log(a),                      # [di, N] fp32
        "ssm_D": jnp.ones((di,), F32),
        "out_proj": (jax.random.normal(ks[5], (di, D), F32) * di ** -0.5).astype(dt),
    }


def _mamba1_scan_chunk(h_in, a, b, C):
    """h_in: [B,di,N]; a,b: [B,T,di,N]; C: [B,T,N] -> (y [B,T,di], h_out)."""
    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, ar * bl + br
    a_cum, b_scan = jax.lax.associative_scan(combine, (a, b), axis=1)
    h = a_cum * h_in[:, None].astype(a.dtype) + b_scan  # [B,T,di,N]
    y = jnp.einsum("btdn,btn->btd", h, C, preferred_element_type=F32)
    return y, h[:, -1].astype(F32)


def mamba1_apply(cfg, params, u, *, unroll: bool = False):
    """u: [B,S,D] -> [B,S,D] (full-sequence / train path)."""
    B, S, D = u.shape
    N = cfg.ssm_state
    di, R = mamba1_dims(cfg)
    T = min(cfg.ssm_chunk, S)
    nchunk = S // T
    x = u @ params["in_x"]
    z = u @ params["in_z"]
    x = _causal_conv(x, params["conv_w"], params["conv_b"])
    x = jax.nn.silu(x.astype(F32)).astype(x.dtype)
    dbc = x @ params["x_proj"]
    dt_in, B_ssm, C_ssm = jnp.split(dbc, [R, R + N], axis=-1)
    dt = jax.nn.softplus(
        (dt_in @ params["dt_proj"]).astype(F32) + params["dt_bias"])  # [B,S,di]
    A = -jnp.exp(params["A_log"])                                     # [di,N]
    if cfg.ssm_impl == "pallas":
        # §Perf A2: fused VMEM-resident scan — HBM touches only the kernel
        # I/O (x, dt, B, C, y); forward-only (prefill/serve paths)
        from repro.kernels.mamba_scan import mamba_scan_kernel
        y = mamba_scan_kernel(x, dt.astype(F32), B_ssm, C_ssm, A,
                              interpret=jax.default_backend() != "tpu")
        y = y.astype(F32)
        y = y + params["ssm_D"] * x.astype(F32)
        y = y * jax.nn.silu(z.astype(F32))
        return (y.astype(u.dtype)) @ params["out_proj"]
    if cfg.ssm_impl == "stub":
        # analysis-only placeholder with the kernel's I/O shapes: lets the
        # compositional lowering measure the NON-scan layer cost by XLA;
        # the kernel's analytic cost is added in EXPERIMENTS.md §Perf
        y = (x.astype(F32) * (1.0 + dt) + B_ssm.sum(-1, keepdims=True)
             + C_ssm.sum(-1, keepdims=True))
        y = y + params["ssm_D"] * x.astype(F32)
        y = y * jax.nn.silu(z.astype(F32))
        return (y.astype(u.dtype)) @ params["out_proj"]
    a = jnp.exp(dt[..., None] * A)                                    # [B,S,di,N]
    b = (dt * x.astype(F32))[..., None] * B_ssm.astype(F32)[:, :, None, :]
    # §Perf A1: the [B,S,di,N] scan intermediates dominate HBM traffic;
    # bf16 halves it (state re-accumulated in f32 at the chunk boundary)
    sd = jnp.dtype(cfg.ssm_scan_dtype)
    a = a.astype(sd)
    b = b.astype(sd)

    a_c = a.reshape(B, nchunk, T, di, N)
    b_c = b.reshape(B, nchunk, T, di, N)
    C_c = C_ssm.astype(sd).reshape(B, nchunk, T, N)

    def chunk_step(h, idx):
        y, h_new = _mamba1_scan_chunk(h, a_c[:, idx], b_c[:, idx], C_c[:, idx])
        return h_new, y

    h0 = jnp.zeros((B, di, N), F32)
    _, ys = jax.lax.scan(chunk_step, h0, jnp.arange(nchunk),
                         unroll=nchunk if unroll else 1)
    y = jnp.moveaxis(ys, 0, 1).reshape(B, S, di)
    y = y + params["ssm_D"] * x.astype(F32)
    y = y * jax.nn.silu(z.astype(F32))
    return (y.astype(u.dtype)) @ params["out_proj"]


def mamba1_cache_init(cfg, batch: int) -> dict:
    di, _ = mamba1_dims(cfg)
    return {
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, di), cfg.param_dtype),
        "ssm": jnp.zeros((batch, di, cfg.ssm_state), F32),
    }


def mamba1_decode(cfg, params, u, cache):
    """u: [B,1,D] -> ([B,1,D], new cache)."""
    N = cfg.ssm_state
    di, R = mamba1_dims(cfg)
    x = u[:, 0] @ params["in_x"]
    z = u[:, 0] @ params["in_z"]
    x, conv_state = _conv_step(cache["conv"], x, params["conv_w"],
                               params["conv_b"])
    x = jax.nn.silu(x.astype(F32)).astype(x.dtype)
    dbc = x @ params["x_proj"]
    dt_in, B_ssm, C_ssm = jnp.split(dbc, [R, R + N], axis=-1)
    dt = jax.nn.softplus(
        (dt_in @ params["dt_proj"]).astype(F32) + params["dt_bias"])  # [B,di]
    A = -jnp.exp(params["A_log"])
    a = jnp.exp(dt[..., None] * A)                                    # [B,di,N]
    b = (dt * x.astype(F32))[..., None] * B_ssm.astype(F32)[:, None, :]
    h = a * cache["ssm"] + b
    y = jnp.einsum("bdn,bn->bd", h, C_ssm.astype(F32))
    y = y + params["ssm_D"] * x.astype(F32)
    y = y * jax.nn.silu(z.astype(F32))
    out = y.astype(u.dtype) @ params["out_proj"]
    return out[:, None], {"conv": conv_state, "ssm": h}


# ===========================================================================
# Mamba-2 (SSD)
# ===========================================================================
def mamba2_dims(cfg):
    di = cfg.ssm_expand * cfg.d_model
    H = di // cfg.ssm_head_dim
    G, N = cfg.ssm_groups, cfg.ssm_state
    return di, H, G, N


def mamba2_init(cfg, key) -> dict:
    dt = cfg.param_dtype
    D, k = cfg.d_model, cfg.ssm_conv
    di, H, G, N = mamba2_dims(cfg)
    ks = jax.random.split(key, 8)
    s = D ** -0.5
    return {
        "in_z": (jax.random.normal(ks[0], (D, di), F32) * s).astype(dt),
        "in_x": (jax.random.normal(ks[1], (D, di), F32) * s).astype(dt),
        "in_B": (jax.random.normal(ks[2], (D, G * N), F32) * s).astype(dt),
        "in_C": (jax.random.normal(ks[3], (D, G * N), F32) * s).astype(dt),
        "in_dt": (jax.random.normal(ks[4], (D, H), F32) * s).astype(dt),
        "conv_xw": (jax.random.normal(ks[5], (k, di), F32) * 0.2).astype(dt),
        "conv_xb": jnp.zeros((di,), dt),
        "conv_Bw": (jax.random.normal(ks[6], (k, G * N), F32) * 0.2).astype(dt),
        "conv_Bb": jnp.zeros((G * N,), dt),
        "conv_Cw": (jax.random.normal(ks[7], (k, G * N), F32) * 0.2).astype(dt),
        "conv_Cb": jnp.zeros((G * N,), dt),
        "dt_bias": jnp.full((H,), -4.6, F32),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H)),
        "ssm_D": jnp.ones((H,), F32),
        "norm": rmsnorm_init(di, dt),
        "out_proj": (jax.random.normal(
            jax.random.fold_in(key, 99), (di, D), F32) * di ** -0.5).astype(dt),
    }


def _ssd_chunk(h_in, x, Bm, Cm, a_log, dt):
    """One SSD chunk in matmul form.
    h_in: [B,H,P,N]; x: [B,T,H,P]; Bm/Cm: [B,T,G,N]; a_log: [B,T,H] (log
    decay); dt: [B,T,H].  Returns (y [B,T,H,P], h_out)."""
    Bsz, T, H, P = x.shape
    G = Bm.shape[2]
    hg = H // G
    cum = jnp.cumsum(a_log, axis=1)                       # [B,T,H]
    # intra-chunk: L[t,s] = exp(cum_t - cum_s), t >= s
    Ldiff = cum[:, :, None, :] - cum[:, None, :, :]       # [B,T,S,H]
    tril = jnp.tril(jnp.ones((T, T), bool))
    L = jnp.where(tril[None, :, :, None], jnp.exp(Ldiff), 0.0)
    CB = jnp.einsum("btgn,bsgn->btsg", Cm.astype(F32), Bm.astype(F32))
    CB = jnp.repeat(CB, hg, axis=-1)                      # [B,T,S,H]
    W = CB * L                                            # [B,T,S,H]
    xdt = x.astype(F32) * dt[..., None]                   # [B,T,H,P]
    y_intra = jnp.einsum("btsh,bshp->bthp", W, xdt)
    # inter-chunk: y_inter[t] = exp(cum_t) * C_t . h_in   (C grouped -> heads)
    Ce = jnp.repeat(Cm.astype(F32), hg, axis=2)           # [B,T,H,N]
    y_inter = jnp.einsum("bthn,bhpn->bthp", Ce, h_in) * jnp.exp(cum)[..., None]
    # state update: h_out = exp(cum_T) h_in + sum_s exp(cum_T - cum_s) dt_s x_s B_s
    w_end = jnp.exp(cum[:, -1:, :] - cum)                 # [B,T,H]
    Be = jnp.repeat(Bm.astype(F32), hg, axis=2)           # [B,T,H,N]
    dh = jnp.einsum("bthp,bthn->bhpn", xdt * w_end[..., None], Be)
    h_out = jnp.exp(cum[:, -1])[:, :, None, None] * h_in + dh
    return y_intra + y_inter, h_out


def mamba2_apply(cfg, params, u, *, unroll: bool = False):
    B, S, D = u.shape
    di, H, G, N = mamba2_dims(cfg)
    P = cfg.ssm_head_dim
    T = min(cfg.ssm_chunk, S)
    nchunk = S // T
    z = u @ params["in_z"]
    x = u @ params["in_x"]
    Bm = u @ params["in_B"]
    Cm = u @ params["in_C"]
    dt_in = u @ params["in_dt"]
    x = _causal_conv(x, params["conv_xw"], params["conv_xb"])
    Bm = _causal_conv(Bm, params["conv_Bw"], params["conv_Bb"])
    Cm = _causal_conv(Cm, params["conv_Cw"], params["conv_Cb"])
    x = jax.nn.silu(x.astype(F32)).astype(x.dtype)
    Bm = jax.nn.silu(Bm.astype(F32)).astype(Bm.dtype)
    Cm = jax.nn.silu(Cm.astype(F32)).astype(Cm.dtype)
    x = x.reshape(B, S, H, P)
    Bm = Bm.reshape(B, S, G, N)
    Cm = Cm.reshape(B, S, G, N)
    dt = jax.nn.softplus(dt_in.astype(F32) + params["dt_bias"])       # [B,S,H]
    a_log = -jnp.exp(params["A_log"]) * dt                             # [B,S,H]

    xc = x.reshape(B, nchunk, T, H, P)
    bc = Bm.reshape(B, nchunk, T, G, N)
    cc = Cm.reshape(B, nchunk, T, G, N)
    ac = a_log.reshape(B, nchunk, T, H)
    dc = dt.reshape(B, nchunk, T, H)

    def chunk_step(h, idx):
        y, h_new = _ssd_chunk(h, xc[:, idx], bc[:, idx], cc[:, idx],
                              ac[:, idx], dc[:, idx])
        return h_new, y

    h0 = jnp.zeros((B, H, P, N), F32)
    _, ys = jax.lax.scan(chunk_step, h0, jnp.arange(nchunk),
                         unroll=nchunk if unroll else 1)
    y = jnp.moveaxis(ys, 0, 1).reshape(B, S, H, P)
    y = y + params["ssm_D"][:, None] * x.astype(F32)
    y = y.reshape(B, S, di)
    y = y * jax.nn.silu(z.astype(F32))
    y = rmsnorm(params["norm"], y.astype(u.dtype), cfg.norm_eps)
    return y @ params["out_proj"]


def mamba2_cache_init(cfg, batch: int) -> dict:
    di, H, G, N = mamba2_dims(cfg)
    dt = cfg.param_dtype
    return {
        "conv_x": jnp.zeros((batch, cfg.ssm_conv - 1, di), dt),
        "conv_B": jnp.zeros((batch, cfg.ssm_conv - 1, G * N), dt),
        "conv_C": jnp.zeros((batch, cfg.ssm_conv - 1, G * N), dt),
        "ssm": jnp.zeros((batch, H, cfg.ssm_head_dim, N), F32),
    }


def mamba2_decode(cfg, params, u, cache):
    B = u.shape[0]
    di, H, G, N = mamba2_dims(cfg)
    P = cfg.ssm_head_dim
    hg = H // G
    z = u[:, 0] @ params["in_z"]
    x = u[:, 0] @ params["in_x"]
    Bm = u[:, 0] @ params["in_B"]
    Cm = u[:, 0] @ params["in_C"]
    dt_in = u[:, 0] @ params["in_dt"]
    x, conv_x = _conv_step(cache["conv_x"], x, params["conv_xw"],
                           params["conv_xb"])
    Bm, conv_B = _conv_step(cache["conv_B"], Bm, params["conv_Bw"],
                            params["conv_Bb"])
    Cm, conv_C = _conv_step(cache["conv_C"], Cm, params["conv_Cw"],
                            params["conv_Cb"])
    x = jax.nn.silu(x.astype(F32)).astype(x.dtype)
    Bm = jax.nn.silu(Bm.astype(F32)).astype(Bm.dtype)
    Cm = jax.nn.silu(Cm.astype(F32)).astype(Cm.dtype)
    x = x.reshape(B, H, P)
    Bm = Bm.reshape(B, G, N)
    Cm = Cm.reshape(B, G, N)
    dt = jax.nn.softplus(dt_in.astype(F32) + params["dt_bias"])        # [B,H]
    a = jnp.exp(-jnp.exp(params["A_log"]) * dt)                         # [B,H]
    Be = jnp.repeat(Bm.astype(F32), hg, axis=1)                         # [B,H,N]
    Ce = jnp.repeat(Cm.astype(F32), hg, axis=1)
    dh = jnp.einsum("bhp,bhn->bhpn", x.astype(F32) * dt[..., None], Be)
    h = a[:, :, None, None] * cache["ssm"] + dh
    y = jnp.einsum("bhpn,bhn->bhp", h, Ce)
    y = y + params["ssm_D"][:, None] * x.astype(F32)
    y = y.reshape(B, di)
    y = y * jax.nn.silu(z.astype(F32))
    y = rmsnorm(params["norm"], y.astype(u.dtype), cfg.norm_eps)
    out = y @ params["out_proj"]
    return out[:, None], {"conv_x": conv_x, "conv_B": conv_B,
                          "conv_C": conv_C, "ssm": h}
