from repro.models.transformer import (  # noqa: F401
    init_params, apply_model, init_cache, decode_step, count_params,
)
