"""Generic decoder stack driven entirely by ModelConfig.

Layers are grouped into *stages* (see configs.base.layer_plan): runs of a
repeating pattern are executed with lax.scan over stacked parameters (keeps
HLO small at 80+ layers), leading/trailing odd layers run unstacked.  The
same stage structure drives init, train/prefill apply, cache init, and
single-token decode (caches ride the scan as xs/ys).

``unroll=True`` unrolls every scan (layers, attention blocks, ssm chunks)
so the compiled dry-run's cost analysis counts each iteration — see
launch/dryrun.py and EXPERIMENTS.md §Roofline.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, Stage, layer_plan
from repro.models import attention as attn
from repro.models import ssm
from repro.models.layers import (
    embed_init, embed_lookup, lm_head_init, logits_from_hidden, mlp_apply,
    mlp_init, rmsnorm, rmsnorm_init,
)
from repro.models.moe import moe_apply, moe_init

F32 = jnp.float32


# ---------------------------------------------------------------------------
# Single block
# ---------------------------------------------------------------------------
def _block_init(cfg: ModelConfig, key, spec) -> dict:
    mixer, ffn = spec
    ks = jax.random.split(key, 4)
    dt = cfg.param_dtype
    p: dict = {"ln1": rmsnorm_init(cfg.d_model, dt)}
    if mixer in ("attn", "local"):
        p["mixer"] = attn.attn_init(cfg, ks[0], "gqa")
    elif mixer == "mla":
        p["mixer"] = attn.attn_init(cfg, ks[0], "mla")
    elif mixer == "mamba1":
        p["mixer"] = ssm.mamba1_init(cfg, ks[0])
    elif mixer in ("mamba2", "mamba2+shared"):
        p["mixer"] = ssm.mamba2_init(cfg, ks[0])
    else:
        raise ValueError(mixer)
    if ffn == "mlp":
        p["ln2"] = rmsnorm_init(cfg.d_model, dt)
        p["ffn"] = mlp_init(ks[1], cfg.d_model, cfg.d_ff, dt)
    elif ffn == "moe":
        p["ln2"] = rmsnorm_init(cfg.d_model, dt)
        p["ffn"] = moe_init(cfg, ks[1])
    return p


def _shared_block_init(cfg: ModelConfig, key) -> dict:
    ks = jax.random.split(key, 2)
    dt = cfg.param_dtype
    return {
        "ln1": rmsnorm_init(cfg.d_model, dt),
        "attn": attn.attn_init(cfg, ks[0], "gqa"),
        "ln2": rmsnorm_init(cfg.d_model, dt),
        "mlp": mlp_init(ks[1], cfg.d_model, cfg.d_ff, dt),
    }


def _block_apply(cfg, params, spec, x, positions, shared, *, unroll):
    """Full-sequence (train/prefill) block application.  Returns (x, aux)."""
    mixer, ffn = spec
    aux = jnp.zeros((), F32)
    h = rmsnorm(params["ln1"], x, cfg.norm_eps)
    if mixer == "attn":
        x = x + attn.gqa_apply(cfg, params["mixer"], h, positions,
                               unroll=unroll)
    elif mixer == "local":
        x = x + attn.gqa_apply(cfg, params["mixer"], h, positions,
                               window=cfg.sliding_window, unroll=unroll)
    elif mixer == "mla":
        x = x + attn.mla_apply(cfg, params["mixer"], h, positions,
                               unroll=unroll)
    elif mixer == "mamba1":
        x = x + ssm.mamba1_apply(cfg, params["mixer"], h, unroll=unroll)
    elif mixer in ("mamba2", "mamba2+shared"):
        x = x + ssm.mamba2_apply(cfg, params["mixer"], h, unroll=unroll)
    if ffn == "mlp":
        x = x + mlp_apply(params["ffn"], rmsnorm(params["ln2"], x, cfg.norm_eps))
    elif ffn == "moe":
        y, aux = moe_apply(cfg, params["ffn"],
                           rmsnorm(params["ln2"], x, cfg.norm_eps))
        x = x + y
    if mixer == "mamba2+shared":
        h = rmsnorm(shared["ln1"], x, cfg.norm_eps)
        x = x + attn.gqa_apply(cfg, shared["attn"], h, positions,
                               unroll=unroll)
        x = x + mlp_apply(shared["mlp"], rmsnorm(shared["ln2"], x, cfg.norm_eps))
    return x, aux


def _block_cache_init(cfg, spec, batch, seq_len):
    mixer, _ = spec
    if mixer == "attn":
        return attn.gqa_cache_init(cfg, batch, seq_len)
    if mixer == "local":
        return attn.gqa_cache_init(cfg, batch, seq_len,
                                   window=cfg.sliding_window)
    if mixer == "mla":
        return attn.mla_cache_init(cfg, batch, seq_len)
    if mixer == "mamba1":
        return ssm.mamba1_cache_init(cfg, batch)
    if mixer == "mamba2":
        return ssm.mamba2_cache_init(cfg, batch)
    if mixer == "mamba2+shared":
        return {"mamba": ssm.mamba2_cache_init(cfg, batch),
                "shared": attn.gqa_cache_init(cfg, batch, seq_len)}
    raise ValueError(mixer)


def _block_decode(cfg, params, spec, x, pos, cache, shared):
    mixer, ffn = spec
    h = rmsnorm(params["ln1"], x, cfg.norm_eps)
    if mixer == "attn":
        y, cache = attn.gqa_decode(cfg, params["mixer"], h, pos, cache)
        x = x + y
    elif mixer == "local":
        y, cache = attn.gqa_decode(cfg, params["mixer"], h, pos, cache,
                                   window=cfg.sliding_window)
        x = x + y
    elif mixer == "mla":
        y, cache = attn.mla_decode(cfg, params["mixer"], h, pos, cache)
        x = x + y
    elif mixer == "mamba1":
        y, cache = ssm.mamba1_decode(cfg, params["mixer"], h, cache)
        x = x + y
    elif mixer == "mamba2+shared":
        y, mcache = ssm.mamba2_decode(cfg, params["mixer"], h, cache["mamba"])
        x = x + y
    elif mixer == "mamba2":
        y, cache = ssm.mamba2_decode(cfg, params["mixer"], h, cache)
        x = x + y
    if ffn == "mlp":
        x = x + mlp_apply(params["ffn"], rmsnorm(params["ln2"], x, cfg.norm_eps))
    elif ffn == "moe":
        y, _ = moe_apply(cfg, params["ffn"],
                         rmsnorm(params["ln2"], x, cfg.norm_eps))
        x = x + y
    if mixer == "mamba2+shared":
        h = rmsnorm(shared["ln1"], x, cfg.norm_eps)
        y, scache = attn.gqa_decode(cfg, shared["attn"], h, pos,
                                    cache["shared"])
        x = x + y
        x = x + mlp_apply(shared["mlp"], rmsnorm(shared["ln2"], x, cfg.norm_eps))
        cache = {"mamba": mcache, "shared": scache}
    return x, cache


# ---------------------------------------------------------------------------
# Whole model
# ---------------------------------------------------------------------------
def init_params(cfg: ModelConfig, key) -> dict:
    stages = layer_plan(cfg)
    n_keys = len(stages) + 4
    ks = jax.random.split(key, n_keys)
    params: dict = {}
    if cfg.frontend == "token":
        params["embed"] = embed_init(ks[0], cfg.vocab_size, cfg.d_model,
                                     cfg.param_dtype)
    elif cfg.tie_embeddings:
        # embed-frontend archs still need a (tied) output table
        params["embed"] = embed_init(ks[0], cfg.vocab_size, cfg.d_model,
                                     cfg.param_dtype)
    if not cfg.tie_embeddings:
        params["lm_head"] = lm_head_init(ks[1], cfg.d_model, cfg.vocab_size,
                                         cfg.param_dtype)
    params["final_norm"] = rmsnorm_init(cfg.d_model, cfg.param_dtype)
    if cfg.shared_attn_every:
        params["shared"] = _shared_block_init(cfg, ks[2])
    stage_params = []
    for si, st in enumerate(stages):
        sk = jax.random.fold_in(ks[3], si)
        if st.kind == "single":
            stage_params.append(_block_init(cfg, sk, st.pattern[0]))
        else:
            per_pos = []
            for pi, spec in enumerate(st.pattern):
                reps = [
                    _block_init(cfg, jax.random.fold_in(sk, pi * 1000 + r), spec)
                    for r in range(st.n_rep)
                ]
                per_pos.append(jax.tree.map(lambda *a: jnp.stack(a), *reps))
            stage_params.append(tuple(per_pos))
    params["stages"] = stage_params
    return params


def _frontend(cfg, params, inputs):
    if cfg.frontend == "token":
        key = "tokens" if "tokens" in inputs else "token"
        return embed_lookup(params["embed"], inputs[key])
    return inputs["embeds"]


def apply_model(cfg: ModelConfig, params, inputs, *, unroll: bool = False):
    """Train/prefill forward.  Returns (hidden [B,S,D], aux_loss)."""
    x = _frontend(cfg, params, inputs)
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    shared = params.get("shared")
    stages = layer_plan(cfg)
    aux_total = jnp.zeros((), F32)
    for st, sp in zip(stages, params["stages"]):
        if st.kind == "single":
            x, aux = _block_apply(cfg, sp, st.pattern[0], x, positions,
                                  shared, unroll=unroll)
            aux_total = aux_total + aux
        else:
            def unit(x, slices, st=st):
                aux_u = jnp.zeros((), F32)
                for spec, p in zip(st.pattern, slices):
                    x, aux = _block_apply(cfg, p, spec, x, positions, shared,
                                          unroll=unroll)
                    aux_u = aux_u + aux
                return x, aux_u
            if cfg.remat == "unit":
                unit = jax.checkpoint(unit)

            def body(x, slices):
                return unit(x, slices)
            x, auxs = jax.lax.scan(body, x, sp,
                                   unroll=st.n_rep if unroll else 1)
            aux_total = aux_total + auxs.sum()
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return x, aux_total


def hidden_to_logits(cfg, params, hidden):
    return logits_from_hidden(cfg, params, hidden)


def init_cache(cfg: ModelConfig, batch: int, seq_len: int):
    stages = layer_plan(cfg)
    caches = []
    for st in stages:
        if st.kind == "single":
            caches.append(_block_cache_init(cfg, st.pattern[0], batch, seq_len))
        else:
            per_pos = []
            for spec in st.pattern:
                one = _block_cache_init(cfg, spec, batch, seq_len)
                per_pos.append(jax.tree.map(
                    lambda a: jnp.broadcast_to(a[None], (st.n_rep,) + a.shape),
                    one))
            caches.append(tuple(per_pos))
    return caches


def decode_step(cfg: ModelConfig, params, cache, inputs):
    """One decode step.  inputs: {tokens [B,1] | embeds [B,1,D], pos [B]}.
    Returns (logits [B,V], new_cache)."""
    x = _frontend(cfg, params, inputs)
    pos = inputs["pos"]
    shared = params.get("shared")
    stages = layer_plan(cfg)
    new_caches = []
    for st, sp, sc in zip(stages, params["stages"], cache):
        if st.kind == "single":
            x, c = _block_decode(cfg, sp, st.pattern[0], x, pos, sc, shared)
            new_caches.append(c)
        else:
            def body(x, slices, st=st):
                ps, cs = slices
                cs_new = []
                for spec, p, c in zip(st.pattern, ps, cs):
                    x, c2 = _block_decode(cfg, p, spec, x, pos, c, shared)
                    cs_new.append(c2)
                return x, tuple(cs_new)
            x, c = jax.lax.scan(body, x, (sp, sc))
            new_caches.append(c)
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = logits_from_hidden(cfg, params, x)[:, 0]
    return logits, new_caches


def count_params(params) -> int:
    return sum(x.size for x in jax.tree.leaves(params))
