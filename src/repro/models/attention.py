"""Attention: GQA (global + sliding-window) and MLA, with train/prefill
(blockwise online-softmax "flash" formulation in pure jnp) and single-token
decode paths over a position-tagged ring-buffer KV cache.

Design notes
------------
* flash_attention scans q blocks; per q block an inner scan over kv blocks
  keeps fp32 running (max, sum, acc).  ``unroll=True`` fully unrolls both
  scans so compiled cost analysis counts every block (used by the roofline
  dry-run; see launch/dryrun.py).
* The baseline causal path visits every kv block and masks (the standard
  naive-flash baseline, ~2x attention-flop waste).  ``cfg.attn_block_skip``
  switches to a divide-and-conquer causal decomposition
  (causal(S) = 2 x causal(S/2) + rect(S/2 x S/2)) that skips the fully
  masked half with static shapes — a §Perf hillclimb lever.
* Sliding-window layers gather only the ceil(W/blk)+1 kv blocks that
  intersect the window -> O(S*window) flops, not O(S^2).
* Decode caches are ring buffers tagged with per-slot positions (pos_buf),
  so local layers keep only window-sized caches.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import apply_rope, rmsnorm, rmsnorm_init

F32 = jnp.float32
NEG_INF = -1e30


def _constrain_cache(k_cache, v_cache, mode: str = "seq"):
    """Pin KV-cache sharding after the decode scatter.

    mode="seq": shard the cache LENGTH over model (flash-decode style) —
    scores stay local per sequence shard and only the [B,H,hd] weighted
    partials + softmax stats cross the interconnect (psum).
    mode="hd": shard head_dim (C2 variant; psums full-length scores)."""
    from repro.sharding.context import constrain, get_mesh
    mesh = get_mesh()
    if mesh is None:
        return k_cache, v_cache
    msz = mesh.shape.get("model", 1)
    B, cap, Hkv, hd = k_cache.shape
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    dpsz = 1
    for a in dp:
        dpsz *= mesh.shape[a]
    if B % dpsz:
        # SP mode (batch==1 long-context): cache length is data-sharded at
        # the jit boundary; forcing model-sharding here would reshard it
        return k_cache, v_cache
    if mode == "seq" and cap % msz == 0 and cap >= msz:
        axes = (dp, "model", None, None)
    elif Hkv % msz == 0 and Hkv >= msz:
        axes = (dp, None, "model", None)
    elif hd % msz == 0 and hd >= msz:
        axes = (dp, None, None, "model")
    else:
        axes = (dp, "model", None, None)
    return constrain(k_cache, *axes), constrain(v_cache, *axes)


def _seq_shard_ok(k_cache):
    from repro.sharding.context import get_mesh
    mesh = get_mesh()
    if mesh is None:
        return False
    msz = mesh.shape.get("model", 1)
    B, cap = k_cache.shape[0], k_cache.shape[1]
    dp = 1
    for a in ("pod", "data"):
        if a in mesh.axis_names:
            dp *= mesh.shape[a]
    return cap % msz == 0 and cap >= msz and B % dp == 0


def _sharded_cache_update(k_cache, v_cache, pos_buf, k_new, v_new, pos):
    """In-place ring write into a (batch=dp, cap=model)-sharded cache."""
    import jax as _jax
    from jax.sharding import PartitionSpec as P
    from repro.sharding.context import get_mesh
    mesh = get_mesh()
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    dp_spec = dp if len(dp) > 1 else dp[0]
    cap = k_cache.shape[1]
    msz = mesh.shape.get("model", 1)
    capl = cap // msz

    def body(ck, cv, pb, kn, vn, ps):
        mi = _jax.lax.axis_index("model")
        slot = ps % cap
        local = slot - mi * capl
        li = jnp.where((local >= 0) & (local < capl), local, capl)
        bidx = jnp.arange(ck.shape[0])
        ck = ck.at[bidx, li].set(kn, mode="drop")
        cv = cv.at[bidx, li].set(vn, mode="drop")
        pb = pb.at[bidx, li].set(ps, mode="drop")
        return ck, cv, pb

    from repro.sharding.smap import shard_map
    fn = shard_map(
        body, mesh,
        (P(dp_spec, "model", None, None),
         P(dp_spec, "model", None, None),
         P(dp_spec, "model"),
         P(dp_spec, None, None), P(dp_spec, None, None), P(dp_spec)),
        (P(dp_spec, "model", None, None),
         P(dp_spec, "model", None, None),
         P(dp_spec, "model")))
    return fn(k_cache, v_cache, pos_buf, k_new, v_new, pos)


# ---------------------------------------------------------------------------
# Parameter init
# ---------------------------------------------------------------------------
def attn_init(cfg, key, kind: str) -> dict:
    dt = cfg.param_dtype
    D = cfg.d_model
    if kind == "mla":
        H, r = cfg.n_heads, cfg.kv_lora_rank
        nope, rope, hv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
        ks = jax.random.split(key, 6)
        s = D ** -0.5
        return {
            "wq": (jax.random.normal(ks[0], (D, H * (nope + rope)), F32) * s).astype(dt),
            "w_dkv": (jax.random.normal(ks[1], (D, r), F32) * s).astype(dt),
            "w_kr": (jax.random.normal(ks[5], (D, rope), F32) * s).astype(dt),
            "w_uk": (jax.random.normal(ks[2], (r, H * nope), F32) * r ** -0.5).astype(dt),
            "w_uv": (jax.random.normal(ks[3], (r, H * hv), F32) * r ** -0.5).astype(dt),
            "wo": (jax.random.normal(ks[4], (H * hv, D), F32) * (H * hv) ** -0.5).astype(dt),
            "kv_norm": rmsnorm_init(r, dt),
        }
    H, Hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    s = D ** -0.5
    return {
        "wq": (jax.random.normal(ks[0], (D, H * hd), F32) * s).astype(dt),
        "wk": (jax.random.normal(ks[1], (D, Hkv * hd), F32) * s).astype(dt),
        "wv": (jax.random.normal(ks[2], (D, Hkv * hd), F32) * s).astype(dt),
        "wo": (jax.random.normal(ks[3], (H * hd, D), F32) * (H * hd) ** -0.5).astype(dt),
    }


# ---------------------------------------------------------------------------
# Blockwise (flash-style) attention, pure jnp
# ---------------------------------------------------------------------------
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    q_block: int = 512, kv_block: int = 512,
                    unroll: bool = False, return_stats: bool = False):
    """q: [B,Sq,H,hdq]; k: [B,Skv,Hkv,hdq]; v: [B,Skv,Hkv,hdv] -> [B,Sq,H,hdv].

    ``causal`` assumes Sq == Skv.  ``window`` > 0 restricts each query to the
    last ``window`` keys (implies causal).  With ``return_stats`` also
    returns the per-row online-softmax stats (m, l) with shape
    [B, Sq, Hkv, G] (used by the divide-and-conquer merge).
    """
    B, Sq, H, hdq = q.shape
    Skv, Hkv, hdv = k.shape[1], k.shape[2], v.shape[3]
    G = H // Hkv
    qb = min(q_block, Sq)
    kvb = min(kv_block, Skv)
    nq, nkv = Sq // qb, Skv // kvb
    scale = hdq ** -0.5
    qg = q.reshape(B, nq, qb, Hkv, G, hdq)

    if window:
        assert Sq == Skv
        return _sliding_window(qg, k, v, window, qb, scale, unroll)

    kb = k.reshape(B, nkv, kvb, Hkv, hdq)
    vb = v.reshape(B, nkv, kvb, Hkv, hdv)

    def q_step(_, qi):
        q_blk = qg[:, qi] * scale                              # [B,qb,Hkv,G,hd]
        q_pos = qi * qb + jnp.arange(qb)

        def kv_step(carry, kj):
            m, l, acc = carry
            s = jnp.einsum("bqhgd,bkhd->bqhgk", q_blk, kb[:, kj],
                           preferred_element_type=F32)
            if causal:
                kv_pos = kj * kvb + jnp.arange(kvb)
                mask = q_pos[:, None] >= kv_pos[None, :]       # [qb,kvb]
                s = jnp.where(mask[None, :, None, None, :], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            alpha = jnp.exp(m - m_new)
            p = jnp.exp(s - m_new[..., None])
            l_new = l * alpha + p.sum(axis=-1)
            pv = jnp.einsum("bqhgk,bkhv->bqhgv", p.astype(v.dtype), vb[:, kj],
                            preferred_element_type=F32)
            return (m_new, l_new, acc * alpha[..., None] + pv), None

        init = (jnp.full((B, qb, Hkv, G), NEG_INF, F32),
                jnp.zeros((B, qb, Hkv, G), F32),
                jnp.zeros((B, qb, Hkv, G, hdv), F32))
        (m, l, acc), _ = jax.lax.scan(kv_step, init, jnp.arange(nkv),
                                      unroll=nkv if unroll else 1)
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return None, (out.astype(q.dtype), m, l)

    _, (outs, ms, ls) = jax.lax.scan(q_step, None, jnp.arange(nq),
                                     unroll=nq if unroll else 1)
    # outs: [nq, B, qb, Hkv, G, hdv] -> [B, Sq, H, hdv]
    out = jnp.moveaxis(outs, 0, 1).reshape(B, Sq, Hkv, G, hdv)
    out = out.reshape(B, Sq, H, hdv)
    if return_stats:
        m = jnp.moveaxis(ms, 0, 1).reshape(B, Sq, Hkv, G)
        l = jnp.moveaxis(ls, 0, 1).reshape(B, Sq, Hkv, G)
        return out, m, l
    return out


def _merge_two(o1, m1, l1, o2, m2, l2, out_dtype):
    """Merge two normalised online-softmax partial results over the same
    queries but disjoint key sets."""
    m = jnp.maximum(m1, m2)
    w1 = l1 * jnp.exp(m1 - m)
    w2 = l2 * jnp.exp(m2 - m)
    denom = jnp.maximum(w1 + w2, 1e-30)
    G = o1.shape  # [B,S,H,hdv]; stats are [B,S,Hkv,G]
    B, S, H, hdv = o1.shape
    Hkv = m1.shape[2]
    g = H // Hkv
    w1e = w1.reshape(B, S, H)[..., None].astype(F32)
    w2e = w2.reshape(B, S, H)[..., None].astype(F32)
    de = denom.reshape(B, S, H)[..., None]
    o = (o1.astype(F32) * w1e + o2.astype(F32) * w2e) / de
    return (o.astype(out_dtype),
            m, (w1 + w2))


def causal_divide_conquer(q, k, v, *, q_block: int = 512, leaf: int = 2048,
                          unroll: bool = False, return_stats: bool = False):
    """Exact causal attention via causal(S) = [causal(front half)] ++
    [merge(causal(back half), rect(back q x front kv))].

    The strictly-upper half of the score matrix is never materialised or
    computed, halving attention flops with fully static shapes.  Trace-time
    recursion bottoms out at ``leaf`` where the masked flash path runs.
    """
    B, S, H, _ = q.shape
    if S <= leaf:
        return flash_attention(q, k, v, causal=True, q_block=q_block,
                               kv_block=q_block, unroll=unroll,
                               return_stats=return_stats)
    h = S // 2
    front = causal_divide_conquer(q[:, :h], k[:, :h], v[:, :h],
                                  q_block=q_block, leaf=leaf, unroll=unroll,
                                  return_stats=True)
    back_diag = causal_divide_conquer(q[:, h:], k[:, h:], v[:, h:],
                                      q_block=q_block, leaf=leaf,
                                      unroll=unroll, return_stats=True)
    back_rect = flash_attention(q[:, h:], k[:, :h], v[:, :h], causal=False,
                                q_block=q_block, kv_block=q_block,
                                unroll=unroll, return_stats=True)
    o_b, m_b, l_b = _merge_two(*back_diag, *back_rect, q.dtype)
    o_f, m_f, l_f = front
    out = jnp.concatenate([o_f, o_b], axis=1)
    if return_stats:
        return out, jnp.concatenate([m_f, m_b], 1), jnp.concatenate([l_f, l_b], 1)
    return out


def _sliding_window(qg, k, v, window: int, qb: int, scale, unroll):
    """Local attention: q block qi gathers the nwin kv blocks covering
    [qi*qb - window + 1, (qi+1)*qb) and masks exactly.  O(S * window)."""
    B, nq, _, Hkv, G, hdq = qg.shape
    hdv = v.shape[3]
    S = nq * qb
    nwin = (window + qb - 1) // qb + 1           # kv blocks per q block
    pad = (nwin - 1) * qb
    kp = jnp.pad(k, ((0, 0), (pad, 0), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (pad, 0), (0, 0), (0, 0)))

    def q_step(_, qi):
        q_blk = qg[:, qi] * scale
        start = qi * qb                          # padded coord of window start
        k_win = jax.lax.dynamic_slice_in_dim(kp, start, nwin * qb, axis=1)
        v_win = jax.lax.dynamic_slice_in_dim(vp, start, nwin * qb, axis=1)
        s = jnp.einsum("bqhgd,bkhd->bqhgk", q_blk, k_win,
                       preferred_element_type=F32)
        q_pos = qi * qb + jnp.arange(qb)
        kv_pos = qi * qb - pad + jnp.arange(nwin * qb)   # logical positions
        mask = ((q_pos[:, None] >= kv_pos[None, :])
                & (q_pos[:, None] - kv_pos[None, :] < window)
                & (kv_pos[None, :] >= 0))
        s = jnp.where(mask[None, :, None, None, :], s, NEG_INF)
        m = s.max(axis=-1, keepdims=True)
        p = jnp.exp(s - m)
        l = p.sum(axis=-1)
        pv = jnp.einsum("bqhgk,bkhv->bqhgv", p.astype(v.dtype), v_win,
                        preferred_element_type=F32)
        out = pv / jnp.maximum(l, 1e-30)[..., None]
        return None, out.astype(k.dtype)

    _, outs = jax.lax.scan(q_step, None, jnp.arange(nq),
                           unroll=nq if unroll else 1)
    outs = jnp.moveaxis(outs, 0, 1).reshape(B, S, Hkv, G, hdv)
    H = Hkv * G
    return outs.reshape(B, S, H, hdv)


# ---------------------------------------------------------------------------
# GQA block (train/prefill)
# ---------------------------------------------------------------------------
def gqa_apply(cfg, params, x, positions, *, window: int = 0,
              unroll: bool = False):
    B, S, D = x.shape
    H, Hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    q = (x @ params["wq"]).reshape(B, S, H, hd)
    k = (x @ params["wk"]).reshape(B, S, Hkv, hd)
    v = (x @ params["wv"]).reshape(B, S, Hkv, hd)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    if cfg.attn_impl == "naive":
        o = _naive_attention(q, k, v, window)
    elif cfg.attn_block_skip and not window:
        o = causal_divide_conquer(q, k, v, q_block=cfg.attn_q_block,
                                  leaf=2 * cfg.attn_q_block, unroll=unroll)
    else:
        o = flash_attention(q, k, v, causal=True, window=window,
                            q_block=cfg.attn_q_block,
                            kv_block=cfg.attn_kv_block, unroll=unroll)
    return o.reshape(B, S, H * hd) @ params["wo"]


def _naive_attention(q, k, v, window: int = 0):
    """Materialised-scores oracle (smoke tests / tiny shapes only)."""
    B, S, H, hd = q.shape
    Hkv = k.shape[2]
    G = H // Hkv
    qg = q.reshape(B, S, Hkv, G, hd)
    s = jnp.einsum("bqhgd,bkhd->bqhgk", qg, k,
                   preferred_element_type=F32) * hd ** -0.5
    qp, kp = jnp.arange(S)[:, None], jnp.arange(S)[None, :]
    mask = qp >= kp
    if window:
        mask &= (qp - kp) < window
    s = jnp.where(mask[None, :, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bqhgk,bkhd->bqhgd", p.astype(v.dtype), v,
                   preferred_element_type=F32).astype(q.dtype)
    return o.reshape(B, S, H, hd)


# ---------------------------------------------------------------------------
# GQA decode (single token, ring-buffer cache)
# ---------------------------------------------------------------------------
def gqa_cache_init(cfg, batch: int, seq_len: int, *, window: int = 0) -> dict:
    cap = min(window, seq_len) if window else seq_len
    Hkv, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    dt = cfg.param_dtype
    return {
        "k": jnp.zeros((batch, cap, Hkv, hd), dt),
        "v": jnp.zeros((batch, cap, Hkv, hd), dt),
        "pos": jnp.full((batch, cap), -1, jnp.int32),
    }


def gqa_decode(cfg, params, x, pos, cache, *, window: int = 0):
    """x: [B, 1, D]; pos: [B] current position. Returns (out [B,1,D], cache)."""
    B, _, D = x.shape
    H, Hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    G = H // Hkv
    q = (x @ params["wq"]).reshape(B, 1, H, hd)
    k = (x @ params["wk"]).reshape(B, 1, Hkv, hd)
    v = (x @ params["wv"]).reshape(B, 1, Hkv, hd)
    q = apply_rope(q, pos[:, None], cfg.rope_theta)
    k = apply_rope(k, pos[:, None], cfg.rope_theta)
    cap = cache["k"].shape[1]
    slot = pos % cap
    bidx = jnp.arange(B)
    if cfg.decode_cache_hint and _seq_shard_ok(cache["k"]):
        # sequence-sharded cache: do the slot write as a shard_map-local
        # scatter (GSPMD otherwise lowers scatter-into-sharded-dim to a
        # full-cache masked select) — §Perf hillclimb C4
        k_cache, v_cache, pos_buf = _sharded_cache_update(
            cache["k"], cache["v"], cache["pos"], k[:, 0], v[:, 0], pos)
    else:
        k_cache = cache["k"].at[bidx, slot].set(k[:, 0])
        v_cache = cache["v"].at[bidx, slot].set(v[:, 0])
        pos_buf = cache["pos"].at[bidx, slot].set(pos)
        if cfg.decode_cache_hint:
            k_cache, v_cache = _constrain_cache(k_cache, v_cache)
    qg = q.reshape(B, Hkv, G, hd) * hd ** -0.5
    if cfg.decode_cache_hint:
        # q replicated over model (tiny); scores stay sequence-sharded
        from repro.sharding.context import constrain
        qg = constrain(qg, ("pod", "data"), None, None, None)
    s = jnp.einsum("bhgd,bkhd->bhgk", qg, k_cache,
                   preferred_element_type=F32)
    valid = (pos_buf >= 0) & (pos_buf <= pos[:, None])
    if window:
        valid &= (pos[:, None] - pos_buf) < window
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgk,bkhd->bhgd", p.astype(v.dtype), v_cache,
                   preferred_element_type=F32).astype(x.dtype)
    out = o.reshape(B, 1, H * hd) @ params["wo"]
    return out, {"k": k_cache, "v": v_cache, "pos": pos_buf}


# ---------------------------------------------------------------------------
# MLA (train/prefill decompressed; decode absorbed over compressed cache)
# ---------------------------------------------------------------------------
def mla_apply(cfg, params, x, positions, *, unroll: bool = False):
    B, S, D = x.shape
    H = cfg.n_heads
    r, nope, rope_d, hv = (cfg.kv_lora_rank, cfg.qk_nope_dim,
                           cfg.qk_rope_dim, cfg.v_head_dim)
    q = (x @ params["wq"]).reshape(B, S, H, nope + rope_d)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    ckv = rmsnorm(params["kv_norm"], x @ params["w_dkv"], cfg.norm_eps)
    k_rope = apply_rope((x @ params["w_kr"])[..., None, :], positions,
                        cfg.rope_theta)
    k_nope = (ckv @ params["w_uk"]).reshape(B, S, H, nope)
    v = (ckv @ params["w_uv"]).reshape(B, S, H, hv)
    qf = jnp.concatenate([q_nope, q_rope], axis=-1)
    kf = jnp.concatenate([k_nope, jnp.broadcast_to(k_rope, (B, S, H, rope_d))],
                         axis=-1)
    if cfg.attn_impl == "naive":
        o = _naive_attention(qf, kf, v)
    elif cfg.attn_block_skip:
        o = causal_divide_conquer(qf, kf, v, q_block=cfg.attn_q_block,
                                  leaf=2 * cfg.attn_q_block, unroll=unroll)
    else:
        o = flash_attention(qf, kf, v, causal=True, q_block=cfg.attn_q_block,
                            kv_block=cfg.attn_kv_block, unroll=unroll)
    return o.reshape(B, S, H * hv) @ params["wo"]


def mla_cache_init(cfg, batch: int, seq_len: int) -> dict:
    dt = cfg.param_dtype
    return {
        "ckv": jnp.zeros((batch, seq_len, cfg.kv_lora_rank), dt),
        "k_rope": jnp.zeros((batch, seq_len, cfg.qk_rope_dim), dt),
        "pos": jnp.full((batch, seq_len), -1, jnp.int32),
    }


def mla_decode(cfg, params, x, pos, cache):
    """Absorbed-matrix decode over the compressed cache (the memory- and
    flop-efficient MLA decode; the naive alternative decompresses the whole
    cache every step)."""
    B, _, D = x.shape
    H = cfg.n_heads
    r, nope, rope_d, hv = (cfg.kv_lora_rank, cfg.qk_nope_dim,
                           cfg.qk_rope_dim, cfg.v_head_dim)
    q = (x @ params["wq"]).reshape(B, H, nope + rope_d)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = apply_rope(q_rope[:, None], pos[:, None], cfg.rope_theta)[:, 0]
    ckv_t = rmsnorm(params["kv_norm"], (x @ params["w_dkv"])[:, 0],
                    cfg.norm_eps)
    k_rope_t = apply_rope((x @ params["w_kr"])[:, :, None, :], pos[:, None],
                          cfg.rope_theta)[:, 0, 0]
    cap = cache["ckv"].shape[1]
    slot = pos % cap
    bidx = jnp.arange(B)
    ckv_c = cache["ckv"].at[bidx, slot].set(ckv_t)
    kr_c = cache["k_rope"].at[bidx, slot].set(k_rope_t)
    pos_buf = cache["pos"].at[bidx, slot].set(pos)
    if cfg.decode_cache_hint:
        from repro.sharding.context import constrain, get_mesh
        if get_mesh() is not None:
            dp = tuple(a for a in ("pod", "data")
                       if a in get_mesh().axis_names)
            ckv_c = constrain(ckv_c, dp, None, None)
            kr_c = constrain(kr_c, dp, None, None)
    # absorb W_uk into q: q_abs[b,h,r] = q_nope[b,h,n] . W_uk[r, h, n]
    w_uk = params["w_uk"].reshape(r, H, nope)
    q_abs = jnp.einsum("bhn,rhn->bhr", q_nope, w_uk,
                       preferred_element_type=F32).astype(x.dtype)
    scale = (nope + rope_d) ** -0.5
    s = (jnp.einsum("bhr,bsr->bhs", q_abs, ckv_c, preferred_element_type=F32)
         + jnp.einsum("bhd,bsd->bhs", q_rope, kr_c,
                      preferred_element_type=F32)) * scale
    valid = (pos_buf >= 0) & (pos_buf <= pos[:, None])
    s = jnp.where(valid[:, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    ctx = jnp.einsum("bhs,bsr->bhr", p.astype(x.dtype), ckv_c,
                     preferred_element_type=F32).astype(x.dtype)
    w_uv = params["w_uv"].reshape(r, H, hv)
    o = jnp.einsum("bhr,rhv->bhv", ctx, w_uv,
                   preferred_element_type=F32).astype(x.dtype)
    out = o.reshape(B, 1, H * hv) @ params["wo"]
    return out, {"ckv": ckv_c, "k_rope": kr_c, "pos": pos_buf}
