"""Shared primitive layers: RMSNorm, RoPE, SwiGLU MLP, embeddings.

All functions are pure (params-in, activations-out).  Matmul accumulation is
fp32 (``preferred_element_type``) with bf16 storage, matching TPU MXU usage.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

F32 = jnp.float32


def dot(x, w):
    """Matmul with fp32 accumulation, result cast back to x.dtype."""
    return jax.lax.dot_general(
        x, w, (((x.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=F32).astype(x.dtype)


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------
def rmsnorm_init(d: int, dtype) -> dict:
    return {"scale": jnp.zeros((d,), dtype=F32)}  # (1+scale) parametrisation


def rmsnorm(params, x, eps: float):
    xf = x.astype(F32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps) * (1.0 + params["scale"])
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------
def rope_freqs(head_dim: int, theta: float):
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(half, dtype=F32) / half))


def apply_rope(x, positions, theta: float):
    """x: [..., T, H, hd]; positions: broadcastable to [..., T]."""
    hd = x.shape[-1]
    inv = rope_freqs(hd, theta)                       # [hd/2]
    ang = positions.astype(F32)[..., None] * inv      # [..., T, hd/2]
    cos = jnp.cos(ang)[..., None, :]                  # [..., T, 1, hd/2]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(F32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# SwiGLU MLP
# ---------------------------------------------------------------------------
def mlp_init(key, d_model: int, d_ff: int, dtype) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    s_in = d_model ** -0.5
    s_ff = d_ff ** -0.5
    return {
        "wi": (jax.random.normal(k1, (d_model, d_ff), dtype=F32) * s_in).astype(dtype),
        "wg": (jax.random.normal(k2, (d_model, d_ff), dtype=F32) * s_in).astype(dtype),
        "wo": (jax.random.normal(k3, (d_ff, d_model), dtype=F32) * s_ff).astype(dtype),
    }


def mlp_apply(params, x):
    h = dot(x, params["wi"])
    g = dot(x, params["wg"])
    h = h * jax.nn.silu(g.astype(F32)).astype(h.dtype)
    return dot(h, params["wo"])


# ---------------------------------------------------------------------------
# Embeddings
# ---------------------------------------------------------------------------
def embed_init(key, vocab: int, d_model: int, dtype) -> dict:
    tbl = jax.random.normal(key, (vocab, d_model), dtype=F32) * (d_model ** -0.5)
    return {"table": tbl.astype(dtype)}


def embed_lookup(params, tokens):
    return jnp.take(params["table"], tokens, axis=0)


def lm_head_init(key, d_model: int, vocab: int, dtype) -> dict:
    tbl = jax.random.normal(key, (d_model, vocab), dtype=F32) * (d_model ** -0.5)
    return {"table": tbl.astype(dtype)}


def logits_from_hidden(cfg, params, x):
    """x: [B, T, D] -> logits [B, T, V] (vocab axis model-sharded)."""
    if cfg.tie_embeddings:
        w = params["embed"]["table"].T          # [D, V]
    else:
        w = params["lm_head"]["table"]
    return jax.lax.dot_general(
        x, w, (((2,), (0,)), ((), ())), preferred_element_type=F32)
