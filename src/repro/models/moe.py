"""Mixture-of-Experts FFN with sort-based capacity dispatch.

Dispatch is the TPU-native sort route (argsort tokens by expert, rank
within expert, scatter into a fixed-capacity [E, C, D] buffer) rather than
the GShard one-hot-einsum route: the one-hot dispatch einsum costs
2*T*E*C*D flops — for the 384-expert configs here that is >10x the expert
matmul itself, so sort-dispatch is the only roofline-sane baseline.
Tokens beyond capacity are dropped (standard); the router adds the usual
load-balance + z losses.  Expert weights are expert-sharded (EP over the
"model" mesh axis).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

F32 = jnp.float32


def moe_init(cfg, key) -> dict:
    dt = cfg.param_dtype
    D, E, F = cfg.d_model, cfg.n_experts, cfg.moe_d_ff
    ks = jax.random.split(key, 5)
    s_in, s_ff = D ** -0.5, F ** -0.5
    p = {
        "router": (jax.random.normal(ks[0], (D, E), F32) * s_in),
        "e_wi": (jax.random.normal(ks[1], (E, D, F), F32) * s_in).astype(dt),
        "e_wg": (jax.random.normal(ks[2], (E, D, F), F32) * s_in).astype(dt),
        "e_wo": (jax.random.normal(ks[3], (E, F, D), F32) * s_ff).astype(dt),
    }
    if cfg.n_shared_experts:
        from repro.models.layers import mlp_init
        p["shared"] = mlp_init(ks[4], D, F * cfg.n_shared_experts, dt)
    return p


def _capacity(cfg, T: int) -> int:
    c = int(T * cfg.top_k * cfg.capacity_factor / cfg.n_experts)
    return max(8, (c + 7) // 8 * 8)


def moe_apply(cfg, params, x):
    """x: [B,S,D] -> (y [B,S,D], aux_loss scalar)."""
    B, S, D = x.shape
    E, k = cfg.n_experts, cfg.top_k
    T = B * S
    C = _capacity(cfg, T)
    xf = x.reshape(T, D)

    logits = (xf @ params["router"].astype(x.dtype)).astype(F32)   # [T,E]
    probs = jax.nn.softmax(logits, axis=-1)
    gate, eidx = jax.lax.top_k(probs, k)                            # [T,k]
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    # aux losses: load-balance (Switch) + router z-loss
    density = jnp.zeros((E,), F32).at[eidx.reshape(-1)].add(
        jnp.ones((T * k,), F32)) / (T * k)
    p_mean = probs.mean(0)
    aux = E * jnp.sum(density * p_mean)
    zloss = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)
    aux_loss = 0.01 * aux + 0.001 * zloss

    from repro.sharding.context import get_mesh
    if cfg.moe_impl == "smap" and get_mesh() is not None:
        out = _dispatch_smap(cfg, params, xf, eidx, gate)
    else:
        out = _dispatch_gspmd(cfg, params, xf, eidx, gate, C)

    if cfg.n_shared_experts:
        from repro.models.layers import mlp_apply
        out = out + mlp_apply(params["shared"], xf)
    return out.reshape(B, S, D), aux_loss


def _dispatch_gspmd(cfg, params, xf, eidx, gate, C):
    """Global sort-based dispatch (baseline): scatter into the [E, C, D]
    buffer under GSPMD.  GSPMD realises the cross-shard scatters as
    partial-scatter + all-reduce over data — the §Perf hillclimb B baseline."""
    T, D = xf.shape
    E, k = cfg.n_experts, cfg.top_k
    e_flat = eidx.reshape(-1)                                       # [T*k]
    t_flat = jnp.repeat(jnp.arange(T), k)
    g_flat = gate.reshape(-1)
    order = jnp.argsort(e_flat)
    e_s, t_s, g_s = e_flat[order], t_flat[order], g_flat[order]
    counts = jnp.zeros((E,), jnp.int32).at[e_flat].add(1)
    starts = jnp.cumsum(counts) - counts                            # exclusive
    rank = jnp.arange(T * k) - starts[e_s]
    keep = rank < C
    dest = jnp.where(keep, e_s * C + rank, E * C)                   # E*C = drop

    xs = jnp.zeros((E * C + 1, D), xf.dtype).at[dest].set(xf[t_s])
    xs = xs[:-1].reshape(E, C, D)

    h = jnp.einsum("ecd,edf->ecf", xs, params["e_wi"],
                   preferred_element_type=F32).astype(xf.dtype)
    g = jnp.einsum("ecd,edf->ecf", xs, params["e_wg"],
                   preferred_element_type=F32)
    h = h * jax.nn.silu(g).astype(h.dtype)
    ys = jnp.einsum("ecf,efd->ecd", h, params["e_wo"],
                    preferred_element_type=F32).astype(xf.dtype)

    ys_flat = jnp.concatenate([ys.reshape(E * C, D),
                               jnp.zeros((1, D), xf.dtype)], 0)
    contrib = ys_flat[dest] * (g_s * keep)[:, None].astype(xf.dtype)
    return jnp.zeros((T, D), xf.dtype).at[t_s].add(contrib)


def _dispatch_smap(cfg, params, xf, eidx, gate):
    """Shard_map expert-parallel dispatch (§Perf hillclimb B).

    TP activations are logically replicated over "model", so each expert
    shard SELECTS its own tokens locally — the dispatch needs no
    collectives at all; only the combined output psums over "model"
    ([T_local, D], the same size as a standard TP MLP all-reduce).
    Capacity is per (data-shard, expert): slight drop-semantics change vs
    the global-capacity baseline (documented in EXPERIMENTS.md)."""
    from jax.sharding import PartitionSpec as P
    from repro.sharding.context import get_mesh
    mesh = get_mesh()
    T, D = xf.shape
    E, k, F = cfg.n_experts, cfg.top_k, cfg.moe_d_ff
    msz = mesh.shape.get("model", 1)
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    dp_spec = dp if len(dp) > 1 else (dp[0] if dp else None)
    dpsz = 1
    for a in dp:
        dpsz *= mesh.shape[a]
    if T % dpsz or E % msz:
        return _dispatch_gspmd(cfg, params, xf, eidx, gate, _capacity(cfg, T))
    Tl = T // dpsz
    E_l = E // msz
    C = max(8, (int(Tl * k * cfg.capacity_factor) // E + 7) // 8 * 8)

    def body(x_l, e_l, g_l, wi, wg, wo):
        mi = jax.lax.axis_index("model")
        e_flat = e_l.reshape(-1)
        t_flat = jnp.repeat(jnp.arange(Tl), k)
        g_flat = g_l.reshape(-1)
        mine = (e_flat >= mi * E_l) & (e_flat < (mi + 1) * E_l)
        e_loc = jnp.where(mine, e_flat - mi * E_l, E_l)
        pos = jnp.arange(Tl * k)
        order = jnp.lexsort((pos, e_loc))
        e_s, t_s, g_s = e_loc[order], t_flat[order], g_flat[order]
        starts = jnp.searchsorted(e_s, e_s)
        rank = pos - starts
        keep = (e_s < E_l) & (rank < C)
        dest = jnp.where(keep, e_s * C + rank, E_l * C)
        xs = jnp.zeros((E_l * C + 1, D), x_l.dtype).at[dest].set(x_l[t_s])
        xs = xs[:-1].reshape(E_l, C, D)
        h = jnp.einsum("ecd,edf->ecf", xs, wi,
                       preferred_element_type=F32).astype(x_l.dtype)
        g = jnp.einsum("ecd,edf->ecf", xs, wg, preferred_element_type=F32)
        h = h * jax.nn.silu(g).astype(h.dtype)
        ys = jnp.einsum("ecf,efd->ecd", h, wo,
                        preferred_element_type=F32).astype(x_l.dtype)
        ys_flat = jnp.concatenate([ys.reshape(E_l * C, D),
                                   jnp.zeros((1, D), x_l.dtype)], 0)
        contrib = ys_flat[dest] * (g_s * keep)[:, None].astype(x_l.dtype)
        out = jnp.zeros((Tl, D), x_l.dtype).at[t_s].add(contrib)
        return jax.lax.psum(out, "model")

    from repro.sharding.smap import shard_map
    fn = shard_map(
        body, mesh,
        (P(dp_spec, None), P(dp_spec, None), P(dp_spec, None),
         P("model", None, None), P("model", None, None),
         P("model", None, None)),
        P(dp_spec, None))
    return fn(xf, eidx, gate.astype(xf.dtype),
              params["e_wi"], params["e_wg"], params["e_wo"])
