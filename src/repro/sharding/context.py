"""Ambient mesh context for in-model sharding constraints.

Model code is mesh-agnostic; launchers (dryrun / trainer / layer-cost
lowering) set the mesh here, and `constrain(x, *axes)` applies
with_sharding_constraint when a mesh is active (no-op otherwise, so unit
tests and single-device paths are untouched).  Axis entries may be None,
an axis name, or a tuple of names; axes that don't divide the dim are
dropped automatically.
"""
from __future__ import annotations

import contextlib
import contextvars

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

_MESH = contextvars.ContextVar("repro_mesh", default=None)


def set_mesh(mesh):
    _MESH.set(mesh)


def get_mesh():
    return _MESH.get()


@contextlib.contextmanager
def use_mesh(mesh):
    tok = _MESH.set(mesh)
    try:
        yield
    finally:
        _MESH.reset(tok)


def _axis_size(mesh, names) -> int:
    if names is None:
        return 1
    s = 1
    for n in (names if isinstance(names, tuple) else (names,)):
        s *= mesh.shape[n]
    return s


def constrain(x, *axes):
    """Apply a sharding constraint if a mesh is active and dims divide."""
    mesh = _MESH.get()
    if mesh is None:
        return x
    spec = []
    for dim, ax in zip(x.shape, axes):
        if ax is None:
            spec.append(None)
            continue
        valid = tuple(a for a in ((ax,) if not isinstance(ax, tuple) else ax)
                      if a in mesh.axis_names)
        sz = _axis_size(mesh, valid) if valid else 1
        spec.append((valid if len(valid) > 1 else (valid[0] if valid else None))
                    if valid and dim % sz == 0 and dim >= sz else None)
    spec += [None] * (x.ndim - len(spec))
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*spec)))
