from repro.sharding.partition import (  # noqa: F401
    dp_axes, param_pspecs, params_sharding, opt_pspecs, input_pspecs,
    cache_pspecs, to_named, batch_pspec,
)
