"""Sharding rules: map every parameter / input / cache leaf to a
PartitionSpec over the production mesh ("pod", "data", "model").

Parallelism map (see DESIGN.md):
  * DP  — batch over ("pod", "data")
  * TP  — column/row parallel weights over "model" (Megatron layout)
  * EP  — MoE experts over "model"
  * SP  — sequence over "data" when batch==1 (long-context decode)
  * ZeRO-1 — optimizer state additionally sharded over "data"
  * FSDP — params additionally sharded over "data" (cfg.fsdp; required for
    the 1T-param config)

Rules are keyed on (leaf name, trailing ndim); stacked stage parameters
(leading [n_rep] axis) reuse the block rules with the prefix replicated.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P


def dp_axes(mesh) -> tuple:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _axis_size(mesh, names) -> int:
    s = 1
    for n in (names if isinstance(names, tuple) else (names,)):
        s *= mesh.shape[n]
    return s


# name -> (trailing_ndim, trailing spec)
_RULES: dict[tuple[str, int], tuple] = {
    # attention / mlp (column, row)
    ("wq", 2): (None, "model"),
    ("wk", 2): (None, "model"),
    ("wv", 2): (None, "model"),
    ("wo", 2): ("model", None),
    ("wi", 2): (None, "model"),
    ("wg", 2): (None, "model"),
    # MLA
    ("w_dkv", 2): (None, "model"),
    ("w_kr", 2): (None, None),
    ("w_uk", 2): (None, "model"),
    ("w_uv", 2): (None, "model"),
    # MoE (expert-parallel)
    ("router", 2): (None, None),
    ("e_wi", 3): ("model", None, None),
    ("e_wg", 3): ("model", None, None),
    ("e_wo", 3): ("model", None, None),
    # mamba1
    ("in_x", 2): (None, "model"),
    ("in_z", 2): (None, "model"),
    ("conv_w", 2): (None, "model"),
    ("conv_b", 1): ("model",),
    ("x_proj", 2): ("model", None),
    ("dt_proj", 2): (None, "model"),
    ("dt_bias", 1): ("model",),
    ("A_log", 2): ("model", None),
    ("A_log", 1): (None,),
    ("ssm_D", 1): ("model",),
    ("ssm_D", 2): ("model", None),
    ("out_proj", 2): ("model", None),
    # mamba2 extras
    ("in_B", 2): (None, "model"),
    ("in_C", 2): (None, "model"),
    ("in_dt", 2): (None, None),
    ("conv_xw", 2): (None, "model"),
    ("conv_xb", 1): ("model",),
    ("conv_Bw", 2): (None, "model"),
    ("conv_Bb", 1): ("model",),
    ("conv_Cw", 2): (None, "model"),
    ("conv_Cb", 1): ("model",),
    ("dt_bias", 2): (None, None),
}


def _leaf_name(path) -> str:
    for p in reversed(path):
        if isinstance(p, jax.tree_util.DictKey):
            return p.key
    return ""


def _top_name(path) -> str:
    p = path[0]
    return p.key if isinstance(p, jax.tree_util.DictKey) else ""


def _with_extra_data(spec: tuple, shape, mesh, dp) -> tuple:
    """Add the data axis to the first unsharded dim divisible by it
    (ZeRO/FSDP extra sharding).  Falls back to the original spec."""
    dsz = _axis_size(mesh, dp)
    spec = list(spec)
    for i, (s, dim) in enumerate(zip(spec, shape)):
        if s is None and dim % dsz == 0 and dim >= dsz:
            spec[i] = dp if len(dp) > 1 else dp[0]
            return tuple(spec)
    return tuple(spec)


def param_pspecs(cfg, params_tree, mesh, *, extra_data: bool = False):
    """PartitionSpec tree for a params(-like) tree.  ``extra_data`` adds
    data-axis sharding (used for FSDP params and ZeRO-1 optimizer state)."""
    dp = dp_axes(mesh)
    msz = mesh.shape.get("model", 1)

    def rule(path, leaf):
        shape = leaf.shape
        name = _leaf_name(path)
        top = _top_name(path)
        if top in ("embed", "lm_head"):
            if name == "table" and len(shape) >= 2:
                if top == "embed":
                    spec = [None] * (len(shape) - 2) + ["model", None]
                else:
                    spec = [None] * (len(shape) - 2) + [None, "model"]
            else:
                spec = [None] * len(shape)
        else:
            hit = None
            for t in range(min(len(shape), 3), 0, -1):
                if (name, t) in _RULES:
                    hit = (t, _RULES[(name, t)])
                    break
            if hit is None:
                spec = [None] * len(shape)
            else:
                t, trailing = hit
                spec = [None] * (len(shape) - t) + list(trailing)
        # drop model sharding if not divisible
        for i, s in enumerate(spec):
            if s == "model" and (shape[i] % msz or shape[i] < msz):
                spec[i] = None
        spec = tuple(spec)
        if (extra_data or cfg.fsdp) and leaf.ndim >= 2 and dp:
            spec = _with_extra_data(spec, shape, mesh, dp)
        return P(*spec)

    return jax.tree_util.tree_map_with_path(rule, params_tree)


def to_named(mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def params_sharding(cfg, params_tree, mesh):
    return to_named(mesh, param_pspecs(cfg, params_tree, mesh))


def opt_pspecs(cfg, params_tree, mesh):
    """Optimizer-state (m, v) specs: param specs + ZeRO-1 data sharding."""
    return param_pspecs(cfg, params_tree, mesh,
                        extra_data=cfg.zero1)


def batch_pspec(mesh, global_batch: int):
    """Shard batch over as much of the dp axes as divisibility allows."""
    dp = dp_axes(mesh)
    use = []
    rem = global_batch
    for a in dp:
        if rem % mesh.shape[a] == 0:
            use.append(a)
            rem //= mesh.shape[a]
    return tuple(use)


def input_pspecs(cfg, shape_spec, inputs_tree, mesh):
    """Specs for the model inputs of a given shape cell."""
    dp = batch_pspec(mesh, shape_spec.global_batch)
    bspec = dp if dp else None
    full_dp = dp_axes(mesh)
    seq_spec = None
    if not dp and shape_spec.global_batch == 1:
        seq_spec = full_dp          # SP: shard sequence instead (B==1)

    def rule(path, leaf):
        name = _leaf_name(path)
        if name in ("tokens", "targets", "embeds"):
            if leaf.ndim >= 2 and leaf.shape[1] > 1:
                spec = [bspec, seq_spec] + [None] * (leaf.ndim - 2)
            else:
                spec = [bspec] + [None] * (leaf.ndim - 1)
            return P(*spec)
        if name == "pos":
            return P(bspec)
        return P(*([None] * leaf.ndim))

    return jax.tree_util.tree_map_with_path(rule, inputs_tree)


def cache_pspecs(cfg, shape_spec, cache_tree, mesh):
    """KV/SSM cache specs: batch over dp (or sequence over dp when B==1);
    heads/channels over model when divisible."""
    dp = batch_pspec(mesh, shape_spec.global_batch)
    bspec = dp if dp else None
    full_dp = dp_axes(mesh)
    sp_mode = (not dp) and shape_spec.global_batch == 1
    msz = mesh.shape.get("model", 1)

    def rule(path, leaf):
        name = _leaf_name(path)
        shape = leaf.shape
        # caches may be stacked [n_rep, ...] inside scan stages
        prefix = 0
        nd = leaf.ndim
        # find the batch dim: the first dim equal to global_batch
        try:
            bdim = list(shape).index(shape_spec.global_batch)
        except ValueError:
            bdim = None
        spec = [None] * nd
        hint_seq = getattr(cfg, "decode_cache_hint", False)
        if name in ("k", "v"):                  # [.., B, cap, Hkv, hd]
            if bdim is not None and not sp_mode:
                spec[bdim] = bspec
            if sp_mode and nd >= 3:
                spec[-3] = full_dp              # shard cache length
            if (hint_seq and not sp_mode and shape[-3] % msz == 0
                    and shape[-3] >= msz and bdim != nd - 3):
                spec[-3] = "model"              # flash-decode: seq over model
            elif shape[-2] % msz == 0 and shape[-2] >= msz:
                spec[-2] = "model"
            elif shape[-1] % msz == 0 and shape[-1] >= msz:
                spec[-1] = "model"
        elif name in ("ckv", "k_rope"):         # [.., B, cap, r]
            if bdim is not None and not sp_mode:
                spec[bdim] = bspec
            if sp_mode and nd >= 2:
                spec[-2] = full_dp
        elif name == "pos":                     # [.., B, cap]
            if bdim is not None and not sp_mode:
                spec[bdim] = bspec
            if sp_mode:
                spec[-1] = full_dp
            elif (hint_seq and shape[-1] % msz == 0 and shape[-1] >= msz
                  and bdim != nd - 1):
                spec[-1] = "model"
        elif name == "ssm":                     # [.., B, di, N] | [.., B,H,P,N]
            if bdim is not None:
                spec[bdim] = bspec
            ch_dim = nd - 3 if name == "ssm" else None
            if shape[ch_dim] % msz == 0 and shape[ch_dim] >= msz:
                spec[ch_dim] = "model"
        elif name.startswith("conv"):           # [.., B, k-1, C]
            if bdim is not None:
                spec[bdim] = bspec
            if shape[-1] % msz == 0 and shape[-1] >= msz:
                spec[-1] = "model"
        return P(*spec)

    return jax.tree_util.tree_map_with_path(rule, cache_tree)
