"""Cross-version shard_map.

jax >= 0.6 exposes ``jax.shard_map`` (with ``check_vma``); 0.4.x only has
``jax.experimental.shard_map.shard_map`` (with ``check_rep``).  Every
shard_map call site in the repo goes through this helper so the whole tree
runs on either line (the 0.4.37 container included).
"""
from __future__ import annotations

import jax


def axis_size(axis: str) -> int:
    """Static mesh-axis size inside shard_map: jax.lax.axis_size where
    available (>= 0.5), else the classic psum-of-1 idiom (constant-folded,
    still static)."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis)
    return jax.lax.psum(1, axis)


def shard_map(f, mesh, in_specs, out_specs):
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map as _sm
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=False)
