"""Roofline term derivation from compiled dry-run artifacts.

Hardware model: TPU v5e — 197 TFLOP/s bf16 per chip, 819 GB/s HBM,
~50 GB/s/link ICI.

Conventions (validated against analytic flop counts in task-1 probe):
  * ``compiled.cost_analysis()`` reports PER-DEVICE flops / bytes of the
    SPMD-partitioned module, so terms divide by per-chip peaks directly
    (the "/ chips" in the spec formulas is already applied by SPMD
    partitioning).
  * XLA counts a while/scan body ONCE, so roofline lowerings unroll every
    scan (``unroll=True`` threads through layers / attention blocks / ssm
    chunks / loss chunks).
  * collective bytes are summed from the post-partitioning HLO text:
    result-shape bytes of every all-reduce / all-gather / reduce-scatter /
    all-to-all / collective-permute instruction (async *-start counted
    once).  These are per-device shapes -> per-chip link traffic.
"""
from __future__ import annotations

import re
from dataclasses import dataclass


@dataclass(frozen=True)
class HWSpec:
    peak_flops: float = 197e12      # bf16 per chip
    hbm_bw: float = 819e9           # bytes/s per chip
    ici_bw: float = 50e9            # bytes/s per link


HW = HWSpec()

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "token": 0, "s4": 1, "u4": 1,
}

_COLL_RE = re.compile(
    r"^\s*(?:%?[\w.\-]+ = )?"
    r"(\(?[\w\[\],{}\s/]*\)?)\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\(", re.M)

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes_from_hlo(hlo_text: str) -> dict:
    """Sum per-device result bytes of collective ops, by type."""
    out = {"all-reduce": 0, "all-gather": 0, "reduce-scatter": 0,
           "all-to-all": 0, "collective-permute": 0}
    counts = dict.fromkeys(out, 0)
    for m in _COLL_RE.finditer(hlo_text):
        shape_str, kind, start = m.group(1), m.group(2), m.group(3)
        # *-done duplicates are not matched (no '(' after shape for done);
        # count the -start (or sync) form once.
        b = _shape_bytes(shape_str)
        out[kind] += b
        counts[kind] += 1
    return {"bytes_by_type": out, "counts_by_type": counts,
            "total_bytes": sum(out.values())}


def roofline_terms(flops_per_dev: float, bytes_per_dev: float,
                   coll_bytes_per_dev: float, hw: HWSpec = HW) -> dict:
    t_c = flops_per_dev / hw.peak_flops
    t_m = bytes_per_dev / hw.hbm_bw
    t_n = coll_bytes_per_dev / hw.ici_bw
    terms = {"compute_s": t_c, "memory_s": t_m, "collective_s": t_n}
    dom = max(terms, key=terms.get)
    bound = max(t_c, t_m, t_n)
    terms["dominant"] = dom
    terms["roofline_fraction_compute"] = t_c / bound if bound else 0.0
    return terms


def model_flops(cfg, n_params: int, n_active: int, shape) -> float:
    """Analytic MODEL_FLOPS: 6·N·D for train, 2·N·tokens for inference."""
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    # decode: one token per sequence
    return 2.0 * n_active * shape.global_batch


def active_params(cfg, params_tree_shapes) -> tuple[int, int]:
    """(total, active) parameter counts; active discounts routed experts
    to their top-k/E share."""
    import jax
    total = 0
    routed = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(params_tree_shapes)[0]:
        names = [p.key for p in path if hasattr(p, "key")]
        total += leaf.size
        if any(n in ("e_wi", "e_wg", "e_wo") for n in names):
            routed += leaf.size
    active = total - routed
    if cfg.n_experts:
        active += routed * cfg.top_k / cfg.n_experts
    return total, int(active)
