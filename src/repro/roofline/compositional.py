"""Compositional cost analysis.

XLA's cost_analysis counts while/scan bodies ONCE, and fully unrolling a
whole 80-layer train step makes single-core compiles take >10 min.  The
compositional approach is exact and fast:

    cost(cell) = cost(base) + sum_spec  n_layers(spec) * cost(layer(spec))

where cost(layer) is obtained by lowering ONE layer (fwd + vjp + its AdamW
slice for train cells; the decode step for decode cells) with every inner
scan unrolled, under the same mesh/shardings as the full program, and
cost(base) is the n_layers=0 program (frontend, final norm, blockwise CE
loss, optimizer for non-layer params).  flops, HBM bytes and collective
bytes all compose this way; memory_analysis comes from the full scanned
compile (deployment-realistic), recorded alongside.

Known approximations (documented in EXPERIMENTS.md §Roofline): GSPMD may
fuse across the layer boundary in the full program (small), and the global
grad-norm reduction over layer params (~2 flops/param) is attributed to the
base program only.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import transformer as tfm
from repro.roofline.analysis import collective_bytes_from_hlo
from repro.sharding.partition import (batch_pspec, cache_pspecs, dp_axes,
                                      input_pspecs, opt_pspecs, param_pspecs,
                                      to_named)

F32 = jnp.float32


def _cost_of(lowered):
    compiled = lowered.compile()
    ca = compiled.cost_analysis()
    coll = collective_bytes_from_hlo(compiled.as_text())
    return {
        "flops": float(ca.get("flops", 0.0)),
        "bytes": float(ca.get("bytes accessed", 0.0)),
        "coll_bytes": float(coll["total_bytes"]),
        "coll_by_type": coll["bytes_by_type"],
    }


def _acc(total, part, n=1):
    total["flops"] += n * part["flops"]
    total["bytes"] += n * part["bytes"]
    total["coll_bytes"] += n * part["coll_bytes"]
    for k, v in part["coll_by_type"].items():
        total["coll_by_type"][k] = total["coll_by_type"].get(k, 0) + n * v
    return total


def _zero():
    return {"flops": 0.0, "bytes": 0.0, "coll_bytes": 0.0, "coll_by_type": {}}


def _act_specs(cfg, shape, mesh):
    dp = batch_pspec(mesh, shape.global_batch)
    bspec = dp if dp else None
    sp = dp_axes(mesh) if (not dp and shape.global_batch == 1) else None
    return bspec, sp


def _x_spec(cfg, shape, mesh, seq_dim=True):
    bspec, sp = _act_specs(cfg, shape, mesh)
    return P(bspec, sp, None) if seq_dim else P(bspec, None, None)


def layer_cost_train(cfg: ModelConfig, spec, shape, mesh) -> dict:
    """Cost of one layer's fwd + bwd (with remat recompute) + AdamW slice."""
    B, S = shape.global_batch, shape.seq_len
    key = jax.random.PRNGKey(0)
    blk_s = jax.eval_shape(lambda k: tfm._block_init(cfg, k, spec), key)
    shared_s = (jax.eval_shape(lambda k: tfm._shared_block_init(cfg, k), key)
                if spec[0] == "mamba2+shared" else None)
    p_tree = {"blk": blk_s} | ({"shared": shared_s} if shared_s else {})
    p_spec = param_pspecs(cfg, p_tree, mesh)
    o_spec = opt_pspecs(cfg, p_tree, mesh)
    x_s = jax.ShapeDtypeStruct((B, S, cfg.d_model), cfg.param_dtype)
    xp = _x_spec(cfg, shape, mesh)
    pos_s = jax.ShapeDtypeStruct((B, S), jnp.int32)

    def f(p, m, v, x, pos, ct):
        def fwd(p, x):
            y, aux = tfm._block_apply(cfg, p["blk"], spec, x, pos,
                                      p.get("shared"), unroll=True)
            return y, aux
        if cfg.remat == "unit":
            fwd = jax.checkpoint(fwd)
        (y, aux), vjp = jax.vjp(fwd, p, x)
        gp, gx = vjp((ct, jnp.ones((), F32)))
        # AdamW slice for this layer's params (matches optimizer cost/bytes)
        def upd(pp, gg, mm, vv):
            gg = gg.astype(F32)
            mm = 0.9 * mm + 0.1 * gg
            vv = 0.95 * vv + 0.05 * gg * gg
            pp = (pp.astype(F32) - 3e-4 * (mm / (jnp.sqrt(vv) + 1e-8)
                                           + 0.1 * pp.astype(F32))).astype(pp.dtype)
            return pp, mm, vv
        out = jax.tree.map(upd, p, gp, m, v)
        return y, gx, out

    lowered = jax.jit(f, in_shardings=(
        to_named(mesh, p_spec), to_named(mesh, o_spec), to_named(mesh, o_spec),
        NamedSharding(mesh, xp), None, NamedSharding(mesh, xp)),
    ).lower(p_tree,
            jax.tree.map(lambda l: jax.ShapeDtypeStruct(l.shape, F32), p_tree),
            jax.tree.map(lambda l: jax.ShapeDtypeStruct(l.shape, F32), p_tree),
            x_s, pos_s, x_s)
    return _cost_of(lowered)


def layer_cost_prefill(cfg: ModelConfig, spec, shape, mesh) -> dict:
    B, S = shape.global_batch, shape.seq_len
    key = jax.random.PRNGKey(0)
    blk_s = jax.eval_shape(lambda k: tfm._block_init(cfg, k, spec), key)
    shared_s = (jax.eval_shape(lambda k: tfm._shared_block_init(cfg, k), key)
                if spec[0] == "mamba2+shared" else None)
    p_tree = {"blk": blk_s} | ({"shared": shared_s} if shared_s else {})
    p_spec = param_pspecs(cfg, p_tree, mesh)
    x_s = jax.ShapeDtypeStruct((B, S, cfg.d_model), cfg.param_dtype)
    xp = _x_spec(cfg, shape, mesh)
    pos_s = jax.ShapeDtypeStruct((B, S), jnp.int32)

    def f(p, x, pos):
        y, _ = tfm._block_apply(cfg, p["blk"], spec, x, pos, p.get("shared"),
                                unroll=True)
        return y

    lowered = jax.jit(f, in_shardings=(to_named(mesh, p_spec),
                                       NamedSharding(mesh, xp), None),
                      ).lower(p_tree, x_s, pos_s)
    return _cost_of(lowered)


def layer_cost_decode(cfg: ModelConfig, spec, shape, mesh) -> dict:
    B, S = shape.global_batch, shape.seq_len
    key = jax.random.PRNGKey(0)
    blk_s = jax.eval_shape(lambda k: tfm._block_init(cfg, k, spec), key)
    shared_s = (jax.eval_shape(lambda k: tfm._shared_block_init(cfg, k), key)
                if spec[0] == "mamba2+shared" else None)
    p_tree = {"blk": blk_s} | ({"shared": shared_s} if shared_s else {})
    p_spec = param_pspecs(cfg, p_tree, mesh)
    cache_s = jax.eval_shape(lambda: tfm._block_cache_init(cfg, spec, B, S))
    c_spec = cache_pspecs(cfg, shape, cache_s, mesh)
    x_s = jax.ShapeDtypeStruct((B, 1, cfg.d_model), cfg.param_dtype)
    xp = _x_spec(cfg, shape, mesh, seq_dim=False)
    pos_s = jax.ShapeDtypeStruct((B,), jnp.int32)
    bspec, _ = _act_specs(cfg, shape, mesh)

    def f(p, c, x, pos):
        return tfm._block_decode(cfg, p["blk"], spec, x, pos, c,
                                 p.get("shared"))

    lowered = jax.jit(f, in_shardings=(
        to_named(mesh, p_spec), to_named(mesh, c_spec),
        NamedSharding(mesh, xp), NamedSharding(mesh, P(bspec))),
        out_shardings=(NamedSharding(mesh, xp), to_named(mesh, c_spec)),
        donate_argnums=(1,),
    ).lower(p_tree, cache_s, x_s, pos_s)
    return _cost_of(lowered)


def base_cost(cfg: ModelConfig, shape, mesh) -> dict:
    """n_layers=0 program: frontend + final norm + head/loss (+ optimizer
    over non-layer params for train)."""
    from repro.configs.base import input_specs
    from repro.optim.adamw import adamw_init
    from repro.serving.serve_step import prefill as prefill_fn
    from repro.train.step import train_step

    cfg0 = cfg.scaled(n_layers=0, first_k_dense=0, shared_attn_every=0)
    key = jax.random.PRNGKey(0)
    params_s = jax.eval_shape(lambda k: tfm.init_params(cfg0, k), key)
    p_shard = to_named(mesh, param_pspecs(cfg0, params_s, mesh))
    inputs = input_specs(cfg0, shape)
    in_shard = to_named(mesh, input_pspecs(cfg0, shape, inputs, mesh))
    if shape.kind == "train":
        opt_s = jax.eval_shape(adamw_init, params_s)
        o_shard = to_named(mesh, opt_pspecs(cfg0, opt_s, mesh))
        lowered = jax.jit(
            lambda p, o, b: train_step(cfg0, p, o, b, unroll=True),
            in_shardings=(p_shard, o_shard, in_shard),
            out_shardings=(p_shard, o_shard, None),
            donate_argnums=(0, 1)).lower(params_s, opt_s, inputs)
    elif shape.kind == "prefill":
        lowered = jax.jit(
            lambda p, b: prefill_fn(cfg0, p, b, unroll=True),
            in_shardings=(p_shard, in_shard)).lower(params_s, inputs)
    else:
        def f(p, b):
            x = tfm._frontend(cfg0, p, b)
            from repro.models.layers import logits_from_hidden, rmsnorm
            x = rmsnorm(p["final_norm"], x, cfg0.norm_eps)
            return logits_from_hidden(cfg0, p, x)[:, 0]
        lowered = jax.jit(f, in_shardings=(p_shard, in_shard)).lower(
            params_s, inputs)
    return _cost_of(lowered)


def compositional_cost(cfg: ModelConfig, shape, mesh) -> dict:
    """Total per-device cost composed from base + per-spec layer costs."""
    specs = cfg.layer_specs()
    uniq: dict = {}
    for s in specs:
        uniq[s] = uniq.get(s, 0) + 1
    total = _acc(_zero(), base_cost(cfg, shape, mesh))
    per_layer = {}
    for s, n in uniq.items():
        if shape.kind == "train":
            c = layer_cost_train(cfg, s, shape, mesh)
        elif shape.kind == "prefill":
            c = layer_cost_prefill(cfg, s, shape, mesh)
        else:
            c = layer_cost_decode(cfg, s, shape, mesh)
        per_layer["/".join(str(x) for x in s)] = {"count": n, **c}
        total = _acc(total, c, n)
    total["per_layer"] = per_layer
    return total
