"""Gradient compression for data-parallel sync: int8 quantisation with
error feedback (EF-SGD style).

The DP gradient all-reduce moves param-sized tensors every step; at 1000+
nodes the interconnect term dominates.  compress/decompress quantise to
int8 with a per-tensor scale; the residual (quantisation error) is carried
in a feedback buffer and added to the next step's gradient, which restores
convergence (the EF trick).  ``dp_allreduce_compressed`` is the shard_map
building block: quantise -> psum(int32) -> dequantise, an 8x reduction in
all-reduce bytes.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

F32 = jnp.float32


def compress(g, err):
    """g fp, err fp feedback.  Returns (q int8, scale, new_err)."""
    gf = g.astype(F32) + err
    scale = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
    new_err = gf - q.astype(F32) * scale
    return q, scale, new_err


def decompress(q, scale):
    return q.astype(F32) * scale


def ef_state(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, F32), params)


def dp_allreduce_compressed(grads, err, axis: str):
    """Inside shard_map over the data axis: error-feedback int8 all-reduce.
    Returns (mean grads fp32, new error state)."""
    from repro.sharding.smap import axis_size
    n = axis_size(axis)

    def one(g, e):
        gf = g.astype(F32) + e
        # agree on one scale across ranks (pmax) BEFORE quantising, so the
        # summed int8 payloads dequantise exactly; EF absorbs rounding
        scale = jax.lax.pmax(jnp.max(jnp.abs(gf)), axis) / 127.0
        scale = jnp.maximum(scale, 1e-12)
        q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
        new_e = gf - q.astype(F32) * scale
        s = jax.lax.psum(q.astype(jnp.int32), axis)
        out = s.astype(F32) * scale / n
        return out, new_e

    flat_g, td = jax.tree.flatten(grads)
    flat_e = td.flatten_up_to(err)
    outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (td.unflatten([o[0] for o in outs]),
            td.unflatten([o[1] for o in outs]))
