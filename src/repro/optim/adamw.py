"""AdamW with global-norm clipping.

State is kept in fp32 (m, v) regardless of parameter dtype; under ZeRO-1 the
state tree is sharded over the data axis (see sharding.partition.opt_pspecs)
so each data-parallel rank owns a slice — XLA keeps the update local to the
slice and all-gathers nothing (params themselves stay TP-sharded).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

F32 = jnp.float32


def adamw_init(params):
    zeros = lambda p: jnp.zeros(p.shape, F32)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree):
    leaves = [jnp.sum(jnp.square(x.astype(F32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_update(params, grads, state, *, lr=3e-4, b1=0.9, b2=0.95,
                 eps=1e-8, weight_decay=0.1, clip_norm=1.0):
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, clip_norm / jnp.maximum(gnorm, 1e-9))
    bc1 = 1.0 - b1 ** step.astype(F32)
    bc2 = 1.0 - b2 ** step.astype(F32)

    def upd(p, g, m, v):
        g = g.astype(F32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mh = m / bc1
        vh = v / bc2
        delta = mh / (jnp.sqrt(vh) + eps) + weight_decay * p.astype(F32)
        return (p.astype(F32) - lr * delta).astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "step": step}, gnorm
