"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax.numpy as jnp

I32 = jnp.int32
KEY_INF32 = jnp.iinfo(jnp.int32).max


def ref_hash_probe(bucket, qsig, qfp, sig, fp, addr, *, slots_per_bucket):
    """Oracle for hash_probe_kernel (mirrors core.hash_index.lookup)."""
    rows_sig = sig[bucket]
    rows_fp = fp[bucket]
    rows_addr = addr[bucket]
    CS = sig.shape[1]
    match = (rows_sig == qsig[:, None]) & (rows_fp == qfp[:, None])
    found = match.any(axis=1)
    off = jnp.argmax(match, axis=1)
    out_addr = jnp.where(found, jnp.take_along_axis(
        rows_addr, off[:, None], axis=1)[:, 0], -1)
    occ = (rows_sig != 0).sum(axis=1)
    S = slots_per_bucket
    acc = jnp.where(found, off // S + 1,
                    jnp.maximum((occ + S - 1) // S, 1))
    return out_addr, found.astype(I32), acc.astype(I32)


def ref_sorted_search(queries, keys, addrs, *, fanout=128):
    """Oracle for sorted_search_kernel (directory descent semantics)."""
    cap = keys.shape[0]
    levels = 1
    span = fanout
    while span < cap:
        span *= fanout
        levels += 1
    pos = jnp.zeros(queries.shape, I32)
    for li in range(levels):
        stride = fanout ** (levels - 1 - li)
        idx = pos[:, None] + jnp.arange(fanout, dtype=I32)[None, :] * stride
        node = keys[jnp.clip(idx, 0, cap - 1)]
        node = jnp.where(idx < cap, node, KEY_INF32)
        cnt = (node <= queries[:, None]).sum(axis=1).astype(I32)
        pos = pos + jnp.maximum(cnt - 1, 0) * stride
    found = keys[pos] == queries
    out = jnp.where(found, addrs[pos], -1)
    return out, found.astype(I32), jnp.full(queries.shape, levels, I32)


def ref_pending_lookup(lkeys, laddrs, lops, applied, tail, queries):
    """Oracle for the in-kernel pending-log probe (mirrors
    core.log.pending_lookup over the [applied, tail) ring window)."""
    cap = lkeys.shape[0]
    seq = applied + jnp.arange(cap)
    idx = seq % cap
    pv = seq < tail
    pk = jnp.where(pv, lkeys[idx], KEY_INF32)
    m = pk[None, :] == queries[:, None]
    hit = m.any(axis=1)
    last = (cap - 1) - jnp.argmax(m[:, ::-1], axis=1)
    op = jnp.where(hit, lops[idx][last], 0)
    addr = laddrs[idx][last]
    return hit, op, addr


def ref_backup_probe(cfg, skeys, saddrs, lkeys, laddrs, lops, lwin,
                     queries, rep_sel):
    """Oracle for backup_probe_kernel: per-replica pending-log (newest
    wins) then sorted descent, sequential replica-select overwrite."""
    R = skeys.shape[0]
    OP_PUT = 1
    addr_b = jnp.full(queries.shape, -1, I32)
    found_b = jnp.zeros(queries.shape, bool)
    acc_b = jnp.zeros(queries.shape, I32)
    for r in range(R):
        a_s, f_s, c_s = ref_sorted_search(queries, skeys[r], saddrs[r],
                                          fanout=cfg.fanout)
        hit, op, praw = ref_pending_lookup(lkeys[r], laddrs[r], lops[r],
                                           lwin[r, 0], lwin[r, 1], queries)
        a_r = jnp.where(hit, jnp.where(op == OP_PUT, praw, -1), a_s)
        f_r = jnp.where(hit, op == OP_PUT, f_s.astype(bool))
        sel = rep_sel[:, r] != 0
        addr_b = jnp.where(sel, a_r, addr_b)
        found_b = jnp.where(sel, f_r, found_b)
        acc_b = jnp.where(sel, c_s + 1, acc_b)
    return addr_b, found_b.astype(I32), acc_b


def ref_merge(ekeys, eaddrs, bkeys, baddrs, bops):
    """Oracle for merge_kernel (mirrors core.sorted_index.merge on int32
    arrays): newest-wins per key, DELETEs (op 2) compact away, op 0
    entries are ignored."""
    cap = ekeys.shape[0]
    m = bkeys.shape[0]
    OP_DEL = 2
    all_keys = jnp.concatenate(
        [ekeys, jnp.where(bops > 0, bkeys, KEY_INF32)])
    all_addrs = jnp.concatenate([eaddrs, baddrs])
    all_del = jnp.concatenate([jnp.zeros((cap,), bool), bops == OP_DEL])
    prio = jnp.concatenate(
        [jnp.zeros((cap,), I32), 1 + jnp.arange(m, dtype=I32)])
    order = jnp.lexsort((prio, all_keys))
    k = all_keys[order]
    a = all_addrs[order]
    d = all_del[order]
    is_last = jnp.concatenate([k[1:] != k[:-1], jnp.ones((1,), bool)])
    keep = is_last & (~d) & (k != KEY_INF32)
    dest = jnp.cumsum(keep) - 1
    dest = jnp.where(keep, dest, cap + m)
    nk = jnp.full((cap,), KEY_INF32, I32).at[dest].set(k, mode="drop")
    na = jnp.full((cap,), -1, I32).at[dest].set(a, mode="drop")
    return nk, na, keep.sum().astype(I32)


def ref_sort_pairs_stable(keys, vals):
    """Oracle for sort_pairs_stable_kernel: rowwise stable sort by key,
    payload rides the exact same permutation (index tie-break)."""
    order = jnp.argsort(keys, axis=1, stable=True)
    return (jnp.take_along_axis(keys, order, axis=1),
            jnp.take_along_axis(vals, order, axis=1))


def ref_mamba_scan(x, dt, B_ssm, C_ssm, A):
    """Oracle for mamba_scan_kernel: sequential selective scan."""
    import jax
    Bsz, S, di = x.shape
    N = B_ssm.shape[-1]
    f32 = jnp.float32

    def step(h, t):
        a = jnp.exp(dt[:, t].astype(f32)[..., None] * A)     # [B,di,N]
        b = ((dt[:, t] * x[:, t]).astype(f32)[..., None]
             * B_ssm[:, t].astype(f32)[:, None, :])
        h = a * h + b
        y = (h * C_ssm[:, t].astype(f32)[:, None, :]).sum(-1)
        return h, y.astype(x.dtype)

    h0 = jnp.zeros((Bsz, di, N), f32)
    _, ys = jax.lax.scan(step, h0, jnp.arange(S))
    return jnp.moveaxis(ys, 0, 1)


def ref_bitonic_sort(keys, vals):
    """Oracle for bitonic_sort_kernel: rowwise stable sort by key."""
    order = jnp.argsort(keys, axis=1, stable=True)
    return (jnp.take_along_axis(keys, order, axis=1),
            jnp.take_along_axis(vals, order, axis=1))
