"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax.numpy as jnp

I32 = jnp.int32
KEY_INF32 = jnp.iinfo(jnp.int32).max


def ref_hash_probe(bucket, qsig, qfp, sig, fp, addr, *, slots_per_bucket):
    """Oracle for hash_probe_kernel (mirrors core.hash_index.lookup)."""
    rows_sig = sig[bucket]
    rows_fp = fp[bucket]
    rows_addr = addr[bucket]
    CS = sig.shape[1]
    match = (rows_sig == qsig[:, None]) & (rows_fp == qfp[:, None])
    found = match.any(axis=1)
    off = jnp.argmax(match, axis=1)
    out_addr = jnp.where(found, jnp.take_along_axis(
        rows_addr, off[:, None], axis=1)[:, 0], -1)
    occ = (rows_sig != 0).sum(axis=1)
    S = slots_per_bucket
    acc = jnp.where(found, off // S + 1,
                    jnp.maximum((occ + S - 1) // S, 1))
    return out_addr, found.astype(I32), acc.astype(I32)


def ref_sorted_search(queries, keys, addrs, *, fanout=128):
    """Oracle for sorted_search_kernel (directory descent semantics)."""
    cap = keys.shape[0]
    levels = 1
    span = fanout
    while span < cap:
        span *= fanout
        levels += 1
    pos = jnp.zeros(queries.shape, I32)
    for li in range(levels):
        stride = fanout ** (levels - 1 - li)
        idx = pos[:, None] + jnp.arange(fanout, dtype=I32)[None, :] * stride
        node = keys[jnp.clip(idx, 0, cap - 1)]
        node = jnp.where(idx < cap, node, KEY_INF32)
        cnt = (node <= queries[:, None]).sum(axis=1).astype(I32)
        pos = pos + jnp.maximum(cnt - 1, 0) * stride
    found = keys[pos] == queries
    out = jnp.where(found, addrs[pos], -1)
    return out, found.astype(I32), jnp.full(queries.shape, levels, I32)


def ref_mamba_scan(x, dt, B_ssm, C_ssm, A):
    """Oracle for mamba_scan_kernel: sequential selective scan."""
    import jax
    Bsz, S, di = x.shape
    N = B_ssm.shape[-1]
    f32 = jnp.float32

    def step(h, t):
        a = jnp.exp(dt[:, t].astype(f32)[..., None] * A)     # [B,di,N]
        b = ((dt[:, t] * x[:, t]).astype(f32)[..., None]
             * B_ssm[:, t].astype(f32)[:, None, :])
        h = a * h + b
        y = (h * C_ssm[:, t].astype(f32)[:, None, :]).sum(-1)
        return h, y.astype(x.dtype)

    h0 = jnp.zeros((Bsz, di, N), f32)
    _, ys = jax.lax.scan(step, h0, jnp.arange(S))
    return jnp.moveaxis(ys, 0, 1)


def ref_bitonic_sort(keys, vals):
    """Oracle for bitonic_sort_kernel: rowwise stable sort by key."""
    order = jnp.argsort(keys, axis=1, stable=True)
    return (jnp.take_along_axis(keys, order, axis=1),
            jnp.take_along_axis(vals, order, axis=1))
