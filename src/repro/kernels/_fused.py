"""Pallas kernels for the serving hot path (block-vectorized).

These are the kernels ``kernels/ops.py`` dispatches to when
``cfg.use_kernels`` resolves to "on":

  * ``group_probe_kernel`` — the fused GET probe: hash-bucket chain walk
    + per-replica newest-wins pending-log lookup + sorted-directory
    descent + replica-select combine, all in ONE kernel (the paper's
    "dedicatedly chosen primitive per operation", offloaded to where the
    index lives — the same argument the SmartNIC ordered-KV line makes
    for pushing index logic onto the data path);
  * ``backup_probe_kernel`` / ``hash_probe_block_kernel`` /
    ``sorted_search_block_kernel`` — the individual probes (the sorted
    search also emits the descent position, which ``ops.range_query``
    turns into the SCAN lower bound);
  * ``merge_kernel`` — the bitonic-merge incremental apply: bitonic-sort
    the log batch by (key, arrival), place both sequences by branchless
    binary-search rank (merge-path), then the same newest-wins /
    tombstone-compacting keep pass as ``sorted_index.merge``;
  * ``sort_pairs_stable_kernel`` — rowwise stable (key, payload) sort
    (bitonic with an index tie-break).

Unlike the per-query DMA kernels in ``_hash_probe.py`` /
``_sorted_search.py`` (which model the paper's one-RTT RDMA reads and
remain the measured-access-count reference), these kernels tile the
QUERY batch through VMEM via BlockSpec and stage each table once per
block — the layout that wins on the VPU, and in interpret mode on CPU,
where the fast tier runs them.  Every body mirrors its jnp reference
(``hash_index.lookup`` / ``sorted_index.search`` / ``log
.pending_lookup`` / ``sorted_index.merge``) operation-for-operation:
the dispatch contract is BIT-EXACT parity, enforced by
tests/test_kernel_dispatch.py.

Keys are int32 in-kernel (canonical x32 key codec); ``ops.py`` falls
back to the jnp path for int64 keys.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

I32 = jnp.int32
KEY_INF32 = jnp.iinfo(jnp.int32).max
OP_PUT = 1
OP_DEL = 2


def directory_levels(cap: int, fanout: int) -> int:
    lv, span = 1, fanout
    while span < cap:
        span *= fanout
        lv += 1
    return lv


def _full_spec(a):
    """Whole-array BlockSpec: stage the table into VMEM once per block."""
    nd = a.ndim
    return pl.BlockSpec(a.shape, lambda i, _n=nd: (0,) * _n)


# ---------------------------------------------------------------------------
# shared in-kernel bodies (each mirrors its jnp reference exactly)
# ---------------------------------------------------------------------------
def _hash_probe(sig, fp, haddr, fill, b, qsig, qfp, S):
    """Mirror of hash_index.lookup (incl. the fill-based miss count)."""
    rows_sig = sig[b]                               # [QB, CS]
    rows_fp = fp[b]
    rows_addr = haddr[b]
    match = (rows_sig == qsig[:, None]) & (rows_fp == qfp[:, None])
    found = match.any(axis=1)
    off = jnp.argmax(match, axis=1).astype(I32)
    addr = jnp.where(found, jnp.take_along_axis(
        rows_addr, off[:, None], axis=1)[:, 0], -1)
    occupied = jnp.maximum(fill[b], 1)
    acc = jnp.where(found, off // S + 1, (occupied + S - 1) // S)
    return addr.astype(I32), found, acc.astype(I32)


def _descent(skeys, q, cap, fanout, levels):
    """Mirror of sorted_index.search's directory descent."""
    pos = jnp.zeros(q.shape, I32)
    offs = jax.lax.iota(I32, fanout)
    for l in range(levels - 1, -1, -1):
        stride = fanout ** l
        gi = pos[:, None] + offs[None, :] * stride           # [QB, fanout]
        node = skeys[jnp.clip(gi, 0, cap - 1)]
        node = jnp.where(gi < cap, node, KEY_INF32)
        cnt = (node <= q[:, None]).sum(axis=1).astype(I32)
        pos = pos + jnp.maximum(cnt - 1, 0) * stride
    return pos


def _pending_lookup(lkeys, laddrs, lops, applied, tail, q):
    """Mirror of log.pending_lookup (newest wins over [applied, tail))."""
    lcap = lkeys.shape[0]
    seq = applied + jnp.arange(lcap, dtype=I32)
    idx = seq % lcap
    pv = seq < tail
    pk = jnp.where(pv, lkeys[idx], KEY_INF32)
    m = pk[None, :] == q[:, None]                            # [QB, lcap]
    hit = m.any(axis=1)
    last = (lcap - 1) - jnp.argmax(m[:, ::-1], axis=1)
    op = jnp.where(hit, lops[idx][last], 0)
    addr = laddrs[idx][last]
    return hit, op, addr


def _backup_combine(sk, sa, lk, la, lo_, lw, sel, q, fanout, levels):
    """Per-replica (pending log -> sorted) probe + replica-select combine
    (mirror of the jnp backup probe: later-selected replicas win)."""
    R, cap = sk.shape
    QB = q.shape[0]
    addr_b = jnp.full((QB,), -1, I32)
    found_b = jnp.zeros((QB,), jnp.bool_)
    acc_b = jnp.zeros((QB,), I32)
    for r in range(R):
        pos = _descent(sk[r], q, cap, fanout, levels)
        f_s = sk[r][pos] == q
        a_s = jnp.where(f_s, sa[r][pos], -1)
        hit, op, praw = _pending_lookup(lk[r], la[r], lo_[r],
                                        lw[r, 0], lw[r, 1], q)
        a_r = jnp.where(hit, jnp.where(op == OP_PUT, praw, -1), a_s)
        f_r = jnp.where(hit, op == OP_PUT, f_s)
        s = sel[:, r] != 0
        addr_b = jnp.where(s, a_r, addr_b)
        found_b = jnp.where(s, f_r, found_b)
        acc_b = jnp.where(s, jnp.full((QB,), levels + 1, I32), acc_b)
    return addr_b, found_b, acc_b


def _cx_multi(arrs, j, asc):
    """Bitonic compare-exchange at distance j over the LAST axis, ordering
    by (arrs[0], arrs[1]) lexicographically; the remaining arrays ride
    along as payload.  arrs[1] strictly unique -> a total order, so the
    network is a stable sort by arrs[0]."""
    T = arrs[0].shape[-1]
    lead = arrs[0].shape[:-1]
    split = lambda x: x.reshape(lead + (T // (2 * j), 2, j))
    a2 = asc.reshape(T // (2 * j), 2, j)[:, 0, :]            # [T/2j, j]
    lo = [split(x)[..., 0, :] for x in arrs]
    hi = [split(x)[..., 1, :] for x in arrs]
    gt = (lo[0] > hi[0]) | ((lo[0] == hi[0]) & (lo[1] > hi[1]))
    lt = (lo[0] < hi[0]) | ((lo[0] == hi[0]) & (lo[1] < hi[1]))
    swap = jnp.where(a2, gt, lt)
    out = []
    for l, h in zip(lo, hi):
        nl = jnp.where(swap, h, l)
        nh = jnp.where(swap, l, h)
        out.append(jnp.stack([nl, nh], axis=-2).reshape(lead + (T,)))
    return out


def _bitonic_multi(arrs):
    """Full bitonic network over the last axis (power-of-two length)."""
    T = arrs[0].shape[-1]
    idx = jax.lax.iota(I32, T)
    stage = 2
    while stage <= T:
        asc = (idx // stage) % 2 == 0
        j = stage // 2
        while j >= 1:
            arrs = _cx_multi(arrs, j, asc)
            j //= 2
        stage *= 2
    return arrs


def _count_prefix(a, q, leq: bool):
    """#elements of sorted ``a`` that are < q (or <= q): branchless
    power-of-two binary search, any array length."""
    n = a.shape[0]
    pos = jnp.zeros(q.shape, I32)
    s = 1
    while s * 2 <= n:
        s *= 2
    while s >= 1:
        cand = pos + s
        v = a[jnp.clip(cand - 1, 0, n - 1)]
        good = (v <= q) if leq else (v < q)
        pos = jnp.where((cand <= n) & good, cand, pos)
        s //= 2
    return pos


# ---------------------------------------------------------------------------
# hash probe (block)
# ---------------------------------------------------------------------------
def _hash_body(S, b_ref, qsig_ref, qfp_ref, sig_ref, fp_ref, ha_ref,
               fill_ref, ao, fo, co):
    a, f, c = _hash_probe(sig_ref[...], fp_ref[...], ha_ref[...],
                          fill_ref[...], b_ref[...], qsig_ref[...],
                          qfp_ref[...], S)
    ao[...] = a
    fo[...] = f.astype(I32)
    co[...] = c


@functools.partial(jax.jit, static_argnames=("slots_per_bucket", "q_block",
                                             "interpret"))
def hash_probe_block_kernel(bucket, qsig, qfp, sig, fp, addr, fill, *,
                            slots_per_bucket: int, q_block: int = 512,
                            interpret: bool = True):
    """bucket/qsig/qfp: [Q] int32 descriptors; sig/fp/addr: [nb, CS];
    fill: [nb].  Returns (addr, found int32, n_accesses), each [Q] —
    bit-exact with hash_index.lookup."""
    Q = bucket.shape[0]
    QB = min(q_block, Q)
    assert Q % QB == 0
    qspec = pl.BlockSpec((QB,), lambda i: (i,))
    return pl.pallas_call(
        functools.partial(_hash_body, slots_per_bucket),
        grid=(Q // QB,),
        in_specs=[qspec, qspec, qspec,
                  _full_spec(sig), _full_spec(fp), _full_spec(addr),
                  _full_spec(fill)],
        out_specs=[qspec, qspec, qspec],
        out_shape=[jax.ShapeDtypeStruct((Q,), I32)] * 3,
        interpret=interpret,
    )(bucket, qsig, qfp, sig, fp, addr, fill)


# ---------------------------------------------------------------------------
# sorted search (block) — also emits the descent position (SCAN lower bound)
# ---------------------------------------------------------------------------
def _search_body(cap, fanout, levels, q_ref, k_ref, a_ref,
                 ao, fo, co, po, lo_out):
    q = q_ref[...]
    keys = k_ref[...]
    pos = _descent(keys, q, cap, fanout, levels)
    found = keys[pos] == q
    ao[...] = jnp.where(found, a_ref[...][pos], -1)
    fo[...] = found.astype(I32)
    co[...] = jnp.full(q.shape, levels, I32)
    po[...] = pos
    # lower bound: first index with key >= q (== searchsorted output
    # wherever it matters — see ops.range_query's parity note)
    lo_out[...] = pos + (keys[pos] < q).astype(I32)


@functools.partial(jax.jit, static_argnames=("fanout", "q_block",
                                             "interpret"))
def sorted_search_block_kernel(queries, keys, addrs, *, fanout: int = 128,
                               q_block: int = 512, interpret: bool = True):
    """queries: [Q] int32; keys: [cap] int32 ascending (INF-padded);
    addrs: [cap] int32.  Returns (addr, found int32, n_accesses, pos,
    lower_bound), each [Q] — search outputs bit-exact with
    sorted_index.search."""
    Q = queries.shape[0]
    cap = keys.shape[0]
    levels = directory_levels(cap, fanout)
    QB = min(q_block, Q)
    assert Q % QB == 0
    qspec = pl.BlockSpec((QB,), lambda i: (i,))
    return pl.pallas_call(
        functools.partial(_search_body, cap, fanout, levels),
        grid=(Q // QB,),
        in_specs=[qspec, _full_spec(keys), _full_spec(addrs)],
        out_specs=[qspec] * 5,
        out_shape=[jax.ShapeDtypeStruct((Q,), I32)] * 5,
        interpret=interpret,
    )(queries, keys, addrs)


# ---------------------------------------------------------------------------
# backup probe (per-replica pending log + sorted descent + select)
# ---------------------------------------------------------------------------
def _backup_body(fanout, levels, rk_ref, sel_ref, sk_ref, sa_ref,
                 lk_ref, la_ref, lo_ref, lw_ref, bao, bfo, bco):
    a, f, c = _backup_combine(sk_ref[...], sa_ref[...], lk_ref[...],
                              la_ref[...], lo_ref[...], lw_ref[...],
                              sel_ref[...], rk_ref[...], fanout, levels)
    bao[...] = a
    bfo[...] = f.astype(I32)
    bco[...] = c


@functools.partial(jax.jit, static_argnames=("fanout", "q_block",
                                             "interpret"))
def backup_probe_kernel(rkeys, rep_sel, skeys, saddrs, lkeys, laddrs,
                        lops, lwin, *, fanout: int = 128,
                        q_block: int = 512, interpret: bool = True):
    """rkeys: [Q] int32; rep_sel: [Q, R] int32 lane->replica select;
    skeys/saddrs: [R, cap]; lkeys/laddrs/lops: [R, lcap]; lwin: [R, 2]
    (applied, tail).  Returns (addr, found int32, n_accesses) — the
    degraded-read probe, bit-exact with the jnp backup probe."""
    Q = rkeys.shape[0]
    R, cap = skeys.shape
    levels = directory_levels(cap, fanout)
    QB = min(q_block, Q)
    assert Q % QB == 0
    qspec = pl.BlockSpec((QB,), lambda i: (i,))
    sspec = pl.BlockSpec((QB, R), lambda i: (i, 0))
    return pl.pallas_call(
        functools.partial(_backup_body, fanout, levels),
        grid=(Q // QB,),
        in_specs=[qspec, sspec, _full_spec(skeys), _full_spec(saddrs),
                  _full_spec(lkeys), _full_spec(laddrs), _full_spec(lops),
                  _full_spec(lwin)],
        out_specs=[qspec] * 3,
        out_shape=[jax.ShapeDtypeStruct((Q,), I32)] * 3,
        interpret=interpret,
    )(rkeys, rep_sel, skeys, saddrs, lkeys, laddrs, lops, lwin)


# ---------------------------------------------------------------------------
# fused GET probe: hash walk + backup probe in ONE kernel
# ---------------------------------------------------------------------------
def _group_body(S, fanout, levels, b_ref, qsig_ref, qfp_ref, rk_ref,
                sel_ref, sig_ref, fp_ref, ha_ref, fill_ref, sk_ref, sa_ref,
                lk_ref, la_ref, lo_ref, lw_ref,
                hao, hfo, hco, bao, bfo, bco):
    ha, hf, hc = _hash_probe(sig_ref[...], fp_ref[...], ha_ref[...],
                             fill_ref[...], b_ref[...], qsig_ref[...],
                             qfp_ref[...], S)
    ba, bf, bc = _backup_combine(sk_ref[...], sa_ref[...], lk_ref[...],
                                 la_ref[...], lo_ref[...], lw_ref[...],
                                 sel_ref[...], rk_ref[...], fanout, levels)
    hao[...] = ha
    hfo[...] = hf.astype(I32)
    hco[...] = hc
    bao[...] = ba
    bfo[...] = bf.astype(I32)
    bco[...] = bc


@functools.partial(jax.jit, static_argnames=("slots_per_bucket", "fanout",
                                             "q_block", "interpret"))
def group_probe_kernel(bucket, qsig, qfp, rkeys, rep_sel, sig, fp, haddr,
                       fill, skeys, saddrs, lkeys, laddrs, lops, lwin, *,
                       slots_per_bucket: int, fanout: int = 128,
                       q_block: int = 512, interpret: bool = True):
    """The fused GET probe.  Query side: bucket/qsig/qfp (hash
    descriptors), rkeys (raw int32 keys), rep_sel [Q, R].  Table side:
    the hash arrays + fill, the stacked sorted replicas, the stacked
    pending logs + their (applied, tail) windows.  Returns
    (h_addr, h_found, h_acc, b_addr, b_found, b_acc), each [Q] int32 —
    the primary/backup pair the op bodies combine with their own
    am_primary masks."""
    Q = bucket.shape[0]
    R, cap = skeys.shape
    levels = directory_levels(cap, fanout)
    QB = min(q_block, Q)
    assert Q % QB == 0
    qspec = pl.BlockSpec((QB,), lambda i: (i,))
    sspec = pl.BlockSpec((QB, R), lambda i: (i, 0))
    return pl.pallas_call(
        functools.partial(_group_body, slots_per_bucket, fanout, levels),
        grid=(Q // QB,),
        in_specs=[qspec, qspec, qspec, qspec, sspec,
                  _full_spec(sig), _full_spec(fp), _full_spec(haddr),
                  _full_spec(fill), _full_spec(skeys), _full_spec(saddrs),
                  _full_spec(lkeys), _full_spec(laddrs), _full_spec(lops),
                  _full_spec(lwin)],
        out_specs=[qspec] * 6,
        out_shape=[jax.ShapeDtypeStruct((Q,), I32)] * 6,
        interpret=interpret,
    )(bucket, qsig, qfp, rkeys, rep_sel, sig, fp, haddr, fill,
      skeys, saddrs, lkeys, laddrs, lops, lwin)


# ---------------------------------------------------------------------------
# bitonic-merge incremental apply (log batch -> sorted index)
# ---------------------------------------------------------------------------
def _merge_body(ek_ref, ea_ref, bk_ref, ba_ref, bo_ref,
                nk_ref, na_ref, sz_ref):
    ek = ek_ref[...]
    ea = ea_ref[...]
    bo = bo_ref[...]
    bk = jnp.where(bo > 0, bk_ref[...], KEY_INF32)
    cap = ek.shape[0]
    MP = bk.shape[0]
    # stable sort of the batch by (key, arrival): arrival priority is the
    # tie-break that makes newest-wins exact (mirror of merge's lexsort
    # prio 1..m; padding lanes carry op=0 -> key INF, dropped below)
    prio = 1 + jax.lax.iota(I32, MP)
    sk, _, sa, sd = _bitonic_multi(
        [bk, prio, ba_ref[...], (bo == OP_DEL).astype(I32)])
    # merge-path placement: each element's rank in the merged order via
    # branchless binary search (existing-first on equal keys, matching
    # the jnp lexsort's priority ordering)
    pe = jax.lax.iota(I32, cap) + _count_prefix(sk, ek, leq=False)
    pb = jax.lax.iota(I32, MP) + _count_prefix(ek, sk, leq=True)
    L = cap + MP
    mk = jnp.full((L,), KEY_INF32, I32).at[pe].set(ek).at[pb].set(sk)
    ma = jnp.full((L,), -1, I32).at[pe].set(ea).at[pb].set(sa)
    md = jnp.zeros((L,), I32).at[pb].set(sd)
    # newest-wins + tombstone compaction: identical keep pass to
    # sorted_index.merge on the identically-ordered merged sequence
    is_last = jnp.concatenate([mk[1:] != mk[:-1], jnp.ones((1,), bool)])
    keep = is_last & (md == 0) & (mk != KEY_INF32)
    dest = jnp.cumsum(keep) - 1
    dest = jnp.where(keep, dest, L)
    nk_ref[...] = jnp.full((cap,), KEY_INF32, I32).at[dest].set(
        mk, mode="drop")
    na_ref[...] = jnp.full((cap,), -1, I32).at[dest].set(ma, mode="drop")
    sz_ref[0] = keep.sum().astype(I32)


@functools.partial(jax.jit, static_argnames=("interpret",))
def merge_kernel(ekeys, eaddrs, bkeys, baddrs, bops, *,
                 interpret: bool = True):
    """ekeys/eaddrs: [cap] int32 (ascending, INF-padded); bkeys/baddrs/
    bops: [m] int32 log batch (op 0 invalid / 1 PUT / 2 DEL).  Returns
    (new_keys [cap], new_addrs [cap], size [1]) — bit-exact with
    sorted_index.merge."""
    cap = ekeys.shape[0]
    m = bkeys.shape[0]
    MP = 1
    while MP < max(m, 1):
        MP <<= 1
    if MP != m:
        bkeys = jnp.pad(bkeys, (0, MP - m))
        baddrs = jnp.pad(baddrs, (0, MP - m), constant_values=-1)
        bops = jnp.pad(bops, (0, MP - m))
    return pl.pallas_call(
        _merge_body,
        out_shape=[jax.ShapeDtypeStruct((cap,), I32),
                   jax.ShapeDtypeStruct((cap,), I32),
                   jax.ShapeDtypeStruct((1,), I32)],
        interpret=interpret,
    )(ekeys, eaddrs, bkeys, baddrs, bops)


# ---------------------------------------------------------------------------
# rowwise stable pair sort
# ---------------------------------------------------------------------------
def _sort_stable_body(k_ref, v_ref, ko_ref, vo_ref):
    keys = k_ref[...]
    vals = v_ref[...]
    T = keys.shape[-1]
    pr = jnp.broadcast_to(jax.lax.iota(I32, T), keys.shape)
    ks, _, vs = _bitonic_multi([keys, pr, vals])
    ko_ref[...] = ks
    vo_ref[...] = vs


@functools.partial(jax.jit, static_argnames=("row_block", "interpret"))
def sort_pairs_stable_kernel(keys, vals, *, row_block: int = 8,
                             interpret: bool = True):
    """keys/vals: [R, T] int32, T a power of two.  Rowwise STABLE sort by
    key (index tie-break) — bit-exact with stable argsort + gather."""
    R, T = keys.shape
    assert T & (T - 1) == 0, "T must be a power of two"
    RB = min(row_block, R)
    assert R % RB == 0
    spec = pl.BlockSpec((RB, T), lambda i: (i, 0))
    return pl.pallas_call(
        _sort_stable_body,
        grid=(R // RB,),
        in_specs=[spec, spec],
        out_specs=[spec, spec],
        out_shape=[jax.ShapeDtypeStruct((R, T), I32)] * 2,
        interpret=interpret,
    )(keys, vals)
