"""DEPRECATED module home: import through repro.kernels.ops instead.

The kernel moved to the private module kernels/_hash_probe.py; the
public surface is the cfg-routed dispatch API (repro.kernels.ops.probe)
plus the legacy jitted wrapper repro.kernels.ops.hash_probe.
"""
import warnings

from repro.kernels._hash_probe import hash_probe_kernel  # noqa: F401

warnings.warn(
    "repro.kernels.hash_probe is deprecated: use repro.kernels.ops "
    "(probe(cfg, ...) dispatch, or the hash_probe wrapper)",
    DeprecationWarning, stacklevel=2)
