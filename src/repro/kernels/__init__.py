"""Pallas kernels for the index hot path + the model-side fused scan.

Public surface: the cfg-routed dispatch API in ``repro.kernels.ops``
(re-exported below) — ``probe``/``search``/``merge``/``range_query``/
``sort``/``group_probe``/``backup_probe`` take the HiStoreConfig and
route by ``cfg.use_kernels`` ("off" | "on" | "auto"); both paths are
bit-exact by contract.  The old per-kernel module imports
(kernels.hash_probe / sorted_search / bitonic_sort) are deprecated
shims over the private ``_``-prefixed kernel modules.
"""
from repro.kernels import ops  # noqa: F401
from repro.kernels.ops import (active_path, backup_probe,  # noqa: F401
                               group_probe, kernels_enabled, merge, probe,
                               range_query, search, sort)
