"""THE kernel-dispatch surface for the serving hot path.

Every routed op body (GET probe, degraded backup probe, SCAN bounds, the
log->sorted merge, recovery replay's probes) calls THESE functions —
``probe`` / ``search`` / ``merge`` / ``range_query`` / ``sort`` /
``group_probe`` / ``backup_probe`` — never a kernel module directly.
Each takes the HiStoreConfig and routes by ``cfg.use_kernels``:

  "on"    always serve through the Pallas kernels (kernels/_fused.py;
          interpret mode off-TPU, Mosaic on TPU);
  "off"   always the pure-jnp reference path (core/hash_index.py,
          core/sorted_index.py, core/log.py — unchanged semantics);
  "auto"  (default) kernels on TPU, jnp elsewhere; the
          HISTORE_USE_KERNELS env var ("on"/"off") overrides — how CI
          runs the interpret-mode kernel leg without touching configs.

The two paths are BIT-EXACT by contract (tests/test_kernel_dispatch.py
holds every routed primitive to array equality, and the client-level
seeded traces + parity_report must agree across the knob).  The raw-key
kernels (sorted search/merge/range, pending-log probe) need the
canonical int32 key codec — int64 keys (jax_enable_x64 deployments)
fall back to jnp per call; the hash probe is descriptor-based (int32
bucket/sig/fp) and serves either key dtype.

Resolution happens at TRACE time: the knob (and env override) must be
process-constant, because jitted callers cache on the cfg object.
Benchmarks that compare modes therefore pass explicit per-mode cfgs.

The legacy per-module wrappers (``hash_probe``/``sorted_search``/
``sort_pairs``) remain at the bottom; importing their old module homes
(kernels/hash_probe.py etc.) now warns deprecation and forwards here.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from repro.core import hash_index as hix
from repro.core import log as lg
from repro.core import sorted_index as six
from repro.core.hashing import bucket_of, sig_fp_of
from repro.kernels._bitonic_sort import bitonic_sort_kernel
from repro.kernels._fused import (backup_probe_kernel, group_probe_kernel,
                                  hash_probe_block_kernel, merge_kernel,
                                  sort_pairs_stable_kernel,
                                  sorted_search_block_kernel)
from repro.kernels._hash_probe import hash_probe_kernel
from repro.kernels._sorted_search import sorted_search_kernel

I32 = jnp.int32

_ON = ("on", "1", "true", "yes")
_OFF = ("off", "0", "false", "no")
ENV_KNOB = "HISTORE_USE_KERNELS"


def kernels_enabled(cfg) -> bool:
    """Resolve cfg.use_kernels to a bool (see module docstring)."""
    knob = getattr(cfg, "use_kernels", "auto")
    if knob == "on":
        return True
    if knob == "off":
        return False
    env = os.environ.get(ENV_KNOB, "").strip().lower()
    if env in _ON:
        return True
    if env in _OFF:
        return False
    return jax.default_backend() == "tpu"


def active_path(cfg, key_dtype=None) -> str:
    """"kernel" or "jnp": which path serves raw-key index ops under this
    cfg (and key dtype — int64 keys fall back to jnp)."""
    if not kernels_enabled(cfg):
        return "jnp"
    if key_dtype is not None and jnp.dtype(key_dtype) != jnp.int32:
        return "jnp"
    return "kernel"


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _qblock(Q: int, cap: int = 512) -> int:
    """Power-of-two query block <= cap (pad Q up to a multiple of it)."""
    qb = 1
    while qb < min(Q, cap):
        qb <<= 1
    return qb


def _pad_queries(pad, b, sig, fp, rk=None):
    if pad:
        b = jnp.pad(b, (0, pad))
        sig = jnp.pad(sig, (0, pad), constant_values=-7)  # never matches
        fp = jnp.pad(fp, (0, pad))
        if rk is not None:
            rk = jnp.pad(rk, (0, pad), constant_values=-1)
    return b, sig, fp, rk


# ---------------------------------------------------------------------------
# point probes
# ---------------------------------------------------------------------------
def probe(cfg, index, keys):
    """GET probe on a HashIndex -> (addr, found bool, n_accesses).
    Descriptor-based, so it serves either key dtype; bit-exact with
    hash_index.lookup."""
    if not kernels_enabled(cfg):
        return hix.lookup(index, keys, cfg)
    b, sig, fp = hix.descriptors(index, keys)
    Q = keys.shape[0]
    QB = _qblock(Q)
    b, sig, fp, _ = _pad_queries((-Q) % QB, b, sig, fp)
    addr, found, acc = hash_probe_block_kernel(
        b, sig, fp, index.sig, index.fp, index.addr, index.fill,
        slots_per_bucket=cfg.slots_per_bucket, q_block=QB,
        interpret=_interpret())
    return addr[:Q], found[:Q].astype(bool), acc[:Q]


def search(cfg, index, queries):
    """Point lookup on a SortedIndex -> (addr, found bool, n_accesses).
    Bit-exact with sorted_index.search."""
    if not kernels_enabled(cfg) or index.keys.dtype != jnp.int32:
        return six.search(index, queries, cfg.fanout)
    Q = queries.shape[0]
    QB = _qblock(Q)
    pad = (-Q) % QB
    q = queries.astype(I32)
    if pad:
        q = jnp.pad(q, (0, pad), constant_values=-1)
    addr, found, acc, _, _ = sorted_search_block_kernel(
        q, index.keys, index.addrs, fanout=cfg.fanout, q_block=QB,
        interpret=_interpret())
    return addr[:Q], found[:Q].astype(bool), acc[:Q]


def _log_stack(blogs_r):
    """Kernel-ready views of stacked [R, ...] pending logs."""
    lwin = jnp.stack([blogs_r.applied, blogs_r.tail], axis=1).astype(I32)
    return (blogs_r.keys.astype(I32), blogs_r.addrs,
            blogs_r.ops.astype(I32), lwin)


def _backup_probe_jnp(cfg, sorted_r, blogs_r, keys, rep_sel):
    """jnp reference of the replica-select backup probe (sequential
    overwrite: the LAST selected replica answers a multi-selected lane —
    the G==1 wrap case — exactly like the shifted-layout store body)."""
    R = blogs_r.tail.shape[0]
    addr_b = jnp.full(keys.shape, -1, I32)
    found_b = jnp.zeros(keys.shape, bool)
    acc_b = jnp.zeros(keys.shape, I32)
    for r in range(R):
        srt = jax.tree.map(lambda a: a[r], sorted_r)
        blog = jax.tree.map(lambda a: a[r], blogs_r)
        a_s, f_s, c_s = six.search(srt, keys, cfg.fanout)
        hit, op, praw = lg.pending_lookup(blog, keys)
        a_r = jnp.where(hit, jnp.where(op == six.OP_PUT, praw, -1), a_s)
        f_r = jnp.where(hit, op == six.OP_PUT, f_s)
        sel = rep_sel[:, r] != 0
        addr_b = jnp.where(sel, a_r, addr_b)
        found_b = jnp.where(sel, f_r, found_b)
        acc_b = jnp.where(sel, c_s + 1, acc_b)
    return addr_b, found_b, acc_b


def backup_probe(cfg, sorted_r, blogs_r, keys, rep_sel):
    """Degraded lookup across stacked sorted replicas: per-replica
    pending-log (newest wins) then sorted descent, combined by
    ``rep_sel`` [Q, R] (lane i answered by each selected replica in
    turn, later replicas overwriting).  Returns (addr, found bool,
    n_accesses)."""
    if (not kernels_enabled(cfg) or sorted_r.keys.dtype != jnp.int32
            or keys.dtype != jnp.int32):
        return _backup_probe_jnp(cfg, sorted_r, blogs_r, keys, rep_sel)
    Q = keys.shape[0]
    R = blogs_r.tail.shape[0]
    QB = _qblock(Q)
    pad = (-Q) % QB
    rk = keys
    sel = rep_sel.astype(I32)
    if pad:
        rk = jnp.pad(rk, (0, pad), constant_values=-1)
        sel = jnp.pad(sel, ((0, pad), (0, 0)))
    lkeys, laddrs, lops, lwin = _log_stack(blogs_r)
    addr, found, acc = backup_probe_kernel(
        rk, sel, sorted_r.keys, sorted_r.addrs, lkeys, laddrs, lops,
        lwin, fanout=cfg.fanout, q_block=QB, interpret=_interpret())
    return addr[:Q], found[:Q].astype(bool), acc[:Q]


def group_probe(cfg, hidx, sorted_r, blogs_r, keys, rep_sel):
    """The fused GET probe: hash-bucket chain walk + per-replica
    pending-log/sorted backup probe in ONE kernel launch (the hot-path
    op body combines the pair with its own ``am_primary`` mask).
    Returns (h_addr, h_found, h_acc, b_addr, b_found, b_acc)."""
    if (not kernels_enabled(cfg) or sorted_r.keys.dtype != jnp.int32
            or keys.dtype != jnp.int32):
        a_h, f_h, c_h = hix.lookup(hidx, keys, cfg)
        a_b, f_b, c_b = _backup_probe_jnp(cfg, sorted_r, blogs_r, keys,
                                          rep_sel)
        return a_h, f_h, c_h, a_b, f_b, c_b
    b, sig, fp = hix.descriptors(hidx, keys)
    Q = keys.shape[0]
    QB = _qblock(Q)
    pad = (-Q) % QB
    b, sig, fp, rk = _pad_queries(pad, b, sig, fp, keys)
    sel = rep_sel.astype(I32)
    if pad:
        sel = jnp.pad(sel, ((0, pad), (0, 0)))
    lkeys, laddrs, lops, lwin = _log_stack(blogs_r)
    ha, hf, hc, ba, bf, bc = group_probe_kernel(
        b, sig, fp, rk, sel, hidx.sig, hidx.fp, hidx.addr, hidx.fill,
        sorted_r.keys, sorted_r.addrs, lkeys, laddrs, lops, lwin,
        slots_per_bucket=cfg.slots_per_bucket, fanout=cfg.fanout,
        q_block=QB, interpret=_interpret())
    return (ha[:Q], hf[:Q].astype(bool), hc[:Q],
            ba[:Q], bf[:Q].astype(bool), bc[:Q])


# ---------------------------------------------------------------------------
# merge (incremental apply) and scan bounds
# ---------------------------------------------------------------------------
def merge(cfg, index, keys, addrs, ops):
    """Apply a log batch to a SortedIndex (newest-wins, tombstones
    compact away) -> SortedIndex.  Bit-exact with sorted_index.merge."""
    if (not kernels_enabled(cfg) or index.keys.dtype != jnp.int32
            or keys.dtype != jnp.int32):
        return six.merge(index, keys, addrs, ops)
    nk, na, size = merge_kernel(
        index.keys, index.addrs, keys.astype(I32), addrs.astype(I32),
        ops.astype(I32), interpret=_interpret())
    return six.SortedIndex(nk, na, size[0])


def range_query(cfg, index, lo, hi, limit: int):
    """SCAN [lo, hi] -> (keys [limit], addrs [limit], count).  The lower
    bound comes from the sorted-search kernel's descent position; the
    take/mask tail is shared with the jnp path (range_from_start), so
    the outputs are bit-exact with sorted_index.range_query."""
    if not kernels_enabled(cfg) or index.keys.dtype != jnp.int32:
        return six.range_query(index, lo, hi, limit)
    q = jnp.asarray(lo, I32).reshape((1,))
    *_, lbound = sorted_search_block_kernel(
        q, index.keys, index.addrs, fanout=cfg.fanout, q_block=1,
        interpret=_interpret())
    return six.range_from_start(index, lbound[0], hi, limit)


def sort(cfg, keys, vals):
    """Rowwise STABLE (key, payload) sort, [R, T] with T a power of two.
    Bit-exact with a stable argsort + gather."""
    if kernels_enabled(cfg) and keys.dtype == jnp.int32:
        R = keys.shape[0]
        rb = 8
        while R % rb:
            rb >>= 1
        return sort_pairs_stable_kernel(keys, vals.astype(I32),
                                        row_block=rb,
                                        interpret=_interpret())
    order = jnp.argsort(keys, axis=1, stable=True)
    return (jnp.take_along_axis(keys, order, axis=1),
            jnp.take_along_axis(vals, order, axis=1))


# ---------------------------------------------------------------------------
# legacy jitted wrappers (the original per-query DMA kernels, kept as the
# measured one-RTT-read models; kernels/__init__ re-exports the dispatch
# API above as the public surface)
# ---------------------------------------------------------------------------
def hash_probe(index, keys, cfg, *, q_block: int = 256):
    """GET probe through the per-query DMA Pallas kernel.  index:
    core.hash_index HashIndex; keys: [Q].  Returns (addr, found bool,
    n_accesses)."""
    nb = index.sig.shape[0]
    b = bucket_of(keys, nb)
    sig, fp = sig_fp_of(keys)
    Q = keys.shape[0]
    b, sig, fp, _ = _pad_queries((-Q) % q_block, b, sig, fp)
    addr, found, acc = hash_probe_kernel(
        b, sig, fp, index.sig, index.fp, index.addr,
        slots_per_bucket=cfg.slots_per_bucket, q_block=q_block,
        interpret=_interpret())
    return addr[:Q], found[:Q].astype(bool), acc[:Q]


def sorted_search(index, queries, *, fanout: int = 128, q_block: int = 256):
    """Point lookup on a SortedIndex through the per-query DMA kernel.
    Requires int32 keys (canonical x32 codec)."""
    assert index.keys.dtype == jnp.int32, "kernel path uses int32 keys"
    Q = queries.shape[0]
    pad = (-Q) % q_block
    q = jnp.pad(queries, (0, pad), constant_values=-1) if pad else queries
    addr, found, acc = sorted_search_kernel(
        q.astype(I32), index.keys, index.addrs, fanout=fanout,
        q_block=q_block, interpret=_interpret())
    return addr[:Q], found[:Q].astype(bool), acc[:Q]


def sort_pairs(keys, vals, *, row_block: int = 8):
    """Rowwise (key, payload) sort via the bitonic kernel (NOT stable on
    duplicate keys; ``sort`` above is the stable dispatch)."""
    return bitonic_sort_kernel(keys.astype(I32), vals.astype(I32),
                               row_block=row_block, interpret=_interpret())
