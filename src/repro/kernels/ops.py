"""Jitted public wrappers over the Pallas kernels.

The wrappers own the host-side prep (key hashing, capacity padding) and the
interpret-mode switch: on CPU (this container) kernels run with
interpret=True; on real TPU the same call sites compile the Mosaic kernels.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.hashing import bucket_of, sig_fp_of
from repro.kernels.bitonic_sort import bitonic_sort_kernel
from repro.kernels.hash_probe import hash_probe_kernel
from repro.kernels.sorted_search import sorted_search_kernel

I32 = jnp.int32


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def hash_probe(index, keys, cfg, *, q_block: int = 256):
    """GET probe through the Pallas kernel.  index: core.hash_index
    HashIndex; keys: [Q].  Returns (addr, found bool, n_accesses)."""
    nb = index.sig.shape[0]
    b = bucket_of(keys, nb)
    sig, fp = sig_fp_of(keys)
    Q = keys.shape[0]
    pad = (-Q) % q_block
    if pad:
        b = jnp.pad(b, (0, pad))
        sig = jnp.pad(sig, (0, pad), constant_values=-7)  # never matches
        fp = jnp.pad(fp, (0, pad))
    addr, found, acc = hash_probe_kernel(
        b, sig, fp, index.sig, index.fp, index.addr,
        slots_per_bucket=cfg.slots_per_bucket, q_block=q_block,
        interpret=_interpret())
    return addr[:Q], found[:Q].astype(bool), acc[:Q]


def sorted_search(index, queries, *, fanout: int = 128, q_block: int = 256):
    """Point lookup on a SortedIndex through the Pallas kernel.
    Requires int32 keys (canonical x32 codec)."""
    assert index.keys.dtype == jnp.int32, "kernel path uses int32 keys"
    Q = queries.shape[0]
    pad = (-Q) % q_block
    q = jnp.pad(queries, (0, pad), constant_values=-1) if pad else queries
    addr, found, acc = sorted_search_kernel(
        q.astype(I32), index.keys, index.addrs, fanout=fanout,
        q_block=q_block, interpret=_interpret())
    return addr[:Q], found[:Q].astype(bool), acc[:Q]


def sort_pairs(keys, vals, *, row_block: int = 8):
    """Rowwise (key, payload) sort via the bitonic kernel."""
    return bitonic_sort_kernel(keys.astype(I32), vals.astype(I32),
                               row_block=row_block, interpret=_interpret())
