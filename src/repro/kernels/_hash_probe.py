"""Pallas TPU kernel: batched hash-table probe (the GET hot path).

The RDMA one-sided READ of the paper becomes an HBM->VMEM DMA: the bucket
tables stay in HBM (memory_space=ANY); per query the kernel DMAs the
64 B-class chain row into VMEM (double-buffered across queries, so the next
row's DMA overlaps the current row's compare) and does the signature +
fingerprint compare branchlessly.  This mirrors production paged-lookup
kernels (page-table indirection inside the kernel).

Layout: queries are tiled QB at a time into VMEM via BlockSpec; the chain
row is [CS] int32 (CS = slots_per_bucket * max_chain <= 128 = one lane
vector).  Validated against ref.ref_hash_probe in interpret mode.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

I32 = jnp.int32


def _kernel(slots_per_bucket, b_ref, qsig_ref, qfp_ref,
            sig_hbm, fp_hbm, addr_hbm,
            addr_out, found_out, acc_out,
            sig_s, fp_s, addr_s, sem):
    QB = b_ref.shape[0]
    CS = sig_s.shape[1]
    S = slots_per_bucket

    def start_row(qi, slot):
        b = b_ref[qi]
        pltpu.make_async_copy(sig_hbm.at[b], sig_s.at[slot], sem.at[slot, 0]).start()
        pltpu.make_async_copy(fp_hbm.at[b], fp_s.at[slot], sem.at[slot, 1]).start()
        pltpu.make_async_copy(addr_hbm.at[b], addr_s.at[slot], sem.at[slot, 2]).start()

    def wait_row(qi, slot):
        b = b_ref[qi]
        pltpu.make_async_copy(sig_hbm.at[b], sig_s.at[slot], sem.at[slot, 0]).wait()
        pltpu.make_async_copy(fp_hbm.at[b], fp_s.at[slot], sem.at[slot, 1]).wait()
        pltpu.make_async_copy(addr_hbm.at[b], addr_s.at[slot], sem.at[slot, 2]).wait()

    start_row(0, 0)

    def body(qi, _):
        slot = qi % 2
        nxt = (qi + 1) % 2

        @pl.when(qi + 1 < QB)
        def _():
            start_row(qi + 1, nxt)   # overlap next DMA with this compare

        wait_row(qi, slot)
        row_sig = sig_s[slot]                       # [CS]
        row_fp = fp_s[slot]
        row_addr = addr_s[slot]
        match = (row_sig == qsig_ref[qi]) & (row_fp == qfp_ref[qi])
        iota = jax.lax.iota(I32, CS)
        off = jnp.min(jnp.where(match, iota, CS))
        found = off < CS
        occ = jnp.sum((row_sig != 0).astype(I32))    # fill incl. tombstones
        acc_hit = off // S + 1
        acc_miss = jnp.maximum((occ + S - 1) // S, 1)
        addr_out[qi] = jnp.where(found, row_addr[jnp.minimum(off, CS - 1)], -1)
        found_out[qi] = found.astype(I32)
        acc_out[qi] = jnp.where(found, acc_hit, acc_miss)
        return ()

    jax.lax.fori_loop(0, QB, body, ())


@functools.partial(jax.jit, static_argnames=("slots_per_bucket", "q_block",
                                             "interpret"))
def hash_probe_kernel(bucket, qsig, qfp, sig, fp, addr, *,
                      slots_per_bucket: int, q_block: int = 256,
                      interpret: bool = True):
    """bucket/qsig/qfp: [Q] int32 query descriptors (precomputed hashes);
    sig/fp/addr: [nb, CS] int32 tables.
    Returns (addr [Q], found [Q] int32, n_accesses [Q])."""
    Q = bucket.shape[0]
    QB = min(q_block, Q)
    assert Q % QB == 0
    CS = sig.shape[1]
    grid = (Q // QB,)
    qspec = pl.BlockSpec((QB,), lambda i: (i,))
    tspec = pl.BlockSpec(memory_space=pl.ANY)
    out = pl.pallas_call(
        functools.partial(_kernel, slots_per_bucket),
        grid=grid,
        in_specs=[qspec, qspec, qspec, tspec, tspec, tspec],
        out_specs=[qspec, qspec, qspec],
        out_shape=[jax.ShapeDtypeStruct((Q,), I32)] * 3,
        scratch_shapes=[
            pltpu.VMEM((2, CS), I32),
            pltpu.VMEM((2, CS), I32),
            pltpu.VMEM((2, CS), I32),
            pltpu.SemaphoreType.DMA((2, 3)),
        ],
        interpret=interpret,
    )(bucket, qsig, qfp, sig, fp, addr)
    return out
