"""Pallas TPU kernel: batched in-VMEM bitonic key/payload sort.

Used by the write path: the sort-based batched hash insert (conflict-free
CAS replacement), the MoE token-by-expert dispatch, and the log->sorted
merge all sort (key, payload) batches.  A bitonic network is the TPU-native
choice: every stage is a strided compare-exchange expressible as reshapes +
where (no gathers), log^2(T) stages, fully vectorised on the VPU.

The tile ([rows, T] with T a power of two) lives entirely in VMEM via
BlockSpec; the grid walks row blocks.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

I32 = jnp.int32


def _compare_exchange(keys, vals, j, ascending_mask):
    """One compare-exchange with partner distance j (power of two).
    keys/vals: [R, T].  ascending_mask: [T] bool, direction per element."""
    R, T = keys.shape
    k = keys.reshape(R, T // (2 * j), 2, j)
    v = vals.reshape(R, T // (2 * j), 2, j)
    asc = ascending_mask.reshape(T // (2 * j), 2, j)[:, 0, :]   # [T/2j, j]
    lo_k, hi_k = k[:, :, 0], k[:, :, 1]
    lo_v, hi_v = v[:, :, 0], v[:, :, 1]
    swap = jnp.where(asc[None], lo_k > hi_k, lo_k < hi_k)
    nlo_k = jnp.where(swap, hi_k, lo_k)
    nhi_k = jnp.where(swap, lo_k, hi_k)
    nlo_v = jnp.where(swap, hi_v, lo_v)
    nhi_v = jnp.where(swap, lo_v, hi_v)
    k = jnp.stack([nlo_k, nhi_k], axis=2)
    v = jnp.stack([nlo_v, nhi_v], axis=2)
    return k.reshape(R, T), v.reshape(R, T)


def _kernel(k_ref, v_ref, ko_ref, vo_ref):
    keys = k_ref[...]
    vals = v_ref[...]
    R, T = keys.shape
    idx = jax.lax.broadcasted_iota(I32, (T,), 0)
    stage = 2
    while stage <= T:
        asc = (idx // stage) % 2 == 0        # direction per bitonic block
        j = stage // 2
        while j >= 1:
            keys, vals = _compare_exchange(keys, vals, j, asc)
            j //= 2
        stage *= 2
    ko_ref[...] = keys
    vo_ref[...] = vals


@functools.partial(jax.jit, static_argnames=("row_block", "interpret"))
def bitonic_sort_kernel(keys, vals, *, row_block: int = 8,
                        interpret: bool = True):
    """keys, vals: [R, T] int32, T a power of two.  Sorts each row of keys
    ascending, applying the same permutation to vals."""
    R, T = keys.shape
    assert T & (T - 1) == 0, "T must be a power of two"
    RB = min(row_block, R)
    assert R % RB == 0
    spec = pl.BlockSpec((RB, T), lambda i: (i, 0))
    return pl.pallas_call(
        _kernel,
        grid=(R // RB,),
        in_specs=[spec, spec],
        out_specs=[spec, spec],
        out_shape=[jax.ShapeDtypeStruct((R, T), I32)] * 2,
        interpret=interpret,
    )(keys, vals)
