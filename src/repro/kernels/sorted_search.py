"""DEPRECATED module home: import through repro.kernels.ops instead.

The kernel moved to the private module kernels/_sorted_search.py; the
public surface is the cfg-routed dispatch API (repro.kernels.ops.search
/ range_query) plus the legacy wrapper repro.kernels.ops.sorted_search.
"""
import warnings

from repro.kernels._sorted_search import sorted_search_kernel  # noqa: F401

warnings.warn(
    "repro.kernels.sorted_search is deprecated: use repro.kernels.ops "
    "(search(cfg, ...) dispatch, or the sorted_search wrapper)",
    DeprecationWarning, stacklevel=2)
