"""DEPRECATED module home: import through repro.kernels.ops instead.

The kernel moved to the private module kernels/_bitonic_sort.py; the
public surface is the cfg-routed dispatch API (repro.kernels.ops.sort)
plus the legacy wrapper repro.kernels.ops.sort_pairs.
"""
import warnings

from repro.kernels._bitonic_sort import bitonic_sort_kernel  # noqa: F401

warnings.warn(
    "repro.kernels.bitonic_sort is deprecated: use repro.kernels.ops "
    "(sort(cfg, ...) dispatch, or the sort_pairs wrapper)",
    DeprecationWarning, stacklevel=2)
