"""Pallas TPU kernel: fused Mamba-1 selective scan (§Perf hillclimb A).

The jnp chunked formulation materialises ~14 [B,S,d_inner,N]-sized
intermediates in HBM (the 880 s memory term of falcon-mamba prefill_32k).
This kernel is the TPU restatement of the Mamba paper's hardware-aware
scan: the recurrent state [DBLK, N] lives in a VMEM scratch that persists
across the sequence-chunk grid dimension, so HBM traffic is exactly the
kernel inputs (x, dt, B, C) + output (y) — the [S, d, N] expansion never
leaves the chip.

Grid: (batch, d_inner blocks, seq chunks) — seq chunks iterate minor-most
(sequential on TPU), carrying the state scratch; the state is reset at
chunk 0.  flops = ~9·S·d_inner·N per batch element.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

F32 = jnp.float32


def _kernel(x_ref, dt_ref, b_ref, c_ref, a_ref, y_ref, h_scratch):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _():
        h_scratch[...] = jnp.zeros_like(h_scratch)

    A = a_ref[...]                       # [DBLK, N]
    SC = x_ref.shape[1]

    def step(t, h):
        dt_t = dt_ref[0, t].astype(F32)              # [DBLK]
        x_t = x_ref[0, t].astype(F32)
        a = jnp.exp(dt_t[:, None] * A)               # [DBLK, N]
        b = (dt_t * x_t)[:, None] * b_ref[0, t].astype(F32)[None, :]
        h = a * h + b
        y_ref[0, t, :] = (h * c_ref[0, t].astype(F32)[None, :]).sum(
            axis=1).astype(y_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, SC, step, h_scratch[...])
    h_scratch[...] = h


@functools.partial(jax.jit, static_argnames=("d_block", "seq_chunk",
                                             "interpret"))
def mamba_scan_kernel(x, dt, B_ssm, C_ssm, A, *, d_block: int = 128,
                      seq_chunk: int = 128, interpret: bool = True):
    """x, dt: [B, S, di]; B_ssm, C_ssm: [B, S, N]; A: [di, N] (negative).
    Returns y: [B, S, di] with y[b,t,d] = sum_n C[b,t,n] * h[b,t,d,n]."""
    Bsz, S, di = x.shape
    N = B_ssm.shape[-1]
    DBLK = min(d_block, di)
    SC = min(seq_chunk, S)
    assert di % DBLK == 0 and S % SC == 0
    grid = (Bsz, di // DBLK, S // SC)
    x_spec = pl.BlockSpec((1, SC, DBLK), lambda b, d, c: (b, c, d))
    bc_spec = pl.BlockSpec((1, SC, N), lambda b, d, c: (b, c, 0))
    a_spec = pl.BlockSpec((DBLK, N), lambda b, d, c: (d, 0))
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[x_spec, x_spec, bc_spec, bc_spec, a_spec],
        out_specs=x_spec,
        out_shape=jax.ShapeDtypeStruct((Bsz, S, di), x.dtype),
        scratch_shapes=[pltpu.VMEM((DBLK, N), F32)],
        interpret=interpret,
    )(x, dt, B_ssm, C_ssm, A)
