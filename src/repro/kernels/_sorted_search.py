"""Pallas TPU kernel: hierarchical sorted-directory descent (the SCAN-side
point lookup — the paper's skiplist walk).

Per level the kernel DMAs one fanout-wide node (fanout=128 int32 = 512 B,
exactly a TPU lane vector / an RDMA-read-sized node) from the packed sorted
array in HBM, counts keys <= q branchlessly, and descends.  The number of
DMAs per query equals the directory level count — the same quantity the
paper measures as per-lookup memory accesses (Fig. 3a).

Keys are int32 in-kernel (canonical x32 key codec; the int64 path is the
pure-jnp sorted_index, see DESIGN.md §Key codec).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

I32 = jnp.int32
KEY_INF32 = jnp.iinfo(jnp.int32).max


def _levels(cap: int, fanout: int) -> int:
    lv, span = 1, fanout
    while span < cap:
        span *= fanout
        lv += 1
    return lv


def _kernel(cap, fanout, levels, q_ref, keys_hbm, addrs_hbm,
            addr_out, found_out, acc_out, node_s, anode_s, sem, asem):
    QB = q_ref.shape[0]

    def body(qi, _):
        q = q_ref[qi]

        def level_step(li, pos):
            stride = fanout ** (levels - 1 - li)

            def g(i, _):
                # one express-lane hop element; the leaf level (stride 1)
                # coalesces to a contiguous 512 B burst on real hw.
                j = jnp.minimum(pos + i * stride, cap - 1)
                pltpu.make_async_copy(
                    keys_hbm.at[pl.ds(j, 1)], node_s.at[0, pl.ds(i, 1)],
                    sem).start()
                pltpu.make_async_copy(
                    keys_hbm.at[pl.ds(j, 1)], node_s.at[0, pl.ds(i, 1)],
                    sem).wait()
                return ()

            jax.lax.fori_loop(0, fanout, g, ())
            idx = pos + jax.lax.iota(I32, fanout) * stride
            node = jnp.where(idx < cap, node_s[0], KEY_INF32)
            cnt = jnp.sum((node <= q).astype(I32))
            return pos + jnp.maximum(cnt - 1, 0) * stride

        pos = jax.lax.fori_loop(0, levels, level_step, jnp.int32(0))
        # fetch key+addr at final pos
        pltpu.make_async_copy(keys_hbm.at[pl.ds(pos, 1)],
                              node_s.at[0, pl.ds(0, 1)], sem).start()
        pltpu.make_async_copy(keys_hbm.at[pl.ds(pos, 1)],
                              node_s.at[0, pl.ds(0, 1)], sem).wait()
        pltpu.make_async_copy(addrs_hbm.at[pl.ds(pos, 1)],
                              anode_s.at[0, pl.ds(0, 1)], asem).start()
        pltpu.make_async_copy(addrs_hbm.at[pl.ds(pos, 1)],
                              anode_s.at[0, pl.ds(0, 1)], asem).wait()
        found = node_s[0, 0] == q
        addr_out[qi] = jnp.where(found, anode_s[0, 0], -1)
        found_out[qi] = found.astype(I32)
        acc_out[qi] = levels
        return ()

    jax.lax.fori_loop(0, QB, body, ())


@functools.partial(jax.jit, static_argnames=("fanout", "q_block", "interpret"))
def sorted_search_kernel(queries, keys, addrs, *, fanout: int = 128,
                         q_block: int = 256, interpret: bool = True):
    """queries: [Q] int32; keys: [cap] int32 ascending (INF-padded);
    addrs: [cap] int32.  Returns (addr, found int32, n_accesses)."""
    Q = queries.shape[0]
    cap = keys.shape[0]
    levels = _levels(cap, fanout)
    QB = min(q_block, Q)
    assert Q % QB == 0
    qspec = pl.BlockSpec((QB,), lambda i: (i,))
    tspec = pl.BlockSpec(memory_space=pl.ANY)
    return pl.pallas_call(
        functools.partial(_kernel, cap, fanout, levels),
        grid=(Q // QB,),
        in_specs=[qspec, tspec, tspec],
        out_specs=[qspec, qspec, qspec],
        out_shape=[jax.ShapeDtypeStruct((Q,), I32)] * 3,
        scratch_shapes=[
            pltpu.VMEM((1, fanout), I32),
            pltpu.VMEM((1, fanout), I32),
            pltpu.SemaphoreType.DMA,
            pltpu.SemaphoreType.DMA,
        ],
        interpret=interpret,
    )(queries, keys, addrs)
