from repro.serving.serve_step import make_serve_step, serve_step, prefill  # noqa: F401
