"""Batched serving engine with a HiStore-backed paged KV-cache directory.

This is where the paper's hybrid index becomes a first-class serving
feature.  The KV cache is organised in pages; an *index group* (hash table
+ sorted index + log) is the page directory:

  * page registration (a page fills)  -> PUT (seq_id, page_no) -> page addr
    — synchronous hash update, logged, asynchronously merged into the
    sorted index (exactly the paper's write path).
  * decode-time page lookup           -> GET via the hash table — the
    one-sided single-point read (optionally through the Pallas
    hash_probe kernel).
  * release / eviction of a sequence  -> SCAN over the key range
    [seq_id<<20, (seq_id+1)<<20) on the sorted index — the range query the
    hash table cannot serve, and the reason serving wants the HYBRID index:
    point lookups stay O(1) while range reclamation stays O(log n + k).
  * prefix reuse (RadixAttention-lite)-> GET on hash(prefix_tokens): a hit
    maps a new request onto existing pages.

Keys pack (seq_id, page_no) into the canonical int key; the model itself
runs decode over per-slot ring caches (the compiled serve_step of the
dry-run), while the directory tracks page ownership for reuse/eviction —
the separation mirrors the paper's index server / data server split.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.histore import HiStoreConfig, scaled
from repro.core.client import HiStoreClient, LocalBackend
from repro.core.hashing import key_dtype
from repro.models.transformer import decode_step, init_cache

# key space adapts to the canonical key dtype (int32 in x32 mode):
PAGE_BITS = 20 if jax.config.jax_enable_x64 else 12
_PREFIX_MOD = (1 << 40) if jax.config.jax_enable_x64 else (1 << 30)


def page_key(seq_id: int, page_no: int):
    return (int(seq_id) << PAGE_BITS) | int(page_no)


def prefix_key(prompt) -> int:
    return abs(hash(tuple(prompt))) % _PREFIX_MOD | (1 << (PAGE_BITS - 1))


@dataclasses.dataclass
class Request:
    rid: int
    prompt: list
    max_new: int
    tokens: list = dataclasses.field(default_factory=list)
    slot: int = -1
    pos: int = 0
    done: bool = False
    prefix_hit: bool = False


class ServingEngine:
    """Greedy continuous-batching engine over decode_step."""

    def __init__(self, cfg, params, *, batch_slots: int = 4,
                 max_len: int = 256, page_size: int = 16,
                 store_cfg: Optional[HiStoreConfig] = None):
        self.cfg = cfg
        self.params = params
        self.B = batch_slots
        self.max_len = max_len
        self.page_size = page_size
        self.kd = key_dtype()
        self.store_cfg = store_cfg or scaled(log_capacity=1 << 12,
                                             async_apply_batch=256)
        # page directory: the unified client over the serving-node's index
        # group; values carry the page address, GETs/PUTs/SCANs are padded
        # to small fixed batches, async applies run every 64 mutations
        self.n_pages = batch_slots * (max_len // page_size) * 2
        self.client = HiStoreClient(
            LocalBackend(max(self.n_pages * 4, 1024), self.store_cfg),
            batch_quantum=8, apply_every_n_ops=64)
        self.free_pages = list(range(self.n_pages, 0, -1))
        self.cache = init_cache(cfg, batch_slots, max_len)
        self.slots: list[Optional[Request]] = [None] * batch_slots
        self.queue: list[Request] = []
        self._rid = 0
        self._step = jax.jit(
            lambda p, c, i: decode_step(cfg, p, c, i))
        self.stats = {"index_puts": 0, "index_gets": 0, "index_scans": 0,
                      "prefix_hits": 0, "pages_registered": 0,
                      "pages_freed": 0, "decode_steps": 0}

    @property
    def directory(self):
        """The page-directory index group (introspection / tests)."""
        return self.client.backend.group

    # -- request lifecycle -------------------------------------------------
    def submit(self, prompt: list[int], max_new: int = 16) -> int:
        r = Request(self._rid, list(prompt), max_new)
        self._rid += 1
        # prefix reuse probe: GET on the prompt hash
        res = self.client.get([prefix_key(prompt)])
        self.stats["index_gets"] += 1
        if bool(res.found[0]):
            r.prefix_hit = True
            self.stats["prefix_hits"] += 1
        self.queue.append(r)
        return r.rid

    def _admit(self):
        for i in range(self.B):
            if self.slots[i] is None and self.queue:
                r = self.queue.pop(0)
                r.slot = i
                r.pos = 0
                r.tokens = []
                self.slots[i] = r
                # register the prompt-prefix key for future reuse
                self.client.put([prefix_key(r.prompt)], [r.slot])
                self.stats["index_puts"] += 1

    def _register_page(self, r: Request):
        page_no = (r.pos - 1) // self.page_size
        if not self.free_pages:
            return
        addr = self.free_pages.pop()
        self.client.put([page_key(r.rid, page_no)], [addr])
        self.stats["index_puts"] += 1
        self.stats["pages_registered"] += 1

    def release(self, r: Request):
        """Reclaim all of a sequence's pages via a sorted-index range scan
        (the SCAN the hash table cannot do).  The scan limit is derived
        from the page budget of one sequence and the scan repeats until the
        range drains, so long sequences cannot leak pages."""
        max_pages = max(self.max_len // self.page_size, 1)
        lo = page_key(r.rid, 0)
        hi = page_key(r.rid, max_pages - 1)
        while True:
            res = self.client.scan(lo, hi, max_pages)
            self.stats["index_scans"] += 1
            n = int(res.count)
            if n == 0:
                break
            keys = res.keys[:n]
            # the page address travels in the value payload
            vals = self.client.get(keys)
            freed = [int(a) for a in np.asarray(vals.values[:n, 0])]
            self.free_pages.extend(a for a in freed if a > 0)
            self.stats["pages_freed"] += n
            self.client.delete(keys)
            if n < max_pages:
                break

    # -- decode loop ---------------------------------------------------------
    def _batch_inputs(self):
        toks = np.zeros((self.B, 1), np.int32)
        pos = np.zeros((self.B,), np.int32)
        for i, r in enumerate(self.slots):
            if r is None:
                continue
            if r.pos < len(r.prompt):
                toks[i, 0] = r.prompt[r.pos]
            elif r.tokens:
                toks[i, 0] = r.tokens[-1]
            pos[i] = r.pos
        return {"tokens": jnp.asarray(toks), "pos": jnp.asarray(pos)}

    def step(self):
        self._admit()
        if all(r is None for r in self.slots):
            return False
        logits, self.cache = self._step(self.params, self.cache,
                                        self._batch_inputs())
        self.stats["decode_steps"] += 1
        nxt = np.asarray(jnp.argmax(logits, axis=-1))
        for i, r in enumerate(self.slots):
            if r is None:
                continue
            r.pos += 1
            if r.pos % self.page_size == 0:
                self._register_page(r)
            if r.pos > len(r.prompt):
                r.tokens.append(int(nxt[i]))
            if (len(r.tokens) >= r.max_new
                    or r.pos >= self.max_len - 1):
                r.done = True
                self.release(r)
                self.slots[i] = None
        return True

    def run(self, max_steps: int = 10_000):
        steps = 0
        while (self.queue or any(self.slots)) and steps < max_steps:
            self.step()
            steps += 1
        return steps
