"""Serving steps: prefill (full prompt forward, returns last-position
logits) and serve_step (one new token against the KV cache).

The higher-level batched-request engine (continuous batching, paged KV
cache backed by the HiStore hybrid index) lives in serving/engine.py; these
are the pure compiled steps that the dry-run lowers.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models.transformer import apply_model, decode_step, init_cache
from repro.models.layers import logits_from_hidden


def prefill(cfg, params, inputs, *, unroll: bool = False):
    """Full-prompt forward; returns logits at the final position [B, V]."""
    hidden, _ = apply_model(cfg, params, inputs, unroll=unroll)
    last = hidden[:, -1:]
    return logits_from_hidden(cfg, params, last)[:, 0]


def serve_step(cfg, params, cache, inputs):
    """One decode step: inputs {tokens [B,1] | embeds [B,1,D], pos [B]}.
    Returns (logits [B, V], new_cache)."""
    return decode_step(cfg, params, cache, inputs)


def make_serve_step(cfg):
    return functools.partial(serve_step, cfg)


def make_cache(cfg, batch: int, seq_len: int):
    return init_cache(cfg, batch, seq_len)
