"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so that
importing this module never touches jax device state.  The single-pod mesh
is 16x16 = 256 chips ("data", "model"); the multi-pod mesh adds a leading
"pod" axis of 2 (512 chips).  Dry-runs force 512 host platform devices via
XLA_FLAGS before any jax import (see dryrun.py).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(model: int = 1):
    """Mesh over whatever devices exist (tests / examples on CPU)."""
    n = len(jax.devices())
    assert n % model == 0
    return jax.make_mesh((n // model, model), ("data", "model"))
