import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import (jax locks the device
# count on first init).  Everything below is ordinary.
"""Multi-pod dry-run: lower + compile every (architecture x input-shape x
mesh) cell with production shardings, prove memory fit, and extract the
roofline terms.

Per cell:
  * single-pod (16x16): full SCANNED lowering -> compile proof +
    memory_analysis; then COMPOSITIONAL cost (per-layer unrolled lowerings
    x layer counts + n_layers=0 base, see roofline/compositional.py) ->
    exact flops / bytes / collective bytes for the roofline terms.
  * multi-pod (2x16x16) SCANNED lowering -> proves the "pod" axis shards
    (compile success is the deliverable; metrics also recorded).

Results land in results/dryrun/<arch>__<shape>__<mesh>.json, consumed by
benchmarks/roofline.py and EXPERIMENTS.md.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch mistral-large-123b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all            # full sweep
  PYTHONPATH=src python -m repro.launch.dryrun --all --multi-pod
"""
import argparse
import dataclasses
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import (SHAPES, all_archs, get_config, input_specs,
                           shape_applicable)
from repro.launch.mesh import make_production_mesh
from repro.models.transformer import init_cache, init_params
from repro.optim.adamw import adamw_init
from repro.roofline.analysis import (active_params, collective_bytes_from_hlo,
                                     model_flops, roofline_terms)
from repro.serving.serve_step import prefill, serve_step
from repro.sharding.context import use_mesh
from repro.sharding.partition import (cache_pspecs, input_pspecs, opt_pspecs,
                                      param_pspecs, to_named)
from repro.train.step import train_step

RESULTS = Path(__file__).resolve().parents[3] / "results" / "dryrun"


def _tune_for_shape(cfg, shape):
    """Bound unrolled-HLO size: wide attention blocks for 32k prefill."""
    if shape.kind == "prefill":
        cfg = cfg.scaled(attn_q_block=2048, attn_kv_block=2048)
    if shape.kind == "train":
        cfg = cfg.scaled(attn_q_block=1024, attn_kv_block=1024)
    return cfg


def lower_cell(arch: str, shape_name: str, *, multi_pod: bool,
               comp: bool = True, opts: str = ""):
    unroll = False   # full program is always lowered scanned (fast compile)
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "status": "skipped",
                "reason": why}
    cfg = _tune_for_shape(cfg, shape).with_opts(opts)
    mesh = make_production_mesh(multi_pod=multi_pod)
    key = jax.random.PRNGKey(0)
    return _lower_cell_inner(cfg, arch, shape_name, shape, mesh, multi_pod,
                             comp, key, opts)


def _lower_cell_inner(cfg, arch, shape_name, shape, mesh, multi_pod, comp,
                      key, opts):
    from repro.sharding.context import use_mesh as _use
    with _use(mesh):
        return _lower_cell_body(cfg, arch, shape_name, shape, mesh,
                                multi_pod, comp, key, opts)


def _lower_cell_body(cfg, arch, shape_name, shape, mesh, multi_pod, comp,
                     key, opts):
    unroll = False   # full program always scanned; compositional unrolls
    params_s = jax.eval_shape(lambda k: init_params(cfg, k), key)
    p_spec = param_pspecs(cfg, params_s, mesh)
    p_shard = to_named(mesh, p_spec)
    inputs = input_specs(cfg, shape)
    in_shard = to_named(mesh, input_pspecs(cfg, shape, inputs, mesh))

    t0 = time.time()
    if shape.kind == "train":
        opt_s = jax.eval_shape(adamw_init, params_s)
        o_shard = to_named(mesh, opt_pspecs(cfg, opt_s, mesh))

        def step(p, o, b):
            return train_step(cfg, p, o, b, unroll=unroll)

        jitted = jax.jit(step,
                         in_shardings=(p_shard, o_shard, in_shard),
                         out_shardings=(p_shard, o_shard, None),
                         donate_argnums=(0, 1))
        lowered = jitted.lower(params_s, opt_s, inputs)
    elif shape.kind == "prefill":
        def step(p, b):
            return prefill(cfg, p, b, unroll=unroll)

        jitted = jax.jit(step, in_shardings=(p_shard, in_shard))
        lowered = jitted.lower(params_s, inputs)
    else:  # decode
        cache_s = jax.eval_shape(
            lambda: init_cache(cfg, shape.global_batch, shape.seq_len))
        c_shard = to_named(mesh, cache_pspecs(cfg, shape, cache_s, mesh))

        def step(p, c, b):
            return serve_step(cfg, p, c, b)

        jitted = jax.jit(step, in_shardings=(p_shard, c_shard, in_shard),
                         out_shardings=(None, c_shard),
                         donate_argnums=(1,))
        lowered = jitted.lower(params_s, cache_s, inputs)
    t_lower = time.time() - t0

    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    ca = compiled.cost_analysis()
    ma = compiled.memory_analysis()
    hlo = compiled.as_text()
    coll = collective_bytes_from_hlo(hlo)
    n_total, n_active = active_params(cfg, params_s)
    n_chips = 512 if multi_pod else 256
    mflops = model_flops(cfg, n_total, n_active, shape)

    # compositional exact cost (single-pod roofline only)
    comp_cost = None
    if comp and not multi_pod:
        from repro.roofline.compositional import compositional_cost
        t0 = time.time()
        comp_cost = compositional_cost(cfg, shape, mesh)
        comp_cost["t_comp_s"] = round(time.time() - t0, 1)
    if comp_cost is not None:
        flops = comp_cost["flops"]
        byts = comp_cost["bytes"]
        coll_total = comp_cost["coll_bytes"]
    else:
        flops = float(ca.get("flops", 0.0))
        byts = float(ca.get("bytes accessed", 0.0))
        coll_total = coll["total_bytes"]
    terms = roofline_terms(flops, byts, coll_total)
    rec = {
        "arch": arch, "shape": shape_name, "opts": opts,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "cost_source": "compositional" if comp_cost else "scanned",
        "status": "ok",
        "t_lower_s": round(t_lower, 1), "t_compile_s": round(t_compile, 1),
        "flops_per_dev": flops, "bytes_per_dev": byts,
        "scanned_cost": {"flops": float(ca.get("flops", 0.0)),
                         "bytes": float(ca.get("bytes accessed", 0.0))},
        "collectives": coll if comp_cost is None else {
            "total_bytes": coll_total,
            "bytes_by_type": comp_cost["coll_by_type"],
            "scanned_program": coll},
        "compositional": comp_cost,
        "memory": {
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "alias_bytes": ma.alias_size_in_bytes,
            "peak_estimate_bytes": (ma.argument_size_in_bytes
                                    + ma.output_size_in_bytes
                                    + ma.temp_size_in_bytes
                                    - ma.alias_size_in_bytes),
        },
        "params_total": int(n_total), "params_active": int(n_active),
        "model_flops_global": mflops,
        "useful_flops_ratio": (mflops / (flops * n_chips)) if flops else 0.0,
        "roofline": terms,
        "hlo_bytes": len(hlo),
    }
    return rec


def run_cell(arch, shape_name, multi_pod, comp, outdir: Path, opts="",
             tag_suffix=""):
    tag = f"{arch}__{shape_name}__{'2x16x16' if multi_pod else '16x16'}"
    if tag_suffix:
        tag += f"__{tag_suffix}"
    out = outdir / f"{tag}.json"
    try:
        rec = lower_cell(arch, shape_name, multi_pod=multi_pod, comp=comp,
                         opts=opts)
    except Exception as e:  # noqa: BLE001 - sweep must survive cell failures
        rec = {"arch": arch, "shape": shape_name,
               "mesh": "2x16x16" if multi_pod else "16x16",
               "status": "error", "error": f"{type(e).__name__}: {e}",
               "traceback": traceback.format_exc()[-2000:]}
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps(rec, indent=1))
    status = rec.get("status")
    extra = ""
    if status == "ok":
        r = rec["roofline"]
        extra = (f" compute={r['compute_s']:.3f}s mem={r['memory_s']:.3f}s"
                 f" coll={r['collective_s']:.3f}s dom={r['dominant']}"
                 f" compile={rec['t_compile_s']}s")
    elif status == "error":
        extra = " " + rec["error"][:160]
    print(f"[dryrun] {tag}: {status}{extra}", flush=True)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--scanned", action="store_true",
                    help="skip the compositional cost pass (fast; memory/"
                         "proof only)")
    ap.add_argument("--out", default=str(RESULTS))
    ap.add_argument("--set", default="", dest="opts",
                    help="cfg overrides k=v,k=v (hillclimb variants)")
    ap.add_argument("--tag", default="", help="suffix for the output file")
    args = ap.parse_args()
    outdir = Path(args.out)

    cells = []
    if args.all:
        for a in all_archs():
            for s in SHAPES:
                cells.append((a, s))
    else:
        cells.append((args.arch, args.shape))
    n_ok = n_fail = 0
    for a, s in cells:
        comp = (not args.multi_pod) and (not args.scanned)
        rec = run_cell(a, s, args.multi_pod, comp, outdir, opts=args.opts,
                       tag_suffix=args.tag)
        if rec.get("status") in ("ok", "skipped"):
            n_ok += 1
        else:
            n_fail += 1
    print(f"[dryrun] done: {n_ok} ok/skip, {n_fail} failed", flush=True)
    return 0 if n_fail == 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())
