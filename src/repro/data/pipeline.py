"""Deterministic, stateless, shardable data pipeline.

Batches are a pure function of (seed, step) — no iterator state, so restart
/ elastic re-sharding is trivially exactly-once: after restoring a
checkpoint at step k, batch k+1 is identical whatever the new mesh is.
Per-shard placement uses make_array_from_callback so each host only
materialises its slice (single-host here, but the code path is the
multi-host one).

The synthetic LM stream is a Zipf-ish token mixture with a short-range
copy structure so tiny models show a real, monotonically improving loss.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding


@dataclasses.dataclass(frozen=True)
class SyntheticLM:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    embed_dim: int = 0          # >0 -> embed-frontend stub (vlm/audio)

    def _tokens(self, step: int) -> np.ndarray:
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step]))
        B, S, V = self.global_batch, self.seq_len, self.vocab_size
        base = rng.zipf(1.5, size=(B, S)).astype(np.int64) % max(V - 2, 1)
        # short-range copy structure: token[t] sometimes repeats token[t-3]
        mask = rng.random((B, S)) < 0.35
        out = base.copy()
        out[:, 3:][mask[:, 3:]] = base[:, :-3][mask[:, 3:]]
        return out.astype(np.int32)

    def batch(self, step: int) -> dict:
        toks = self._tokens(step)
        tgt = np.concatenate([toks[:, 1:], np.full((toks.shape[0], 1), -1,
                                                   np.int32)], axis=1)
        if self.embed_dim:
            rng = np.random.default_rng(
                np.random.SeedSequence([self.seed + 7, step]))
            emb = rng.standard_normal(
                (self.global_batch, self.seq_len, self.embed_dim),
                dtype=np.float32)
            return {"embeds": emb, "targets": tgt}
        return {"tokens": toks, "targets": tgt}


def make_batch(ds: SyntheticLM, step: int, mesh=None, specs=None,
               dtype=None) -> dict:
    """Host batch -> device arrays, per-shard placement when a mesh+specs
    are given (the multi-host path)."""
    host = ds.batch(step)
    if dtype is not None and "embeds" in host:
        host["embeds"] = host["embeds"].astype(dtype)
    if mesh is None:
        return {k: jnp.asarray(v) for k, v in host.items()}
    out = {}
    for k, v in host.items():
        sh = NamedSharding(mesh, specs[k]) if specs else None
        out[k] = jax.make_array_from_callback(
            v.shape, sh, lambda idx, v=v: v[idx])
    return out
