"""Pipeline parallelism (GPipe-style) via shard_map + ppermute.

For depth-dominated models a "stage" axis carries layer blocks; micro-
batches stream through stages with collective_permute handoffs.  The
schedule below runs S + M - 1 ticks for M microbatches over S stages
(fill + steady state + drain); backward differentiates straight through
the ppermutes (jax.grad of the shard_map), so no hand-written backward
schedule is needed.

This module is deliberately model-agnostic: stage_fn(params_slice, x) is
any per-stage block.  tests/test_pipeline.py proves numerical equivalence
with the serial execution and trains a toy pipeline end-to-end.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

AXIS = "stage"


def pipeline_apply(stage_fn, params_stacked, x_microbatches, mesh):
    """params_stacked: [S, ...] leaves (stage-sharded); x_microbatches:
    [M, mb, ...] inputs.  Returns outputs [M, mb, ...] after all S stages.
    """
    S = mesh.shape[AXIS]
    M = x_microbatches.shape[0]

    def body(params, xs):
        # params: [1, ...] local stage slice; xs: [M, mb, d] (replicated in)
        me = jax.lax.axis_index(AXIS)
        p = jax.tree.map(lambda a: a[0], params)
        n_tick = S + M - 1
        buf = jnp.zeros_like(xs[0])          # current microbatch at my stage
        outs = jnp.zeros_like(xs)

        def tick(carry, t):
            buf, outs = carry
            # stage 0 injects microbatch t (if any); others take the handoff
            inject = xs[jnp.clip(t, 0, M - 1)]
            cur = jnp.where(me == 0, inject, buf)
            active = (t - me >= 0) & (t - me < M)
            y = stage_fn(p, cur)
            y = jnp.where(active, y, cur)
            # last stage emits microbatch t - (S-1)
            emit_idx = jnp.clip(t - (S - 1), 0, M - 1)
            do_emit = (me == S - 1) & (t >= S - 1)
            outs = jax.lax.cond(
                do_emit, lambda o: o.at[emit_idx].set(y), lambda o: o, outs)
            # handoff to the next stage
            nxt = jax.lax.ppermute(y, AXIS,
                                   [(i, (i + 1) % S) for i in range(S)])
            return (nxt, outs), None

        (buf, outs), _ = jax.lax.scan(tick, (buf, outs),
                                      jnp.arange(S + M - 1))
        # outputs live on the last stage; broadcast to all (psum of masked)
        outs = jax.lax.psum(jnp.where(me == S - 1, outs, 0.0), AXIS)
        return outs

    from repro.sharding.smap import shard_map
    fn = shard_map(body, mesh, (P(AXIS), P()), P())
    return fn(params_stacked, x_microbatches)


def pipeline_loss(stage_fn, loss_fn, params_stacked, x_mb, y_mb, mesh):
    out = pipeline_apply(stage_fn, params_stacked, x_mb, mesh)
    return loss_fn(out, y_mb)


def make_pipeline_train_step(stage_fn, loss_fn, mesh, lr=1e-2):
    @jax.jit
    def step(params_stacked, x_mb, y_mb):
        l, g = jax.value_and_grad(
            lambda p: pipeline_loss(stage_fn, loss_fn, p, x_mb, y_mb, mesh)
        )(params_stacked)
        params = jax.tree.map(lambda p, gg: p - lr * gg, params_stacked, g)
        return params, l
    return step
