"""Training step: blockwise cross-entropy loss + AdamW update.

The LM-head matmul and softmax are computed blockwise over sequence chunks
inside a rematerialised scan, so the [B, S, V] logits tensor is never
materialised (vocab up to 262k here).  The vocab axis is model-sharded; the
logsumexp / label-pick reductions over it lower to psums.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models.transformer import apply_model
from repro.models.layers import logits_from_hidden
from repro.optim.adamw import adamw_update

F32 = jnp.float32
LOSS_CHUNK = 512


def _ce_chunk(cfg, params, hidden_chunk, target_chunk):
    """hidden: [B,c,D]; targets: [B,c] -> (sum_loss, n_valid)."""
    logits = logits_from_hidden(cfg, params, hidden_chunk)        # [B,c,V] f32
    lse = jax.nn.logsumexp(logits, axis=-1)
    V = logits.shape[-1]
    iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape, 2)
    picked = jnp.sum(jnp.where(iota == target_chunk[..., None], logits, 0.0),
                     axis=-1)
    valid = (target_chunk >= 0)
    loss = jnp.where(valid, lse - picked, 0.0)
    return loss.sum(), valid.sum()


def blockwise_ce(cfg, params, hidden, targets, *, unroll: bool = False):
    B, S, D = hidden.shape
    c = min(LOSS_CHUNK, S)
    n = S // c
    hid = hidden.reshape(B, n, c, D)
    tgt = targets.reshape(B, n, c)
    chunk_fn = jax.checkpoint(
        lambda h, t: _ce_chunk(cfg, params, h, t))

    def body(carry, idx):
        s, cnt = carry
        ls, nv = chunk_fn(hid[:, idx], tgt[:, idx])
        return (s + ls, cnt + nv), None

    (loss_sum, n_valid), _ = jax.lax.scan(
        body, (jnp.zeros((), F32), jnp.zeros((), jnp.int32)),
        jnp.arange(n), unroll=n if unroll else 1)
    return loss_sum / jnp.maximum(n_valid, 1)


def loss_fn(cfg, params, batch, *, unroll: bool = False):
    hidden, aux = apply_model(cfg, params, batch, unroll=unroll)
    ce = blockwise_ce(cfg, params, hidden, batch["targets"], unroll=unroll)
    return ce + aux, {"ce": ce, "aux": aux}


def train_step(cfg, params, opt_state, batch, *, unroll: bool = False,
               lr: float = 3e-4):
    """One full training step (fwd + bwd + AdamW).  Pure function; jit and
    shard at the call site (see launch/train.py and launch/dryrun.py)."""
    (loss, metrics), grads = jax.value_and_grad(
        lambda p: loss_fn(cfg, p, batch, unroll=unroll), has_aux=True)(params)
    params, opt_state, gnorm = adamw_update(params, grads, opt_state, lr=lr)
    metrics = dict(metrics, loss=loss, grad_norm=gnorm)
    return params, opt_state, metrics


def make_train_step(cfg, *, unroll: bool = False, lr: float = 3e-4):
    return functools.partial(train_step, cfg, unroll=unroll, lr=lr)
