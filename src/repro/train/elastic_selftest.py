import os
if "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
"""Elastic-scaling + pipeline-parallel self-test on 8 host devices.

1. Elastic re-mesh: train 4 steps on a (4 data x 2 model) mesh, checkpoint,
   restore the same state onto a (2 data x 4 model) mesh (different DP/TP
   split — the node-failure / elastic-rescale path) and train 4 more steps;
   asserts losses keep improving and restore is exact.
2. Pipeline parallelism: 4-stage GPipe schedule via shard_map + ppermute;
   asserts exact equivalence with serial layer application, then trains a
   toy pipeline and asserts the loss drops.
3. Compressed DP sync: int8 error-feedback all-reduce inside shard_map
   matches the fp32 all-reduce direction within tolerance.

Run by file path (python src/repro/train/elastic_selftest.py) so the device
flag precedes any jax-touching import.
"""
import sys
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs.base import ShapeSpec
from repro.configs.tiny import tiny_config
from repro.train.trainer import train
from repro.train.pipeline import AXIS, make_pipeline_train_step, pipeline_apply
from repro.optim.compression import dp_allreduce_compressed, ef_state

SHAPE = ShapeSpec("tiny", 32, 8, "train")


def check_elastic():
    cfg = tiny_config("mistral-nemo-12b")
    with tempfile.TemporaryDirectory() as d:
        mesh_a = jax.make_mesh((4, 2), ("data", "model"))
        out_a = train(cfg, mesh_a, SHAPE, steps=4, ckpt_dir=d, ckpt_every=4,
                      lr=3e-3, log_every=1)
        mesh_b = jax.make_mesh((2, 4), ("data", "model"))
        out_b = train(cfg, mesh_b, SHAPE, steps=8, ckpt_dir=d, ckpt_every=4,
                      lr=3e-3, log_every=1)
        h = out_b["history"]
        assert h[0]["step"] == 4, "resumed on the new mesh"
        assert h[-1]["loss"] < out_a["history"][0]["loss"]
        # exact state carry-over: params bytes equal across meshes
        pa = jax.tree.leaves(out_a["params"])[0]
        pb_like = jax.tree.leaves(out_b["params"])[0]
        assert pa.shape == pb_like.shape
    print("elastic ok")


def check_pipeline():
    S, M, mb, d = 4, 8, 4, 16
    mesh = jax.make_mesh((S,), (AXIS,))
    rng = np.random.RandomState(0)
    w = jnp.asarray(rng.randn(S, d, d) * (d ** -0.5), jnp.float32)

    def stage_fn(p, x):
        return jnp.tanh(x @ p)

    x = jnp.asarray(rng.randn(M, mb, d), jnp.float32)
    y_pipe = pipeline_apply(stage_fn, w, x, mesh)
    # serial reference
    y_ref = x
    for s in range(S):
        y_ref = jnp.tanh(y_ref @ w[s])
    np.testing.assert_allclose(np.asarray(y_pipe), np.asarray(y_ref),
                               rtol=2e-5, atol=2e-5)
    # train the pipeline
    tgt = jnp.asarray(rng.randn(M, mb, d), jnp.float32)
    loss_fn = lambda out, t: jnp.mean((out - t) ** 2)
    step = make_pipeline_train_step(stage_fn, loss_fn, mesh, lr=0.1)
    w2, l0 = step(w, x, tgt)
    for _ in range(20):
        w2, l = step(w2, x, tgt)
    assert float(l) < float(l0) * 0.95, (float(l0), float(l))
    print("pipeline ok")


def check_compressed_dp():
    mesh = jax.make_mesh((8,), ("data",))
    rng = np.random.RandomState(1)
    g_shards = jnp.asarray(rng.randn(8, 32, 16) * 0.01, jnp.float32)
    err = jnp.zeros((8, 32, 16), jnp.float32)

    def body(g, e):
        out, ne = dp_allreduce_compressed({"g": g[0]}, {"g": e[0]}, "data")
        return out["g"][None], ne["g"][None]

    from repro.sharding.smap import shard_map
    fn = jax.jit(shard_map(body, mesh, (P("data"), P("data")),
                           (P("data"), P("data"))))
    out, _ = fn(g_shards, err)
    ref = np.asarray(g_shards).mean(0)
    got = np.asarray(out)[0]
    rel = np.abs(got - ref).max() / np.abs(ref).max()
    assert rel < 0.05, rel
    print("compressed-dp ok")


def check_moe_smap_parity():
    """shard_map EP dispatch == GSPMD sort dispatch (same routing)."""
    from repro.configs.tiny import tiny_config
    from repro.models.moe import moe_apply, moe_init
    from repro.sharding.context import use_mesh
    cfg = tiny_config("kimi-k2-1t-a32b", n_experts=8, top_k=2,
                      capacity_factor=8.0)   # high cf: no drops -> exact
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    params = moe_init(cfg, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, cfg.d_model),
                          jnp.float32)
    y_ref, aux_ref = jax.jit(lambda p, x: moe_apply(cfg, p, x))(params, x)
    cfg2 = cfg.scaled(moe_impl="smap")
    with use_mesh(mesh):
        y_smap, aux_smap = jax.jit(
            lambda p, x: moe_apply(cfg2, p, x))(params, x)
    np.testing.assert_allclose(np.asarray(y_ref), np.asarray(y_smap),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(float(aux_ref), float(aux_smap), rtol=1e-4)
    print("moe-smap ok")


def check_decode_hint_parity():
    """decode with sequence-sharded cache hints == plain decode."""
    from repro.configs.tiny import tiny_config
    from repro.models.transformer import decode_step, init_cache, init_params
    from repro.sharding.context import use_mesh
    cfg = tiny_config("mistral-nemo-12b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    B, S = 4, 32
    cfg_h = cfg.scaled(decode_cache_hint=True)
    logits_ref = logits_hint = None
    for variant in ("ref", "hint"):
        c = init_cache(cfg, B, S)
        out = []
        for t in range(4):
            inputs = {"tokens": jnp.full((B, 1), 3 + t, jnp.int32),
                      "pos": jnp.full((B,), t, jnp.int32)}
            if variant == "ref":
                lg, c = jax.jit(lambda p, c, i: decode_step(cfg, p, c, i))(
                    params, c, inputs)
            else:
                with use_mesh(mesh):
                    lg, c = jax.jit(
                        lambda p, c, i: decode_step(cfg_h, p, c, i))(
                            params, c, inputs)
            out.append(np.asarray(lg))
        if variant == "ref":
            logits_ref = out
        else:
            logits_hint = out
    for a, b in zip(logits_ref, logits_hint):
        np.testing.assert_allclose(a, b, rtol=2e-4, atol=2e-4)
    print("decode-hint ok")


def main():
    check_elastic()
    check_pipeline()
    check_compressed_dp()
    check_moe_smap_parity()
    check_decode_hint_parity()
    print("ELASTIC-SELFTEST-OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
