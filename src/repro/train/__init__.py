from repro.train.step import loss_fn, make_train_step, train_step  # noqa: F401
