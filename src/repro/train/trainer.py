"""Training driver: jit'd step over the mesh, stateless data, async
checkpoints, crash/restart and elastic re-mesh recovery.

Fault-tolerance model (DESIGN.md §Fault tolerance / training):
  * checkpoint/restart — AsyncCheckpointer every ``ckpt_every`` steps;
    restart resumes from the latest manifest.  Data is stateless-by-step so
    no batch is lost or duplicated.
  * node failure / elastic scaling — restore_checkpoint re-places leaves
    under the new mesh's shardings; batch specs recompute from the mesh, so
    the same script continues on a smaller/larger data axis.
  * stragglers — the step is SPMD-synchronous; mitigation happens a level
    up: batches are stateless so a replacement host re-enters at the
    current step without coordination, and the async checkpointer keeps the
    restart window at ckpt_every steps.
"""
from __future__ import annotations

import time
from dataclasses import dataclass

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.checkpoint.checkpoint import (AsyncCheckpointer, latest_step,
                                         restore_checkpoint)
from repro.data.pipeline import SyntheticLM, make_batch
from repro.models.transformer import init_params
from repro.optim.adamw import adamw_init
from repro.sharding.partition import (batch_pspec, input_pspecs, opt_pspecs,
                                      param_pspecs, to_named)
from repro.train.step import train_step
from repro.configs.base import ShapeSpec


@dataclass
class TrainState:
    params: dict
    opt: dict
    step: int


def make_sharded_step(cfg, mesh, shape: ShapeSpec, lr=3e-4):
    key = jax.random.PRNGKey(0)
    params_s = jax.eval_shape(lambda k: init_params(cfg, k), key)
    p_shard = to_named(mesh, param_pspecs(cfg, params_s, mesh))
    opt_s = jax.eval_shape(adamw_init, params_s)
    o_shard = to_named(mesh, opt_pspecs(cfg, opt_s, mesh))
    from repro.configs.base import input_specs as mk_inputs
    ispec_tree = input_pspecs(cfg, shape, mk_inputs(cfg, shape), mesh)
    i_shard = to_named(mesh, ispec_tree)
    fn = jax.jit(lambda p, o, b: train_step(cfg, p, o, b, lr=lr),
                 in_shardings=(p_shard, o_shard, i_shard),
                 out_shardings=(p_shard, o_shard, None),
                 donate_argnums=(0, 1))
    return fn, p_shard, o_shard, ispec_tree


def train(cfg, mesh, shape: ShapeSpec, *, steps: int, ckpt_dir=None,
          ckpt_every: int = 50, lr: float = 3e-4, seed: int = 0,
          log_every: int = 10, fail_at: int | None = None) -> dict:
    """Run (or resume) training.  ``fail_at`` raises midway to exercise the
    crash/restart path in tests.  Returns the metrics history."""
    step_fn, p_shard, o_shard, ispecs = make_sharded_step(cfg, mesh, shape, lr)
    key = jax.random.PRNGKey(seed)
    ds = SyntheticLM(cfg.vocab_size, shape.seq_len, shape.global_batch,
                     seed=seed,
                     embed_dim=cfg.d_model if cfg.frontend == "embed" else 0)

    start = 0
    ckpt = AsyncCheckpointer(ckpt_dir) if ckpt_dir else None
    params_like = jax.eval_shape(lambda k: init_params(cfg, k), key)
    if ckpt_dir and (s := latest_step(ckpt_dir)) is not None:
        tree_like = {"params": params_like,
                     "opt": jax.eval_shape(adamw_init, params_like)}
        tree = restore_checkpoint(ckpt_dir, s,
                                  tree_like,
                                  {"params": p_shard, "opt": o_shard})
        params, opt = tree["params"], tree["opt"]
        start = s
    else:
        params = jax.device_put(init_params(cfg, key), p_shard)
        opt = jax.device_put(adamw_init(params), o_shard)

    history = []
    t0 = time.time()
    for step in range(start, steps):
        if fail_at is not None and step == fail_at:
            if ckpt:
                # flush the async writer: the injected failure models a
                # crash AFTER the last checkpoint is durable, so restart
                # tests don't race the background save thread
                ckpt.wait()
            raise RuntimeError(f"injected failure at step {step}")
        batch = make_batch(ds, step, mesh, ispecs, dtype=cfg.param_dtype)
        params, opt, metrics = step_fn(params, opt, batch)
        if step % log_every == 0 or step == steps - 1:
            m = {k: float(v) for k, v in metrics.items()}
            m["step"] = step
            m["wall_s"] = round(time.time() - t0, 2)
            history.append(m)
            print(f"[train] step={step} loss={m['loss']:.4f} "
                  f"gnorm={m['grad_norm']:.3f}", flush=True)
        if ckpt and (step + 1) % ckpt_every == 0:
            ckpt.save(step + 1, {"params": params, "opt": opt})
    if ckpt:
        ckpt.save(steps, {"params": params, "opt": opt})
        ckpt.wait()
    return {"history": history, "params": params, "opt": opt}
