"""Sharded checkpointing with elastic restore.

Format: one .npz per checkpoint step (flat leaf-path -> array) + a JSON
manifest (step, tree structure, dtypes).  Restore re-places every leaf with
the CURRENT mesh's shardings — the mesh may differ from the one that saved
(elastic scaling): arrays are resharded on device_put.  Saves are atomic
(tmp + rename) so a crash mid-save never corrupts the latest checkpoint;
AsyncCheckpointer snapshots to host then writes on a background thread so
the train loop never blocks on disk.
"""
from __future__ import annotations

import json
import threading
from pathlib import Path

import jax
import numpy as np


def _flatten(tree):
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in leaves:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out[key] = leaf
    return out


def save_checkpoint(ckpt_dir, step: int, tree) -> Path:
    ckpt_dir = Path(ckpt_dir)
    ckpt_dir.mkdir(parents=True, exist_ok=True)
    flat = {k: np.asarray(v) for k, v in _flatten(tree).items()}
    dtypes = {}
    packed = {}
    for k, v in flat.items():
        dtypes[k] = str(v.dtype)
        if v.dtype.kind == "V" or str(v.dtype) == "bfloat16":
            v = v.view(np.uint16)          # npz-safe container for bf16
        packed[k] = v
    tmp = ckpt_dir / f".tmp_step_{step}.npz"
    final = ckpt_dir / f"step_{step:08d}.npz"
    np.savez(tmp, **packed)
    tmp.rename(final)
    manifest = {"step": step, "keys": sorted(flat), "dtypes": dtypes}
    (ckpt_dir / f"manifest_{step:08d}.json").write_text(json.dumps(manifest))
    (ckpt_dir / "manifest.json").write_text(json.dumps(manifest))
    return final


def latest_step(ckpt_dir) -> int | None:
    ckpt_dir = Path(ckpt_dir)
    if not ckpt_dir.exists():
        return None
    steps = sorted(int(p.stem.split("_")[1]) for p in
                   ckpt_dir.glob("step_*.npz"))
    return steps[-1] if steps else None


def restore_checkpoint(ckpt_dir, step: int, like_tree, shardings=None):
    """Restore into the structure of ``like_tree``; reshard onto
    ``shardings`` (tree of NamedSharding) if given — the elastic path."""
    ckpt_dir = Path(ckpt_dir)
    data = np.load(ckpt_dir / f"step_{step:08d}.npz")
    manifest = json.loads((ckpt_dir / f"manifest_{step:08d}.json").read_text())
    flat_like = _flatten(like_tree)
    restored_flat = {}
    for key, like in flat_like.items():
        arr = data[key]
        if manifest["dtypes"].get(key) == "bfloat16":
            import ml_dtypes
            arr = arr.view(ml_dtypes.bfloat16)
        assert arr.shape == tuple(like.shape), (key, arr.shape, like.shape)
        restored_flat[key] = arr
    # rebuild in tree order
    leaves_paths = jax.tree_util.tree_flatten_with_path(like_tree)
    vals = []
    for path, like in leaves_paths[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        arr = restored_flat[key]
        a = arr if str(arr.dtype) == str(like.dtype) else arr.astype(like.dtype)
        vals.append(a)
    tree = jax.tree_util.tree_unflatten(leaves_paths[1], vals)
    if shardings is not None:
        tree = jax.tree.map(lambda a, s: jax.device_put(a, s), tree,
                            shardings)
    return tree


class AsyncCheckpointer:
    """Snapshot-to-host then background write; wait() joins the writer."""

    def __init__(self, ckpt_dir):
        self.ckpt_dir = Path(ckpt_dir)
        self._thread: threading.Thread | None = None

    def save(self, step: int, tree):
        host_tree = jax.tree.map(np.asarray, tree)   # synchronous snapshot
        self.wait()
        self._thread = threading.Thread(
            target=save_checkpoint, args=(self.ckpt_dir, step, host_tree),
            daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
