"""Benchmark harness: one module per paper table/figure + the roofline
table from the dry-run artifacts.  Prints ``name,us_per_call,derived`` CSV.

  PYTHONPATH=src python -m benchmarks.run            # all benches
  PYTHONPATH=src python -m benchmarks.run fig9 ycsb  # substring filter
"""
from __future__ import annotations

import sys
import time


def _reporter(rows):
    def report(name, **kw):
        us = kw.pop("us_per_op", kw.pop("us_per_call", ""))
        derived = ";".join(f"{k}={v}" for k, v in kw.items())
        rows.append((name, us, derived))
        print(f"{name},{us if us == '' else round(us, 3)},{derived}",
              flush=True)
    return report


def main() -> None:
    from benchmarks import (fig3_index_compare, fig9_basic_ops,
                            fig11_breakdown, fig12_ycsb, fig13_recovery,
                            roofline)
    benches = [
        ("fig3_index_compare", fig3_index_compare.run),
        ("fig9_10_basic_ops", fig9_basic_ops.run),
        ("fig9_kernel_dispatch", fig9_basic_ops.run_kernel_dispatch),
        ("fig11_breakdown", fig11_breakdown.run),
        ("fig11_kernel_dispatch", fig11_breakdown.run_kernel_dispatch),
        ("fig12_ycsb", fig12_ycsb.run),
        ("fig13_14_recovery_degraded", fig13_recovery.run),
        ("roofline", roofline.run),
    ]
    filters = [a for a in sys.argv[1:] if not a.startswith("-")]
    rows = []
    report = _reporter(rows)
    print("name,us_per_call,derived")
    for name, fn in benches:
        if filters and not any(f in name for f in filters):
            continue
        t0 = time.time()
        print(f"# --- {name} ---", flush=True)
        fn(report)
        print(f"# {name} done in {time.time()-t0:.1f}s", flush=True)


if __name__ == "__main__":
    main()
