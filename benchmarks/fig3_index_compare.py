"""Fig. 3 reproduction: index-structure comparison.

3a — memory accesses per single-key lookup vs data amount: hash table stays
~1 (sub-bucket reads), the sorted directory grows as ceil(log_fanout N)
(the skiplist/B+-tree levels in the paper grow 3->10 over 1M->100M).
3b — indexing latency: hash probe (one-sided: no server logic) vs sorted
search (server-side walk); we report measured batch latency per op.
3c/3d — share of indexing in the whole PUT/GET (with 32 B value access).
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import (CFG, KD, percentile_fields, timeit,
                               timeit_hist, uniform_keys)
from repro.core import hash_index as hix
from repro.core import sorted_index as six


def run(report):
    q = 4096
    for n in [10_000, 100_000, 1_000_000]:
        keys = jnp.asarray(uniform_keys(n, seed=n), KD)
        addrs = jnp.arange(n, dtype=jnp.int32)
        h = hix.create(n * 2, CFG)
        h, _ = hix.insert(h, keys, addrs, CFG)
        from repro.core.hashing import next_pow2
        s = six.create(next_pow2(n))     # tight capacity: directory levels
        s = six.bulk_load(s, keys, addrs)  # grow with data amount (Fig 3a)
        probe = keys[:q]

        h_h, out_h = timeit_hist(lambda: hix.lookup(h, probe, CFG))
        acc_h = float(jnp.mean(out_h[2]))
        h_s, out_s = timeit_hist(lambda: six.search(s, probe, CFG.fanout))
        acc_s = float(jnp.mean(out_s[2]))
        report("fig3a_hash_accesses", n=n, value=round(acc_h, 2))
        report("fig3a_sorted_accesses", n=n, value=round(acc_s, 2))
        report("fig3b_hash_lookup", n=n, us_per_op=h_h.mean / q * 1e6,
               **percentile_fields(h_h, per_op=q))
        report("fig3b_sorted_lookup", n=n, us_per_op=h_s.mean / q * 1e6,
               **percentile_fields(h_s, per_op=q))

    # 3c/3d: indexing share of full op (index + 32B value access)
    n = 1_000_000
    keys = jnp.asarray(uniform_keys(n, seed=5), KD)
    addrs = jnp.arange(n, dtype=jnp.int32)
    h = hix.create(n * 2, CFG)
    h, _ = hix.insert(h, keys, addrs, CFG)
    s = six.create(1 << 21)
    s = six.bulk_load(s, keys, addrs)
    vals = jnp.zeros((n, CFG.value_words), jnp.int32)
    probe = keys[:q]

    def get_hash_full():
        a, f, _ = hix.lookup(h, probe, CFG)
        return vals[jnp.clip(a, 0, n - 1)]

    def get_sorted_full():
        a, f, _ = six.search(s, probe, CFG.fanout)
        return vals[jnp.clip(a, 0, n - 1)]

    t_idx_h, _ = timeit(lambda: hix.lookup(h, probe, CFG))
    t_full_h, _ = timeit(get_hash_full)
    t_idx_s, _ = timeit(lambda: six.search(s, probe, CFG.fanout))
    t_full_s, _ = timeit(get_sorted_full)
    report("fig3d_get_index_share_hash",
           value=round(t_idx_h / max(t_full_h, 1e-12), 3))
    report("fig3d_get_index_share_sorted",
           value=round(t_idx_s / max(t_full_s, 1e-12), 3))
