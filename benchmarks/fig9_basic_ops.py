"""Fig. 9 + 10 reproduction: throughput/latency of PUT, GET, SCAN for
histore vs all-hashtable vs all-skiplist vs single-hashtable vs
single-skiplist (db_bench-style: load N, then timed op batches), plus
the kernel-dispatch section: the same serving ops measured side-by-side
under ``use_kernels=off`` (jnp reference path) and ``use_kernels=on``
(Pallas kernels), with a gating ``kernel_no_slower`` capability row on
the GET index-probe p50.

Standalone for CI smoke runs (tools/ci.sh --bench-smoke):

    python -m benchmarks.fig9_basic_ops --smoke --json out.json
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import (CFG, KD, SYSTEMS, env_fields,
                               interleaved_medians, percentile_fields,
                               stamped, timeit_hist, uniform_keys)
from repro.configs.histore import scaled
from repro.core.client import LocalBackend
from repro.kernels import ops as kops


def run(report, n_load=200_000, batch=4096):
    report = stamped(report, CFG)
    keys = uniform_keys(n_load, seed=9)
    addrs = np.arange(n_load, dtype=np.int32)
    rng = np.random.default_rng(3)

    for SysCls in SYSTEMS:
        sys_ = SysCls(n_load * 4)
        for i in range(0, n_load, 16384):
            sys_.load(jnp.asarray(keys[i:i + 16384], KD),
                      jnp.asarray(addrs[i:i + 16384]))
        # PUT: new uniform keys
        new_keys = jnp.asarray(uniform_keys(batch, seed=77) + (1 << 29), KD)
        new_addrs = jnp.arange(batch, dtype=jnp.int32)

        def do_put():
            ok = sys_.put(new_keys, new_addrs)
            sys_.apply_async()
            return ok

        h_put, _ = timeit_hist(do_put, warmup=1, iters=3)
        report(f"fig9a_put_{sys_.name}", us_per_op=h_put.mean / batch * 1e6,
               mops=batch / h_put.mean / 1e6,
               **percentile_fields(h_put, per_op=batch))

        # GET: uniform over loaded keys
        gq = jnp.asarray(rng.choice(keys, batch), KD)
        h_get, out = timeit_hist(lambda: sys_.get(gq), iters=3)
        assert bool(out[1].all()), sys_.name
        report(f"fig9b_get_{sys_.name}", us_per_op=h_get.mean / batch * 1e6,
               mops=batch / h_get.mean / 1e6,
               **percentile_fields(h_get, per_op=batch))

        # SCAN: 100-key ranges (paper setting)
        if sys_.supports_scan:
            lo = jnp.asarray(int(np.median(keys)), KD)
            hi = jnp.asarray((1 << 30), KD)
            h_scan, _ = timeit_hist(lambda: sys_.scan(lo, hi, 100),
                                    warmup=1, iters=3)
            report(f"fig9c_scan_{sys_.name}", us_per_op=h_scan.mean * 1e6,
                   **percentile_fields(h_scan))


# threshold for the gating row: same 25% slack as the whole bench gate
# (tools/bench_check.py --rtol default) — "no slower" is asserted up to
# the noise envelope the gate already accepts for every latency field
KERNEL_NO_SLOWER_SLACK = 1.25


def run_kernel_dispatch(report, n_load=20_000, batch=2048):
    """Side-by-side jnp-vs-kernel rows over the SAME backend code: two
    explicit cfgs (``use_kernels`` off / on — never env-resolved
    ``auto``, so the pair is meaningful on any machine), one LocalBackend
    each, identical keys.  Rows:

      fig9b_get_histore_{jnp,kernel}     — full backend GET (probe +
                                           value fetch), p50 per op
      fig9b_index_probe_{jnp,kernel}     — the dispatch-level GET index
                                           probe alone (the op the
                                           kernel replaces)
      fig9c_scan_histore_{jnp,kernel}    — backend SCAN (drain + range)
      fig9_kernel_get_gate               — capability row: True iff the
                                           kernel probe p50 is no slower
                                           than jnp (within the gate's
                                           25% noise slack).  Measured
                                           INTERLEAVED (one timed call
                                           of each path per round) so
                                           machine drift hits both sides
                                           equally and the ratio is
                                           stable.
    """
    keys = uniform_keys(n_load, seed=9)
    rng = np.random.default_rng(3)
    gq = jnp.asarray(rng.choice(keys, batch), KD)
    valid = jnp.ones((batch,), bool)
    lo = jnp.asarray(int(np.median(keys)), KD)
    hi = jnp.asarray((1 << 30), KD)
    probes, hidx = {}, {}
    for knob in ("off", "on"):
        cfg = scaled(use_kernels=knob, log_capacity=1 << 14,
                     async_apply_batch=8192)
        label = "kernel" if kops.kernels_enabled(cfg) else "jnp"
        env = env_fields(cfg)
        be = LocalBackend(n_load * 4, cfg)
        vw = be.value_words
        for i in range(0, n_load, 4096):
            ch = jnp.asarray(keys[i:i + 4096], KD)
            be.put(ch, jnp.zeros((ch.shape[0], vw), jnp.int32),
                   jnp.ones((ch.shape[0],), bool))
        be.drain()

        h_get, out = timeit_hist(lambda: be.get(gq, valid), iters=9)
        assert bool(out[1].all()), f"kernel-dispatch GET miss ({label})"
        report(f"fig9b_get_histore_{label}",
               us_per_op=h_get.mean / batch * 1e6,
               **percentile_fields(h_get, per_op=batch), **env)

        probe = jax.jit(functools.partial(kops.probe, cfg))
        h_probe, _ = timeit_hist(lambda: probe(be.group.hash, gq), iters=9)
        report(f"fig9b_index_probe_{label}",
               us_per_op=h_probe.mean / batch * 1e6,
               **percentile_fields(h_probe, per_op=batch), **env)
        probes[label], hidx[label] = probe, be.group.hash

        h_scan, _ = timeit_hist(lambda: be.scan(lo, hi, 100),
                                warmup=1, iters=5)
        report(f"fig9c_scan_histore_{label}", us_per_op=h_scan.mean * 1e6,
               **percentile_fields(h_scan), **env)

    med = interleaved_medians(
        {label: (lambda label=label: probes[label](hidx[label], gq))
         for label in ("jnp", "kernel")})
    ratio = med["kernel"] / max(med["jnp"], 1e-12)
    report("fig9_kernel_get_gate",
           kernel_no_slower=bool(ratio <= KERNEL_NO_SLOWER_SLACK),
           probe_p50_ratio=round(ratio, 3),
           probe_p50_jnp_us=round(med["jnp"] / batch * 1e6, 4),
           probe_p50_kernel_us=round(med["kernel"] / batch * 1e6, 4),
           platform=jax.default_backend())


def main(argv=None) -> int:
    """Standalone entry (CI bench smoke): run the basic-op benches —
    always including the jnp-vs-kernel dispatch section — and dump
    JSON rows for tools/bench_check.py."""
    import argparse
    import json

    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default=None,
                    help="write collected rows as JSON")
    ap.add_argument("--smoke", action="store_true",
                    help="small n + histore-only system sweep (CI tier)")
    args = ap.parse_args(argv)
    rows = []

    def report(name, **kw):
        rows.append({"name": name, **kw})
        print(name, kw, flush=True)

    if args.smoke:
        run_kernel_dispatch(report, n_load=20_000, batch=2048)
    else:
        run(report)
        run_kernel_dispatch(report)
    if args.json:
        with open(args.json, "w") as f:
            json.dump(rows, f, indent=2, default=str)
        print(f"wrote {args.json} ({len(rows)} rows)", flush=True)
    return 0


if __name__ == "__main__":
    import sys

    sys.exit(main(sys.argv[1:]))
