"""Fig. 9 + 10 reproduction: throughput/latency of PUT, GET, SCAN for
histore vs all-hashtable vs all-skiplist vs single-hashtable vs
single-skiplist (db_bench-style: load N, then timed op batches)."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import (CFG, KD, SYSTEMS, percentile_fields,
                               timeit_hist, uniform_keys)


def run(report, n_load=200_000, batch=4096):
    keys = uniform_keys(n_load, seed=9)
    addrs = np.arange(n_load, dtype=np.int32)
    rng = np.random.default_rng(3)

    for SysCls in SYSTEMS:
        sys_ = SysCls(n_load * 4)
        for i in range(0, n_load, 16384):
            sys_.load(jnp.asarray(keys[i:i + 16384], KD),
                      jnp.asarray(addrs[i:i + 16384]))
        # PUT: new uniform keys
        new_keys = jnp.asarray(uniform_keys(batch, seed=77) + (1 << 29), KD)
        new_addrs = jnp.arange(batch, dtype=jnp.int32)

        def do_put():
            ok = sys_.put(new_keys, new_addrs)
            sys_.apply_async()
            return ok

        h_put, _ = timeit_hist(do_put, warmup=1, iters=3)
        report(f"fig9a_put_{sys_.name}", us_per_op=h_put.mean / batch * 1e6,
               mops=batch / h_put.mean / 1e6,
               **percentile_fields(h_put, per_op=batch))

        # GET: uniform over loaded keys
        gq = jnp.asarray(rng.choice(keys, batch), KD)
        h_get, out = timeit_hist(lambda: sys_.get(gq), iters=3)
        assert bool(out[1].all()), sys_.name
        report(f"fig9b_get_{sys_.name}", us_per_op=h_get.mean / batch * 1e6,
               mops=batch / h_get.mean / 1e6,
               **percentile_fields(h_get, per_op=batch))

        # SCAN: 100-key ranges (paper setting)
        if sys_.supports_scan:
            lo = jnp.asarray(int(np.median(keys)), KD)
            hi = jnp.asarray((1 << 30), KD)
            h_scan, _ = timeit_hist(lambda: sys_.scan(lo, hi, 100),
                                    warmup=1, iters=3)
            report(f"fig9c_scan_{sys_.name}", us_per_op=h_scan.mean * 1e6,
                   **percentile_fields(h_scan))
