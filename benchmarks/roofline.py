"""Roofline table: reads results/dryrun/*.json (produced by
repro.launch.dryrun) and prints the per-(arch x shape) terms — the §Roofline
deliverable.  Also emits the markdown table used by EXPERIMENTS.md."""
from __future__ import annotations

import glob
import json
from pathlib import Path

RESULTS = Path(__file__).resolve().parents[1] / "results" / "dryrun"


def load_cells(results_dir=RESULTS):
    cells = []
    for f in sorted(glob.glob(str(results_dir / "*.json"))):
        cells.append(json.load(open(f)))
    return cells


def run(report, results_dir=RESULTS):
    for r in load_cells(results_dir):
        tag = f"{r['arch']}__{r['shape']}"
        if r["status"] == "skipped":
            report(f"roofline_{tag}", status="skipped")
            continue
        if r["status"] != "ok":
            report(f"roofline_{tag}", status="error")
            continue
        t = r["roofline"]
        report(
            f"roofline_{tag}",
            compute_s=round(t["compute_s"], 4),
            memory_s=round(t["memory_s"], 4),
            collective_s=round(t["collective_s"], 4),
            dominant=t["dominant"].replace("_s", ""),
            useful_flops_ratio=round(r.get("useful_flops_ratio", 0), 3),
            hbm_gb_per_dev=round(r["memory"]["peak_estimate_bytes"] / 1e9, 1),
        )


def markdown_table(results_dir=RESULTS) -> str:
    rows = ["| arch | shape | compute s | memory s | collective s | "
            "dominant | useful-flops | HBM GB/dev |",
            "|---|---|---|---|---|---|---|---|"]
    for r in load_cells(results_dir):
        if r["status"] == "skipped":
            rows.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                        f"skipped (sub-quadratic rule) | — | — |")
            continue
        if r["status"] != "ok":
            rows.append(f"| {r['arch']} | {r['shape']} | ERROR | | | | | |")
            continue
        t = r["roofline"]
        rows.append(
            f"| {r['arch']} | {r['shape']} | {t['compute_s']:.3f} | "
            f"{t['memory_s']:.3f} | {t['collective_s']:.3f} | "
            f"{t['dominant'].replace('_s','')} | "
            f"{r.get('useful_flops_ratio', 0):.3f} | "
            f"{r['memory']['peak_estimate_bytes']/1e9:.1f} |")
    return "\n".join(rows)


if __name__ == "__main__":
    print(markdown_table())
