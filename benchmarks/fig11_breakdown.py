"""Fig. 11 reproduction: per-phase breakdown of PUT / GET / SCAN in
HiStore: log append, log replication (backup sync), index access, data
access, drain-before-scan — plus the kernel-dispatch section: the three
kernelized index phases (GET probe, SCAN range query, async-apply
merge) measured side-by-side under ``use_kernels=off`` and ``on``.

Standalone for CI smoke runs (tools/ci.sh --bench-smoke):

    python -m benchmarks.fig11_breakdown --smoke --json out.json
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import (CFG, KD, env_fields, percentile_fields,
                               stamped, timeit, timeit_hist, uniform_keys)
from repro.configs.histore import scaled
from repro.core import hash_index as hix
from repro.core import index_group as ig
from repro.core import log as lg
from repro.core import sorted_index as six
from repro.kernels import ops as kops


def run(report, n_load=200_000, batch=4096):
    report = stamped(report, CFG)
    keys = uniform_keys(n_load, seed=11)
    addrs = np.arange(n_load, dtype=np.int32)
    g = ig.create(n_load * 4, CFG)
    for i in range(0, n_load, 16384):
        g, _ = ig.put(g, jnp.asarray(keys[i:i + 16384], KD),
                      jnp.asarray(addrs[i:i + 16384]), CFG)
        g = ig.drain(g, CFG)
    vals = jnp.zeros((n_load * 2, CFG.value_words), jnp.int32)

    nk = jnp.asarray(uniform_keys(batch, seed=78) + (1 << 29), KD)
    na = jnp.arange(batch, dtype=jnp.int32)
    ops = jnp.full((batch,), six.OP_PUT, jnp.int8)

    # PUT phases (histogram per phase: percentiles over timed iterations)
    h_log, _ = timeit_hist(lambda: lg.append(g.plog, nk, na, ops))
    h_sync, _ = timeit_hist(lambda: jax.vmap(
        lambda l: lg.append(l, nk, na, ops))(g.blogs))
    h_hash, _ = timeit_hist(lambda: hix.insert(g.hash, nk, na, CFG))
    t_log, t_sync, t_hash = h_log.mean, h_sync.mean, h_hash.mean
    total_put = t_log + t_sync + t_hash
    report("fig11_put_log_append", share=round(t_log / total_put, 3),
           us_per_op=t_log / batch * 1e6,
           **percentile_fields(h_log, per_op=batch))
    report("fig11_put_log_sync", share=round(t_sync / total_put, 3),
           us_per_op=t_sync / batch * 1e6,
           **percentile_fields(h_sync, per_op=batch))
    report("fig11_put_index_access", share=round(t_hash / total_put, 3),
           us_per_op=t_hash / batch * 1e6,
           **percentile_fields(h_hash, per_op=batch))

    # GET phases
    gq = jnp.asarray(keys[:batch], KD)
    h_idx, out = timeit_hist(lambda: hix.lookup(g.hash, gq, CFG))
    addr = out[0]
    h_data, _ = timeit_hist(
        lambda: vals[jnp.clip(addr, 0, vals.shape[0] - 1)])
    t_idx, t_data = h_idx.mean, h_data.mean
    report("fig11_get_index_access",
           share=round(t_idx / (t_idx + t_data), 3),
           us_per_op=t_idx / batch * 1e6,
           **percentile_fields(h_idx, per_op=batch))
    report("fig11_get_data_access",
           share=round(t_data / (t_idx + t_data), 3),
           us_per_op=t_data / batch * 1e6,
           **percentile_fields(h_data, per_op=batch))

    # SCAN phases: drain + search + data fetch (100 keys)
    g2, _ = ig.put(g, nk, na, CFG)
    t_drain, g3 = timeit(lambda: ig.drain(g2, CFG, max_rounds=1),
                         warmup=1, iters=3)
    srt = jax.tree.map(lambda a: a[0], g3.sorted)
    lo = jnp.asarray(int(np.median(keys)), KD)
    t_q, out = timeit(lambda: six.range_query(srt, lo, jnp.asarray(1 << 30, KD), 100))
    a100 = out[1]
    t_dscan, _ = timeit(lambda: vals[jnp.clip(a100, 0, vals.shape[0] - 1)])
    tot = t_drain + t_q + t_dscan
    report("fig11_scan_drain", share=round(t_drain / tot, 3))
    report("fig11_scan_index_query", share=round(t_q / tot, 3))
    report("fig11_scan_data_access", share=round(t_dscan / tot, 3))


def run_kernel_dispatch(report, n_load=20_000, batch=2048):
    """The three kernelized index phases, jnp vs kernel, through the
    SAME kops dispatch calls the serving path makes (explicit off/on
    cfgs — never env-resolved ``auto``):

      fig11_get_index_access_{jnp,kernel}   — kops.probe (fused hash
                                              chain walk)
      fig11_scan_index_query_{jnp,kernel}   — kops.range_query (kernel
                                              lower-bound + gather)
      fig11_apply_merge_{jnp,kernel}        — kops.merge (bitonic
                                              incremental apply)
    """
    keys = uniform_keys(n_load, seed=11)
    addrs = np.arange(n_load, dtype=np.int32)
    nk = jnp.asarray(uniform_keys(batch, seed=78) + (1 << 29), KD)
    na = jnp.arange(batch, dtype=jnp.int32)
    ops = jnp.full((batch,), six.OP_PUT, jnp.int8)
    for knob in ("off", "on"):
        cfg = scaled(use_kernels=knob, log_capacity=1 << 14,
                     async_apply_batch=8192)
        label = "kernel" if kops.kernels_enabled(cfg) else "jnp"
        env = env_fields(cfg)
        g = ig.create(n_load * 4, cfg)
        for i in range(0, n_load, 16384):
            g, _ = ig.put(g, jnp.asarray(keys[i:i + 16384], KD),
                          jnp.asarray(addrs[i:i + 16384]), cfg)
            g = ig.drain(g, cfg)

        gq = jnp.asarray(keys[:batch], KD)
        probe = jax.jit(functools.partial(kops.probe, cfg))
        h_idx, _ = timeit_hist(lambda: probe(g.hash, gq), iters=7)
        report(f"fig11_get_index_access_{label}",
               us_per_op=h_idx.mean / batch * 1e6,
               **percentile_fields(h_idx, per_op=batch), **env)

        srt = jax.tree.map(lambda a: a[0], g.sorted)
        lo = jnp.asarray(int(np.median(keys)), KD)
        hi = jnp.asarray(1 << 30, KD)
        rq = jax.jit(functools.partial(kops.range_query, cfg, limit=100))
        h_q, _ = timeit_hist(lambda: rq(srt, lo, hi), iters=7)
        report(f"fig11_scan_index_query_{label}",
               us_per_op=h_q.mean * 1e6,
               **percentile_fields(h_q), **env)

        mg = jax.jit(functools.partial(kops.merge, cfg))
        h_m, _ = timeit_hist(lambda: mg(srt, nk, na, ops), iters=7)
        report(f"fig11_apply_merge_{label}",
               us_per_op=h_m.mean / batch * 1e6,
               **percentile_fields(h_m, per_op=batch), **env)


def main(argv=None) -> int:
    """Standalone entry (CI bench smoke): run the phase-breakdown
    benches — always including the jnp-vs-kernel dispatch section —
    and dump JSON rows for tools/bench_check.py."""
    import argparse
    import json

    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default=None,
                    help="write collected rows as JSON")
    ap.add_argument("--smoke", action="store_true",
                    help="small n, kernel-dispatch section only (CI tier)")
    args = ap.parse_args(argv)
    rows = []

    def report(name, **kw):
        rows.append({"name": name, **kw})
        print(name, kw, flush=True)

    if args.smoke:
        run_kernel_dispatch(report, n_load=20_000, batch=2048)
    else:
        run(report)
        run_kernel_dispatch(report)
    if args.json:
        with open(args.json, "w") as f:
            json.dump(rows, f, indent=2, default=str)
        print(f"wrote {args.json} ({len(rows)} rows)", flush=True)
    return 0


if __name__ == "__main__":
    import sys

    sys.exit(main(sys.argv[1:]))
