"""Shared benchmark utilities: timing, workloads, comparison systems.

The paper evaluates HiStore against *all-hashtable* (3 hash replicas),
*all-skiplist* (3 skiplist replicas), *single-hashtable* and
*single-skiplist*.  None exist as RDMA systems here, so — as in the paper,
which implemented them itself — we implement each as an index-group
variant over the same substrate: identical logs/replication machinery,
only the index structures differ.  All measurements are CPU wall-clock of
the jitted index-side ops (the data path is identical across systems, so
relative numbers mirror the paper's comparisons; see EXPERIMENTS.md
§Paper-validation for the mapping).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.histore import scaled
from repro.core import hash_index as hix
from repro.core import telemetry as tm
from repro.core import log as lg
from repro.core import sorted_index as six
from repro.core.client import HiStoreClient, LocalBackend
from repro.core.hashing import key_dtype
from repro.kernels import ops as kops

KD = key_dtype()
CFG = scaled(log_capacity=1 << 14, async_apply_batch=8192)


def env_fields(cfg=CFG):
    """The measurement-environment stamp every bench row carries: which
    index path served it (``use_kernels`` RESOLVED — an ``auto`` cfg
    stamps what it actually dispatched to) and the jax platform.  The
    regression gate (tools/bench_check.py FLAG_FIELDS) refuses to compare
    rows whose stamps differ: a kernel-path run gated against a jnp-path
    baseline is a configuration mismatch, not a regression."""
    return {"use_kernels": "on" if kops.kernels_enabled(cfg) else "off",
            "platform": jax.default_backend()}


def stamped(report, cfg=CFG):
    """Wrap a report callback so every row carries env_fields(cfg).
    Per-row kwargs win, so side-by-side kernel-vs-jnp sections can stamp
    each row with the explicit cfg it measured."""
    env = env_fields(cfg)

    def report2(name, **kw):
        report(name, **{**env, **kw})
    return report2


def timeit(fn, *args, warmup=2, iters=5):
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
        jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters, out


def timeit_hist(fn, *args, warmup=2, iters=5):
    """Like ``timeit`` but records every iteration into a latency
    histogram so figure scripts can report percentiles (the paper's §6
    reports p50/p99, not means).  Returns (LatencySnapshot, out)."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    h = tm.LatencyHistogram()
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        h.record(time.perf_counter() - t0)
    return h.snapshot(), out


def percentile_fields(snap, per_op=1):
    """Flatten a LatencySnapshot into bench-row fields (µs, divided by
    ``per_op`` for batch timings).  Percentile fields are informational:
    bench_check gates only the mean-based fields (see LATENCY_FIELDS)."""
    scale = 1e6 / max(per_op, 1)
    return {"p50_us": snap.p50 * scale, "p95_us": snap.p95 * scale,
            "p99_us": snap.p99 * scale}


def interleaved_medians(fns: dict, rounds=15, warmup=2) -> dict:
    """Median wall-clock seconds per labelled thunk, measured in
    ALTERNATING rounds (one timed call of each per round).  A/B
    comparisons on a shared machine drift with load; interleaving puts
    both sides under the same drift so their ratio is stable where two
    sequential ``timeit`` blocks are not (the jnp-vs-kernel gate row
    flapped 1.0x-1.7x sequentially, 0.93x-1.06x interleaved)."""
    for fn in fns.values():
        for _ in range(warmup):
            jax.block_until_ready(fn())
    samples = {label: [] for label in fns}
    for _ in range(rounds):
        for label, fn in fns.items():
            t0 = time.perf_counter()
            jax.block_until_ready(fn())
            samples[label].append(time.perf_counter() - t0)
    return {label: float(np.median(s)) for label, s in samples.items()}


def uniform_keys(n, seed=0, space=1 << 28):
    rng = np.random.default_rng(seed)
    return rng.choice(space, size=n, replace=False).astype(np.int64) + 1


def zipf_indices(n_ops, n_keys, theta=0.9, seed=1):
    """Zipfian ranks (YCSB-style, zipf constant 0.9)."""
    rng = np.random.default_rng(seed)
    ranks = np.arange(1, n_keys + 1)
    p = 1.0 / ranks ** theta
    p /= p.sum()
    return rng.choice(n_keys, size=n_ops, p=p)


# ---------------------------------------------------------------------------
# Comparison systems (index-group variants)
# ---------------------------------------------------------------------------
class HiStoreSys:
    """hash primary + 2 sorted replicas (the paper's system), driven
    through the unified HiStoreClient — the same front door the serving
    engine and examples use, so benchmark numbers include the real client
    path (fixed-shape batching, typed results)."""
    name = "histore"
    supports_scan = True

    def __init__(self, capacity):
        self.client = HiStoreClient(LocalBackend(capacity, CFG),
                                    batch_quantum=4096, max_batch=16384)

    def load(self, keys, addrs):
        self.client.put(keys, addrs)
        self.client.drain()

    def put(self, keys, addrs):
        return self.client.put(keys, addrs).ok

    def get(self, keys):
        # GetResult unpacks positionally as (addrs, found, accesses, ...)
        return self.client.get(keys)

    def scan(self, lo, hi, limit):
        return self.client.scan(lo, hi, limit)

    def apply_async(self):
        self.client.apply()


class AllHashSys:
    """3 hash tables (primary + 2 hash replicas); no range queries."""
    name = "all-hashtable"
    supports_scan = False

    def __init__(self, capacity):
        self.h = hix.create(capacity, CFG)
        self.hrep = [hix.create(capacity, CFG) for _ in range(2)]
        self.plog = lg.create(CFG.log_capacity)
        self.blogs = [lg.create(CFG.log_capacity) for _ in range(2)]

    def load(self, keys, addrs):
        self.put(keys, addrs)
        self._apply_all()

    def put(self, keys, addrs):
        ops = jnp.full(keys.shape, six.OP_PUT, jnp.int8)
        self.plog, ok = lg.append(self.plog, keys, addrs, ops)
        self.blogs = [lg.append(b, keys, addrs, ops)[0] for b in self.blogs]
        self.h, okh = hix.insert(self.h, keys, addrs, CFG)
        return ok & okh

    def _apply_all(self):
        for i, b in enumerate(self.blogs):
            while int(lg.pending_count(b)) > 0:
                k, a, o, b = lg.take_pending(b, CFG.async_apply_batch)
                self.hrep[i], _ = hix.insert(
                    self.hrep[i], jnp.where(o > 0, k, -1), a, CFG)
            self.blogs[i] = b

    def get(self, keys):
        return hix.lookup(self.h, keys, CFG)

    def apply_async(self):
        for i, b in enumerate(self.blogs):
            k, a, o, self.blogs[i] = lg.take_pending(b, CFG.async_apply_batch)
            self.hrep[i], _ = hix.insert(
                self.hrep[i], jnp.where(o > 0, k, -1), a, CFG)


class AllSkipSys:
    """3 skiplists; primary updates its sorted index synchronously."""
    name = "all-skiplist"
    supports_scan = True

    def __init__(self, capacity):
        self.s = six.create(capacity)
        self.srep = [six.create(capacity) for _ in range(2)]
        self.blogs = [lg.create(CFG.log_capacity) for _ in range(2)]

    def load(self, keys, addrs):
        ops = jnp.full(keys.shape, six.OP_PUT, jnp.int8)
        self.s = six.merge(self.s, keys, addrs, ops)
        self.srep = [six.merge(r, keys, addrs, ops) for r in self.srep]

    def put(self, keys, addrs):
        ops = jnp.full(keys.shape, six.OP_PUT, jnp.int8)
        self.blogs = [lg.append(b, keys, addrs, ops)[0] for b in self.blogs]
        self.s = six.merge(self.s, keys, addrs, ops)     # synchronous
        return jnp.ones(keys.shape, bool)

    def get(self, keys):
        return six.search(self.s, keys, CFG.fanout)

    def scan(self, lo, hi, limit):
        return six.range_query(self.s, lo, hi, limit)

    def apply_async(self):
        for i, b in enumerate(self.blogs):
            k, a, o, self.blogs[i] = lg.take_pending(b, CFG.async_apply_batch)
            self.srep[i] = six.merge(self.srep[i], k, a, o)


class SingleHashSys(AllHashSys):
    name = "single-hashtable"

    def put(self, keys, addrs):
        self.h, ok = hix.insert(self.h, keys, addrs, CFG)
        return ok

    def load(self, keys, addrs):
        self.put(keys, addrs)

    def apply_async(self):
        pass


class SingleSkipSys(AllSkipSys):
    name = "single-skiplist"

    def __init__(self, capacity):
        super().__init__(capacity)
        self.blogs = []

    def put(self, keys, addrs):
        ops = jnp.full(keys.shape, six.OP_PUT, jnp.int8)
        self.s = six.merge(self.s, keys, addrs, ops)
        return jnp.ones(keys.shape, bool)

    def apply_async(self):
        pass


SYSTEMS = [HiStoreSys, AllHashSys, AllSkipSys, SingleHashSys, SingleSkipSys]
