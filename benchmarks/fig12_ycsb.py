"""Fig. 12 reproduction: YCSB A-F (zipfian 0.9, scan length 100) over
histore / all-hashtable / all-skiplist, throughput normalised to
all-skiplist (as in the paper)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import (AllHashSys, AllSkipSys, HiStoreSys, KD,
                               percentile_fields, uniform_keys, zipf_indices)
from repro.core import telemetry as tm

WORKLOADS = {
    "A": {"read": 0.5, "update": 0.5},
    "B": {"read": 0.95, "update": 0.05},
    "C": {"read": 1.0},
    "D": {"read": 0.95, "insert": 0.05},
    "E": {"scan": 0.95, "insert": 0.05},
    "F": {"read": 0.5, "rmw": 0.5},
}


def run(report, n_load=100_000, n_ops=16_384, batch=4096):
    keys = uniform_keys(n_load, seed=21)
    addrs = np.arange(n_load, dtype=np.int32)
    results = {}
    for SysCls in (AllSkipSys, HiStoreSys, AllHashSys):
        sys_ = SysCls(n_load * 6)
        t_load0 = time.perf_counter()
        for i in range(0, n_load, 16384):
            sys_.load(jnp.asarray(keys[i:i + 16384], KD),
                      jnp.asarray(addrs[i:i + 16384]))
        # per-phase row (load vs run): informational only — single-pass
        # phase timings are too noisy to gate, so bench_check skips them
        report(f"fig12_load_{sys_.name}", non_gating=True,
               seconds=round(time.perf_counter() - t_load0, 4),
               ops_per_s=round(n_load / (time.perf_counter() - t_load0), 1))
        for wl, mix in WORKLOADS.items():
            if "scan" in mix and not sys_.supports_scan:
                results[(sys_.name, wl)] = float("nan")
                continue
            rng = np.random.default_rng(42)
            hist = tm.LatencyHistogram()    # per-batch run latencies
            t0 = time.perf_counter()
            done = 0
            insert_base = 1 << 29
            while done < n_ops:
                tb0 = time.perf_counter()
                r = rng.random()
                acc = 0.0
                kind = "read"
                for k, p in mix.items():
                    acc += p
                    if r <= acc:
                        kind = k
                        break
                if kind in ("read", "rmw"):
                    idx = zipf_indices(batch, n_load, seed=done)
                    q = jnp.asarray(keys[idx], KD)
                    out = sys_.get(q)
                    jax.block_until_ready(out)
                    if kind == "rmw":
                        sys_.put(q, jnp.arange(batch, dtype=jnp.int32))
                elif kind == "update":
                    idx = zipf_indices(batch, n_load, seed=done + 1)
                    sys_.put(jnp.asarray(keys[idx], KD),
                             jnp.arange(batch, dtype=jnp.int32))
                    sys_.apply_async()
                elif kind == "insert":
                    nk = jnp.asarray(
                        uniform_keys(batch, seed=done + 2) + insert_base, KD)
                    sys_.put(nk, jnp.arange(batch, dtype=jnp.int32))
                    sys_.apply_async()
                elif kind == "scan":
                    lo = jnp.asarray(int(keys[done % n_load]), KD)
                    out = sys_.scan(lo, jnp.asarray(1 << 30, KD), 100)
                    jax.block_until_ready(out)
                hist.record(time.perf_counter() - tb0)
                done += batch
            dt = time.perf_counter() - t0
            results[(sys_.name, wl)] = n_ops / dt
            report(f"fig12_run_{wl}_{sys_.name}", non_gating=True,
                   seconds=round(dt, 4), ops_per_s=round(n_ops / dt, 1),
                   **percentile_fields(hist.snapshot(), per_op=batch))
    for wl in WORKLOADS:
        base = results[("all-skiplist", wl)]
        for name in ("histore", "all-hashtable", "all-skiplist"):
            v = results[(name, wl)]
            report(f"fig12_ycsb_{wl}_{name}", ops_per_s=round(v, 1),
                   normalized=round(v / base, 2) if base == base else "nan")
