"""Fig. 13 + 14 reproduction: recovery time (rebuild hash from sorted /
sorted from hash) vs data amount, and degraded performance under primary /
backup failure (normalised to healthy HiStore).

Four modes: the single-group mode times the index-group rebuild
primitives; the distributed mode (needs >= 3 devices, e.g.
``XLA_FLAGS=--xla_force_host_platform_device_count=8 python -m
benchmarks.run fig13``) times the full kvstore kill/recover protocol —
wipe-on-fail, hash-from-replica rebuild, replica re-clone — plus degraded
GET latency through the client; the value-migration mode times the data
plane: degraded-GET latency while values are stranded off-home (2-hop,
``GetResult.hops == 2``) vs post-migration latency (1-hop), the
migration pass itself, and GC slot-reuse throughput (put+delete churn
past the shard capacity that the seed's ring cursor could not survive);
the DETECTION mode (``--detection``) times the availability control
plane — lease-expiry detection latency after a severed heartbeat (rounds
+ wall time, no oracle fail_server anywhere) and online snapshot
recovery (return-to-service latency with the log delta still streaming)
vs the stop-the-world drain-first rebuild.

Standalone for CI smoke runs (tools/ci.sh --bench-smoke):

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
      python -m benchmarks.fig13_recovery --smoke --json out.json
    XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
      python -m benchmarks.fig13_recovery --detection --smoke --json out.json
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import (CFG, KD, percentile_fields, stamped,
                               timeit, timeit_hist, uniform_keys)
from repro.core import index_group as ig
from repro.core import kvstore as kv
from repro.core.client import (DistributedBackend, HiStoreClient,
                               LocalBackend)


def run(report, batch=4096):
    report = stamped(report, CFG)
    for n in [50_000, 200_000]:
        keys = uniform_keys(n, seed=31)
        addrs = np.arange(n, dtype=np.int32)
        g = ig.create(n * 4, CFG)
        for i in range(0, n, 16384):
            g, _ = ig.put(g, jnp.asarray(keys[i:i + 16384], KD),
                          jnp.asarray(addrs[i:i + 16384]), CFG)
            g = ig.drain(g, CFG)

        gp = ig.fail(g, 0)
        t_hash, _ = timeit(lambda: ig.recover_primary(gp, CFG),
                           warmup=1, iters=2)
        gb = ig.fail(g, 1)
        t_sorted, _ = timeit(lambda: ig.recover_backup(gb, 0, CFG),
                             warmup=1, iters=2)
        report("fig13_recover_primary_hash", n=n, seconds=round(t_hash, 4))
        report("fig13_recover_backup_sorted", n=n,
               seconds=round(t_sorted, 4))

        # Fig 14: degraded performance
        q = jnp.asarray(keys[:batch], KD)
        nk = jnp.asarray(uniform_keys(batch, seed=33) + (1 << 29), KD)
        na = jnp.arange(batch, dtype=jnp.int32)
        t_get, _ = timeit(lambda: ig.get(g, q, CFG, primary_alive=True),
                          iters=3)
        t_put, _ = timeit(lambda: ig.put(g, nk, na, CFG,
                                         backups_alive=(True, True)), iters=3)
        t_get_pf, _ = timeit(lambda: ig.get(gp, q, CFG, primary_alive=False),
                             iters=3)
        t_put_bf, _ = timeit(lambda: ig.put(gb, nk, na, CFG,
                                            backups_alive=(False, True)),
                             iters=3)
        lo = jnp.asarray(int(np.median(keys)), KD)
        hi = jnp.asarray(1 << 30, KD)
        t_scan, _ = timeit(lambda: ig.scan(g, lo, hi, 100, CFG),
                           warmup=1, iters=2)
        t_scan_bf, _ = timeit(lambda: ig.scan(gb, lo, hi, 100, CFG),
                              warmup=1, iters=2)
        report("fig14_get_primary_fail", n=n,
               normalized=round(t_get / t_get_pf, 3))
        report("fig14_put_backup_fail", n=n,
               normalized=round(t_put / t_put_bf, 3))
        report("fig14_scan_backup_fail", n=n,
               normalized=round(t_scan / t_scan_bf, 3))

    run_distributed(report)


def run_distributed(report, n=20_000):
    """Distributed kill/recover protocol timings (kvstore layer)."""
    report = stamped(report, CFG)
    G = len(jax.devices())
    if G < 3:
        report("fig13_dist_recovery", skipped=f"needs >=3 devices, have {G}")
        return
    from repro.configs.histore import scaled
    cfg = scaled(log_capacity=1 << 14, async_apply_batch=4096)
    mesh = jax.make_mesh((G,), (kv.AXIS,))
    backend = DistributedBackend(mesh, cfg, max(4096, 4 * n // G),
                                 capacity_q=256)
    client = HiStoreClient(backend, batch_quantum=64 * G)
    keys = uniform_keys(n, seed=37, space=10 ** 8)
    assert client.put(keys, np.arange(n)).all_ok
    client.drain()

    probe = keys[: 8 * G]
    t_get, _ = timeit(lambda: client.backend.get(
        jnp.asarray(probe, KD), jnp.ones((len(probe),), bool)), iters=3)
    failed = kv.fail_server(backend.store, 1)
    t_rec, recovered = timeit(
        lambda: kv.recover_server(failed, 1, cfg), warmup=1, iters=2)
    assert all(p["agree"] for p in kv.parity_report(recovered, cfg))
    backend.store = failed
    t_get_pf, _ = timeit(lambda: client.backend.get(
        jnp.asarray(probe, KD), jnp.ones((len(probe),), bool)), iters=3)
    backend.store = recovered
    report("fig13_dist_recover_server", n=n, devices=G,
           seconds=round(t_rec, 4))
    report("fig14_dist_get_primary_fail", n=n, devices=G,
           normalized=round(t_get / t_get_pf, 3))

    run_value_migration(report, n=n)


def run_value_migration(report, n=20_000):
    """Value-plane timings: degraded-GET (2-hop fetch) vs post-migration
    (1-hop) latency, the background migration pass, and GC slot-reuse
    throughput."""
    report = stamped(report, CFG)
    G = len(jax.devices())
    if G < 3:
        report("fig13_value_migration",
               skipped=f"needs >=3 devices, have {G}")
        _gc_slot_reuse(report)
        return
    from repro.configs.histore import scaled
    cfg = scaled(log_capacity=1 << 14, async_apply_batch=4096)
    mesh = jax.make_mesh((G,), (kv.AXIS,))
    backend = DistributedBackend(mesh, cfg, max(4096, 4 * n // G),
                                 capacity_q=256)
    # knob off: measure the 2-hop phase migration normally elides
    client = HiStoreClient(backend, batch_quantum=64 * G,
                           migrate_on_recover=False)
    keys = uniform_keys(n, seed=41, space=10 ** 8)
    assert client.put(keys, np.arange(n)).all_ok
    client.drain()
    dead = 1
    own = np.asarray(kv.owner_group(jnp.asarray(keys, KD), G))
    dk = keys[own == dead]
    client.fail_server(dead)
    # degraded overwrites strand the values on the temporary primary
    assert client.put(dk, np.arange(len(dk)) + 1).all_ok
    client.recover_server(dead)
    probe = dk[: min(len(dk), 16 * G)]
    h2, r2 = timeit_hist(lambda: client.get(probe), iters=3)
    t2 = h2.mean
    hops2 = float(np.asarray(r2.hops).mean())
    t0 = time.perf_counter()
    moved = client.migrate()
    t_mig = time.perf_counter() - t0
    h1, r1 = timeit_hist(lambda: client.get(probe), iters=3)
    t1 = h1.mean
    hops1 = float(np.asarray(r1.hops).mean())
    report("fig13_degraded_get_second_hop", n=n, devices=G,
           us_per_op=t2 / len(probe) * 1e6, mean_hops=round(hops2, 3),
           **percentile_fields(h2, per_op=len(probe)))
    report("fig13_post_migration_get", n=n, devices=G,
           us_per_op=t1 / len(probe) * 1e6, mean_hops=round(hops1, 3),
           one_rtt=bool(r1.one_rtt),
           **percentile_fields(h1, per_op=len(probe)))
    report("fig13_value_migration", n=n, devices=G, moved=moved,
           seconds=round(t_mig, 4),
           speedup_2hop_vs_1hop=round(t2 / t1, 3))
    _gc_slot_reuse(report)


def _gc_slot_reuse(report, capacity=2048, batch=512, cycles=10):
    """Allocator throughput under churn: put+delete cycles whose
    cumulative allocations exceed the shard capacity several times over —
    the workload the seed's monotone ring cursor wrap-corrupted on."""
    from repro.configs.histore import scaled
    cfg = scaled(log_capacity=1 << 14, async_apply_batch=4096)
    client = HiStoreClient(LocalBackend(capacity, cfg), batch_quantum=batch)
    warm = uniform_keys(batch, seed=43)
    client.put(warm, np.arange(batch))
    client.delete(warm)
    t0 = time.perf_counter()
    for i in range(cycles):
        kk = uniform_keys(batch, seed=100 + i)
        assert client.put(kk, np.arange(batch)).all_ok
        assert bool(client.delete(kk).ok.all())
    dt = time.perf_counter() - t0
    report("fig13_gc_slot_reuse", capacity=capacity,
           cumulative_allocs=(cycles + 1) * batch,
           us_per_op=dt / (2 * cycles * batch) * 1e6,
           ops_per_sec=int(2 * cycles * batch / dt))


def run_detection(report, n=8_000):
    """Availability control plane timings: lease-expiry detection latency
    (observation rounds + wall time from severed heartbeat to degraded
    routing, zero oracle fail_server calls), the same for DATA servers
    (plus mirror-served GET latency through the undetected window),
    idle-client wall-clock detection via the background ticker, and
    online-vs-stop-the-world recovery — return-to-service latency of the
    snapshot clone with the log delta still streaming vs the drain-first
    rebuild of the same backlog."""
    report = stamped(report, CFG)
    G = len(jax.devices())
    if G < 3:
        report("fig13_detection", skipped=f"needs >=3 devices, have {G}")
        return
    from repro.configs.histore import scaled
    # rounds clock: the detection rows COUNT observation rounds; the
    # wall-clock path is timed separately below with its own config
    cfg = scaled(log_capacity=1 << 14, async_apply_batch=256,
                 lease_misses=3, lease_clock="rounds")
    mesh = jax.make_mesh((G,), (kv.AXIS,))
    keys = uniform_keys(n, seed=47, space=10 ** 8)
    own = np.asarray(kv.owner_group(jnp.asarray(keys, KD), G))
    dead = 1
    probe = keys[own != dead][: 8 * G]

    def fresh_client(ccfg=cfg):
        backend = DistributedBackend(mesh, ccfg, max(4096, 4 * n // G),
                                     capacity_q=256)
        client = HiStoreClient(backend, batch_quantum=64 * G,
                               migrate_on_recover=False)
        assert client.put(keys, np.arange(n)).all_ok
        client.drain()
        return client

    # --- detection latency (lease expiry, no oracle call) ---------------
    client = fresh_client()
    backend = client.backend
    client.get(probe)                       # warm the compiled get+tick
    backend.sever_server(dead)
    rounds = 0
    t0 = time.perf_counter()
    while dead not in backend._dead:
        client.get(probe)
        rounds += 1
        assert rounds <= 10 * cfg.lease_misses, "detector must fire"
    t_detect = time.perf_counter() - t0
    report("fig13_detection_latency", n=n, devices=G,
           lease_misses=cfg.lease_misses, rounds=rounds,
           seconds=round(t_detect, 4), detected=True)
    # --- data-server lease detection + mirror-served GETs ---------------
    # the unified plane: a data-server kill through cut heartbeats —
    # GETs of its shard are mirror-served (second-hop fetch) through the
    # undetected window, the data lease expires in observation rounds,
    # recovery + migration restore one-RTT reads
    client = fresh_client()
    backend = client.backend
    client.get(probe)                       # warm the compiled get+tick
    backend.sever_data_server(dead)
    rounds = 0
    t0 = time.perf_counter()
    while dead not in backend._data_dead:
        client.get(probe)
        rounds += 1
        assert rounds <= 10 * cfg.lease_misses, "data detector must fire"
    t_detect = time.perf_counter() - t0
    report("fig13_data_detection_latency", n=n, devices=G,
           lease_misses=cfg.lease_misses, rounds=rounds,
           seconds=round(t_detect, 4), detected=True)
    dk = keys[own == dead][: 8 * G]
    h2, r2 = timeit_hist(lambda: client.get(dk), iters=3)
    report("fig13_mirror_served_get", n=n, devices=G,
           us_per_op=h2.mean / max(len(dk), 1) * 1e6,
           mean_hops=round(float(np.asarray(r2.hops).mean()), 3),
           served_under_data_failure=bool(r2.all_found),
           **percentile_fields(h2, per_op=max(len(dk), 1)))
    backend.recover_data_server(dead)
    moved = client.migrate()
    t1, r1 = timeit(lambda: client.get(dk), iters=3)
    report("fig13_post_data_recovery_get", n=n, devices=G, moved=moved,
           us_per_op=t1 / max(len(dk), 1) * 1e6, one_rtt=bool(r1.one_rtt))
    # --- wall-clock idle detection (background ticker only) -------------
    wcfg = scaled(log_capacity=1 << 14, async_apply_batch=256,
                  lease_misses=3, lease_clock="wall",
                  lease_timeout_s=0.5, lease_interval_s=0.1)
    client = fresh_client(wcfg)
    backend = client.backend
    backend._lease_tick(bump=True)          # compile the tick op
    client.start_ticker()
    try:
        backend.sever_server(dead)
        t0 = time.perf_counter()
        while dead not in backend._dead:
            time.sleep(0.01)
            assert time.perf_counter() - t0 < 30, "idle detector must fire"
        t_idle = time.perf_counter() - t0
    finally:
        client.stop_ticker()
    report("fig13_wall_idle_detection", n=n, devices=G,
           lease_timeout_s=wcfg.lease_timeout_s,
           lease_interval_s=wcfg.lease_interval_s,
           seconds=round(t_idle, 4), detected_idle=True)
    # --- online catch-up vs stop-the-world recovery ---------------------
    # metric: RETURN-TO-SERVICE latency of the rebuild itself — the
    # online mode hands the backlog to the incremental apply stream
    # (measured separately as stream_seconds), the stop-the-world mode
    # drains it inside the rebuild.  The post-recovery re-replication
    # verify is common to both policies, so it is timed once on its own
    # row; one unmeasured warm-up cycle per variant keeps one-time jit
    # compilation out of the comparison.
    live = keys[own != dead]

    def cycle(online):
        client = fresh_client()
        backend = client.backend
        backend.sever_server(dead)
        waited = 0
        while dead not in backend._dead:
            client.get(probe)
            waited += 1
            assert waited <= 10 * cfg.lease_misses, "detector must fire"
        # degraded-window writes build the backlog recovery must stream
        assert client.put(live, np.arange(len(live)) + 5).all_ok
        t0 = time.perf_counter()
        rec = backend.recover_server(dead, online=online,
                                     re_replicate=False)
        t_rec = time.perf_counter() - t0
        t0 = time.perf_counter()
        backend.store, n_reb = kv.re_replicate(backend.store, cfg)
        t_rerep = time.perf_counter() - t0
        t0 = time.perf_counter()
        client.drain()                     # the streamed catch-up itself
        t_stream = time.perf_counter() - t0
        assert all(p["agree"]
                   for p in kv.parity_report(backend.store, cfg))
        return t_rec, t_stream, t_rerep, rec

    for online in (True, False):
        cycle(online)                      # warm-up (compile)
    t_online, t_stream, t_rerep, rec = cycle(True)
    t_stw, _, _, _ = cycle(False)
    report("fig13_recover_online", n=n, devices=G,
           seconds=round(t_online, 4),
           catch_up_pending=int(rec.catch_up_pending),
           stream_seconds=round(t_stream, 4))
    report("fig13_recover_stop_the_world", n=n, devices=G,
           seconds=round(t_stw, 4),
           online_speedup=round(t_stw / max(t_online, 1e-9), 3))
    report("fig13_re_replication_pass", n=n, devices=G,
           seconds=round(t_rerep, 4))


def main(argv=None) -> int:
    """Standalone entry (CI bench smoke): run the distributed recovery +
    value-migration benches (or, with --detection, the availability
    control-plane benches) for a few steps and dump JSON."""
    import argparse
    import json

    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default=None,
                    help="write collected rows as JSON")
    ap.add_argument("--smoke", action="store_true",
                    help="distributed-mode only, small n (CI tier)")
    ap.add_argument("--detection", action="store_true",
                    help="detection-latency + catch-up-vs-stop-the-world "
                         "timing mode")
    args = ap.parse_args(argv)
    rows = []

    def report(name, **kw):
        rows.append({"name": name, **kw})
        print(name, kw, flush=True)

    if args.detection:
        run_detection(report, n=2_000 if args.smoke else 8_000)
    elif args.smoke:
        run_distributed(report, n=4_000)
    else:
        run(report)
    if args.json:
        with open(args.json, "w") as f:
            json.dump(rows, f, indent=2, default=str)
        print(f"wrote {args.json} ({len(rows)} rows)", flush=True)
    return 0


if __name__ == "__main__":
    import sys

    sys.exit(main(sys.argv[1:]))
