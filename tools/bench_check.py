#!/usr/bin/env python
"""Bench-regression gate: compare a fresh bench-smoke JSON against a
committed baseline, or scan a rolling history for monotone drift.

    python tools/bench_check.py NEW.json BASELINE.json [--rtol 0.25]
    python tools/bench_check.py --trend HISTORY_DIR [--window 20]
                                [--trend-out bench_trend.json]

Both files are lists of row dicts as written by
``benchmarks/fig13_recovery.py --json`` (each row: {"name": ..., metric
fields...}).  The gate fails (exit 1) on:

  * **latency regression** — a latency-like field (``seconds``,
    ``us_per_op``, ``stream_seconds``) grew past
    ``baseline * (1 + rtol)`` AND past ``baseline + atol`` (the absolute
    slack absorbs scheduler noise on near-zero timings; the relative
    threshold is the paper-facing contract: >25% slower fails);
  * **lost capability** — a boolean field that is True in the baseline
    (e.g. ``one_rtt``, ``detected``) is False or missing in the new run,
    or a baseline row is missing / newly ``skipped`` entirely.

Speedups, extra rows and extra fields never fail the gate.  Rows pair by
``name`` (duplicate names pair in file order).  Rows flagged
``non_gating: true`` (single-pass phase timings, e.g. the fig12
load/run split) are skipped entirely.  Paired rows whose
measurement-environment stamps differ (FLAG_FIELDS: ``use_kernels``,
``platform`` — benchmarks/common.py env_fields) are skipped as a
configuration mismatch, never judged as a regression or lost
capability.  ``--rtol`` can also come from
the BENCH_CHECK_RTOL env var (CI escape hatch for slow runners);
explicit flags win.

**Trend mode** (``--trend DIR``) reads the newest ``--window`` JSON
files in DIR (sorted by filename — CI stamps them with a UTC
timestamp), and fails on *monotone creep*: a latency series that rises
at every step (within 5% per-step noise) and whose total growth clears
the same rtol+atol bar as the baseline gate.  This catches the 3×8%
death-by-a-thousand-cuts drift the single-baseline 25% threshold never
sees.  Results (pass or fail) are written to ``--trend-out`` for CI
artifact upload.  Fewer than 3 history files always passes.

No third-party imports: the unit tests (tests/test_bench_check.py) and
the fast CI tier run this without jax.
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import sys

LATENCY_FIELDS = ("seconds", "us_per_op", "stream_seconds")
# absolute slack per latency field: sub-atol timings are noise-dominated
# (a 0.01s -> 0.02s "2x regression" is scheduler jitter, not a finding)
DEFAULT_ATOL = {"seconds": 0.5, "us_per_op": 150.0, "stream_seconds": 0.5}
# rows whose wall time is a fixed lease timeout plus thread-scheduling
# latency, not a code-speed measurement: a loaded runner descheduling
# the ticker for seconds is within the batteries' own accepted envelope,
# so only their capability flags gate (detected_idle), never the timing
UNGATED_LATENCY_ROWS = {"fig13_wall_idle_detection"}
# measurement-environment stamps (benchmarks/common.py env_fields): when
# BOTH paired rows carry one of these and the values differ, the pair is
# a configuration mismatch (e.g. a kernel-path run vs a jnp-path
# baseline) and is SKIPPED, not judged — neither regression nor lost
# capability.  A row missing the stamp gates as before (old baselines
# stay valid).
FLAG_FIELDS = ("use_kernels", "platform")


def _flag_mismatch(new: dict, base: dict):
    """The first env-stamp field present in both rows with differing
    values, or None when the rows are comparable."""
    for f in FLAG_FIELDS:
        if f in new and f in base and new[f] != base[f]:
            return f
    return None


def _rows_by_name(rows: list) -> dict:
    out: dict = {}
    for row in rows:
        out.setdefault(str(row.get("name")), []).append(row)
    return out


def compare(new_rows: list, base_rows: list, rtol: float,
            atol: dict = DEFAULT_ATOL) -> list:
    """Return the list of failure strings (empty == gate passes)."""
    failures = []
    new_by_name = _rows_by_name(new_rows)
    for name, brows in _rows_by_name(base_rows).items():
        nrows = new_by_name.get(name, [])
        for i, base in enumerate(brows):
            if base.get("non_gating"):
                continue
            if i >= len(nrows):
                failures.append(f"{name}: row missing from the new run "
                                "(lost capability)")
                continue
            new = nrows[i]
            flag = _flag_mismatch(new, base)
            if flag is not None:
                print(f"bench-check: {name}: {flag} differs "
                      f"({new.get(flag)!r} vs baseline "
                      f"{base.get(flag)!r}) — row skipped, not compared")
                continue
            if "skipped" in new and "skipped" not in base:
                failures.append(f"{name}: newly skipped "
                                f"({new['skipped']}) — lost capability")
                continue
            for f in LATENCY_FIELDS:
                if name in UNGATED_LATENCY_ROWS:
                    break
                if f not in base or f not in new:
                    continue
                b, n = float(base[f]), float(new[f])
                if n > b * (1.0 + rtol) and n > b + atol.get(f, 0.0):
                    # a 0.0 baseline (timing rounded to nothing) still
                    # gates through the absolute slack; report without
                    # the undefined relative blow-up
                    pct = (f"+{(n / b - 1) * 100:.0f}%" if b > 0
                           else "from a 0 baseline")
                    failures.append(
                        f"{name}.{f}: {n:.6g} vs baseline {b:.6g} "
                        f"({pct} > {rtol * 100:.0f}% regression gate)")
            for f, bv in base.items():
                if bv is True and new.get(f) is not True:
                    failures.append(
                        f"{name}.{f}: capability flag lost "
                        f"(baseline True, new {new.get(f)!r})")
    return failures


# per-step tolerance for calling a series "monotone": a step may dip up
# to this fraction and the creep still counts as steady upward drift
TREND_STEP_NOISE = 0.05


def trend(histories: list, rtol: float,
          atol: dict = DEFAULT_ATOL) -> tuple:
    """Scan a chronological list of bench-JSON row lists for monotone
    latency creep.  Returns (failures, series) where series maps
    "name.field" -> the list of values examined (for bench_trend.json).
    A series fails when it has >= 3 points, never drops more than
    TREND_STEP_NOISE per step, and its total growth clears the same
    rtol+atol bar as the baseline gate."""
    failures, series = [], {}
    if len(histories) < 3:
        return failures, series
    # collect per-(name, field) chronological series; rows pair by name
    # + duplicate index as in compare()
    values: dict = {}
    for rows in histories:
        for name, nrows in _rows_by_name(rows).items():
            if name in UNGATED_LATENCY_ROWS:
                continue
            for i, row in enumerate(nrows):
                if row.get("non_gating"):
                    continue
                # env-stamped rows form per-stamp series: a history that
                # alternates jnp and kernel runs must not read as creep
                flags = tuple((k, str(row[k])) for k in FLAG_FIELDS
                              if k in row)
                for f in LATENCY_FIELDS:
                    if f in row:
                        values.setdefault((name, i, f, flags), []).append(
                            float(row[f]))
    for (name, i, f, flags), vs in sorted(values.items()):
        label = f"{name}.{f}" if i == 0 else f"{name}[{i}].{f}"
        if flags:
            label += "{" + ",".join(f"{k}={v}" for k, v in flags) + "}"
        series[label] = vs
        if len(vs) < 3:
            continue        # row too new to have a trend
        creeping = all(vs[j + 1] >= vs[j] * (1.0 - TREND_STEP_NOISE)
                       for j in range(len(vs) - 1))
        first, last = vs[0], vs[-1]
        if (creeping and last > first * (1.0 + rtol)
                and last > first + atol.get(f, 0.0)):
            pct = (f"+{(last / first - 1) * 100:.0f}%" if first > 0
                   else "from a 0 start")
            failures.append(
                f"{label}: monotone creep over {len(vs)} runs — "
                f"{first:.6g} -> {last:.6g} ({pct} > "
                f"{rtol * 100:.0f}% trend gate)")
    return failures, series


def run_trend(history_dir: str, window: int, rtol: float,
              out_path: str) -> int:
    paths = sorted(glob.glob(os.path.join(history_dir, "*.json")))
    paths = paths[-window:]
    histories = []
    for p in paths:
        try:
            with open(p) as f:
                histories.append(json.load(f))
        except (OSError, ValueError) as e:
            print(f"bench-trend: skipping unreadable {p}: {e}",
                  file=sys.stderr)
    failures, series = trend(histories, rtol)
    report = {"history_dir": history_dir, "window": window,
              "files": [os.path.basename(p) for p in paths],
              "rtol": rtol, "failures": failures,
              "series": {k: v for k, v in sorted(series.items())}}
    if out_path:
        with open(out_path, "w") as f:
            json.dump(report, f, indent=2)
    if failures:
        print(f"BENCH-TREND FAILED ({len(histories)} runs from "
              f"{history_dir}, rtol={rtol}):", file=sys.stderr)
        for msg in failures:
            print(f"  - {msg}", file=sys.stderr)
        return 1
    print(f"bench-trend OK: no monotone creep across {len(histories)} "
          f"runs ({len(series)} series examined)")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="fail on bench regressions vs a committed baseline, "
                    "or on monotone drift across a run history")
    ap.add_argument("new", nargs="?", help="fresh bench-smoke JSON")
    ap.add_argument("baseline", nargs="?",
                    help="committed BENCH_baseline_*.json")
    ap.add_argument("--rtol", type=float,
                    default=float(os.environ.get("BENCH_CHECK_RTOL",
                                                 0.25)),
                    help="relative latency-regression threshold "
                         "(default 0.25 = fail on >25%% slower)")
    ap.add_argument("--trend", metavar="DIR", default=None,
                    help="trend mode: scan the newest bench JSONs in DIR "
                         "for monotone latency creep")
    ap.add_argument("--window", type=int, default=20,
                    help="trend mode: how many newest history files to "
                         "examine (default 20)")
    ap.add_argument("--trend-out", default="bench_trend.json",
                    help="trend mode: write the examined series + "
                         "verdict here (default bench_trend.json)")
    args = ap.parse_args(argv)
    if args.trend:
        return run_trend(args.trend, args.window, args.rtol,
                         args.trend_out)
    if not args.new or not args.baseline:
        ap.error("NEW and BASELINE are required outside --trend mode")
    with open(args.new) as f:
        new_rows = json.load(f)
    with open(args.baseline) as f:
        base_rows = json.load(f)
    failures = compare(new_rows, base_rows, args.rtol)
    if failures:
        print(f"BENCH-CHECK FAILED ({args.new} vs {args.baseline}, "
              f"rtol={args.rtol}):", file=sys.stderr)
        for msg in failures:
            print(f"  - {msg}", file=sys.stderr)
        return 1
    print(f"bench-check OK: {args.new} within {args.rtol * 100:.0f}% of "
          f"{args.baseline} ({len(base_rows)} baseline rows, no lost "
          "capabilities)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
