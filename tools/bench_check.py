#!/usr/bin/env python
"""Bench-regression gate: compare a fresh bench-smoke JSON against a
committed baseline.

    python tools/bench_check.py NEW.json BASELINE.json [--rtol 0.25]

Both files are lists of row dicts as written by
``benchmarks/fig13_recovery.py --json`` (each row: {"name": ..., metric
fields...}).  The gate fails (exit 1) on:

  * **latency regression** — a latency-like field (``seconds``,
    ``us_per_op``, ``stream_seconds``) grew past
    ``baseline * (1 + rtol)`` AND past ``baseline + atol`` (the absolute
    slack absorbs scheduler noise on near-zero timings; the relative
    threshold is the paper-facing contract: >25% slower fails);
  * **lost capability** — a boolean field that is True in the baseline
    (e.g. ``one_rtt``, ``detected``) is False or missing in the new run,
    or a baseline row is missing / newly ``skipped`` entirely.

Speedups, extra rows and extra fields never fail the gate.  Rows pair by
``name`` (duplicate names pair in file order).  ``--rtol`` can also come
from the BENCH_CHECK_RTOL env var (CI escape hatch for slow runners);
explicit flags win.

No third-party imports: the unit tests (tests/test_bench_check.py) and
the fast CI tier run this without jax.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

LATENCY_FIELDS = ("seconds", "us_per_op", "stream_seconds")
# absolute slack per latency field: sub-atol timings are noise-dominated
# (a 0.01s -> 0.02s "2x regression" is scheduler jitter, not a finding)
DEFAULT_ATOL = {"seconds": 0.5, "us_per_op": 150.0, "stream_seconds": 0.5}
# rows whose wall time is a fixed lease timeout plus thread-scheduling
# latency, not a code-speed measurement: a loaded runner descheduling
# the ticker for seconds is within the batteries' own accepted envelope,
# so only their capability flags gate (detected_idle), never the timing
UNGATED_LATENCY_ROWS = {"fig13_wall_idle_detection"}


def _rows_by_name(rows: list) -> dict:
    out: dict = {}
    for row in rows:
        out.setdefault(str(row.get("name")), []).append(row)
    return out


def compare(new_rows: list, base_rows: list, rtol: float,
            atol: dict = DEFAULT_ATOL) -> list:
    """Return the list of failure strings (empty == gate passes)."""
    failures = []
    new_by_name = _rows_by_name(new_rows)
    for name, brows in _rows_by_name(base_rows).items():
        nrows = new_by_name.get(name, [])
        for i, base in enumerate(brows):
            if i >= len(nrows):
                failures.append(f"{name}: row missing from the new run "
                                "(lost capability)")
                continue
            new = nrows[i]
            if "skipped" in new and "skipped" not in base:
                failures.append(f"{name}: newly skipped "
                                f"({new['skipped']}) — lost capability")
                continue
            for f in LATENCY_FIELDS:
                if name in UNGATED_LATENCY_ROWS:
                    break
                if f not in base or f not in new:
                    continue
                b, n = float(base[f]), float(new[f])
                if n > b * (1.0 + rtol) and n > b + atol.get(f, 0.0):
                    # a 0.0 baseline (timing rounded to nothing) still
                    # gates through the absolute slack; report without
                    # the undefined relative blow-up
                    pct = (f"+{(n / b - 1) * 100:.0f}%" if b > 0
                           else "from a 0 baseline")
                    failures.append(
                        f"{name}.{f}: {n:.6g} vs baseline {b:.6g} "
                        f"({pct} > {rtol * 100:.0f}% regression gate)")
            for f, bv in base.items():
                if bv is True and new.get(f) is not True:
                    failures.append(
                        f"{name}.{f}: capability flag lost "
                        f"(baseline True, new {new.get(f)!r})")
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="fail on bench regressions vs a committed baseline")
    ap.add_argument("new", help="fresh bench-smoke JSON")
    ap.add_argument("baseline", help="committed BENCH_baseline_*.json")
    ap.add_argument("--rtol", type=float,
                    default=float(os.environ.get("BENCH_CHECK_RTOL",
                                                 0.25)),
                    help="relative latency-regression threshold "
                         "(default 0.25 = fail on >25%% slower)")
    args = ap.parse_args(argv)
    with open(args.new) as f:
        new_rows = json.load(f)
    with open(args.baseline) as f:
        base_rows = json.load(f)
    failures = compare(new_rows, base_rows, args.rtol)
    if failures:
        print(f"BENCH-CHECK FAILED ({args.new} vs {args.baseline}, "
              f"rtol={args.rtol}):", file=sys.stderr)
        for msg in failures:
            print(f"  - {msg}", file=sys.stderr)
        return 1
    print(f"bench-check OK: {args.new} within {args.rtol * 100:.0f}% of "
          f"{args.baseline} ({len(base_rows)} baseline rows, no lost "
          "capabilities)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
