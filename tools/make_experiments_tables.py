"""Assemble the EXPERIMENTS.md tables from results/*.json artifacts."""
import glob
import json
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]


def load(d):
    out = {}
    for f in sorted(glob.glob(str(ROOT / d / "*.json"))):
        r = json.load(open(f))
        out[(r["arch"], r["shape"], Path(f).stem.split("__")[-1])] = r
    return out


def fmt_cell(r):
    t = r["roofline"]
    return (f"{t['compute_s']:.3f} | {t['memory_s']:.3f} | "
            f"{t['collective_s']:.3f} | {t['dominant'].replace('_s','')} | "
            f"{r.get('useful_flops_ratio', 0):.2f} | "
            f"{r['memory']['peak_estimate_bytes']/1e9:.0f}")


def main():
    base = load("results/dryrun")
    mp = load("results/dryrun_multipod")
    perf = load("results/perf")

    print("## table:roofline")
    print("| arch | shape | compute s | memory s | collective s | dominant "
          "| useful-flops | HBM GB/dev | multi-pod |")
    print("|---|---|---|---|---|---|---|---|---|")
    for (a, s, m), r in sorted(base.items()):
        mpr = mp.get((a, s, "2x16x16"), {})
        mps = {"ok": "ok", "skipped": "skip"}.get(mpr.get("status"), "?")
        if r["status"] == "skipped":
            print(f"| {a} | {s} | — | — | — | skipped (full attention) "
                  f"| — | — | {mps} |")
            continue
        print(f"| {a} | {s} | {fmt_cell(r)} | {mps} |")

    print()
    print("## table:opt")
    print("| arch | shape | variant | bound before s | bound after s | "
          "speedup | dominant after |")
    print("|---|---|---|---|---|---|---|")
    for (a, s, tag), r in sorted(perf.items()):
        if tag not in ("opt", "optstub") or r["status"] != "ok":
            continue
        b = base.get((a, s, "16x16"))
        if not b or b["status"] != "ok":
            continue
        tb = b["roofline"]
        ta = r["roofline"]
        before = max(tb["compute_s"], tb["memory_s"], tb["collective_s"])
        after = max(ta["compute_s"], ta["memory_s"], ta["collective_s"])
        print(f"| {a} | {s} | {r.get('opts','')} | {before:.3f} | "
              f"{after:.3f} | {before/after:.1f}x | "
              f"{ta['dominant'].replace('_s','')} |")


if __name__ == "__main__":
    main()
