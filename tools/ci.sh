#!/usr/bin/env bash
# One-command verify.
#   bash tools/ci.sh                # fast tier: tests minus the slow markers
#   bash tools/ci.sh --all          # everything: full pytest + example smokes
#   bash tools/ci.sh --fast         # alias of the default (kept for muscle memory)
#   bash tools/ci.sh --bench-smoke  # fig13 recovery + value-migration bench,
#                                   # distributed mode, few steps; writes
#                                   # bench_smoke_fig13.json, then the
#                                   # --detection mode (lease detection
#                                   # latency + online-vs-stop-the-world
#                                   # recovery) into
#                                   # bench_smoke_fig13_detection.json,
#                                   # then the kernel-dispatch smokes
#                                   # (fig9 basic ops + fig11 breakdown,
#                                   # jnp-vs-kernel side-by-side incl.
#                                   # the fig9_kernel_get_gate
#                                   # kernel_no_slower capability row)
#                                   # into bench_smoke_fig9/11.json, and
#                                   # gates ALL against the committed
#                                   # BENCH_baseline_*.json via
#                                   # tools/bench_check.py (>25% latency
#                                   # regression or a lost capability flag
#                                   # fails; BENCH_CHECK_RTOL loosens the
#                                   # threshold for slow runners).  Both
#                                   # JSONs are then appended (UTC-stamped)
#                                   # to bench-history/ and the rolling
#                                   # window is scanned for monotone
#                                   # latency creep (bench_check --trend,
#                                   # writes bench_trend.json)
#
# The fast tier includes the lease-detector battery
# (tests/test_lease_detection.py spawns tests/lease_selftest.py on 8 host
# devices): failure detection is availability-critical, so it is
# deliberately NOT behind the slow marker.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

if [[ "${1:-}" == "--all" ]]; then
  echo "== tier-1: pytest (full) =="
  python -m pytest -q --durations=15
  echo "== smoke: examples/quickstart.py =="
  python examples/quickstart.py
  echo "== smoke: examples/histore_cluster.py (8 host devices) =="
  python examples/histore_cluster.py
elif [[ "${1:-}" == "--bench-smoke" ]]; then
  export XLA_FLAGS="--xla_force_host_platform_device_count=8${XLA_FLAGS:+ $XLA_FLAGS}"
  echo "XLA_FLAGS=${XLA_FLAGS}"
  # fail fast if the host mesh did not materialize: benching a 1-device
  # degenerate mesh would silently skip every distributed row and then
  # trip the gate's lost-capability check with a confusing message
  python - <<'PY'
import os, sys
import jax
n = len(jax.devices())
if n < 8:
    sys.exit(f"bench-smoke needs 8 host devices, got {n} "
             f"(XLA_FLAGS={os.environ.get('XLA_FLAGS')!r} not honored? "
             "a GPU/TPU jaxlib build ignores the host-platform flag)")
print(f"bench-smoke preflight: {n} host devices OK")
PY
  set -x
  python -m benchmarks.fig13_recovery --smoke --json bench_smoke_fig13.json
  python -m benchmarks.fig13_recovery --detection --smoke \
    --json bench_smoke_fig13_detection.json
  python -m benchmarks.fig9_basic_ops --smoke --json bench_smoke_fig9.json
  python -m benchmarks.fig11_breakdown --smoke --json bench_smoke_fig11.json
  python tools/bench_check.py bench_smoke_fig13.json \
    BENCH_baseline_fig13.json
  python tools/bench_check.py bench_smoke_fig13_detection.json \
    BENCH_baseline_fig13_detection.json
  python tools/bench_check.py bench_smoke_fig9.json \
    BENCH_baseline_fig9.json
  python tools/bench_check.py bench_smoke_fig11.json \
    BENCH_baseline_fig11.json
  # trend gate: append this run to the rolling history (the CI workflow
  # caches bench-history/ across runs), then scan the window for
  # monotone creep the single-baseline threshold cannot see
  stamp="$(date -u +%Y%m%dT%H%M%S)"
  mkdir -p bench-history
  cp bench_smoke_fig13.json "bench-history/${stamp}_fig13.json"
  cp bench_smoke_fig13_detection.json \
    "bench-history/${stamp}_fig13_detection.json"
  cp bench_smoke_fig9.json "bench-history/${stamp}_fig9.json"
  cp bench_smoke_fig11.json "bench-history/${stamp}_fig11.json"
  python tools/bench_check.py --trend bench-history \
    --trend-out bench_trend.json
  set +x
else
  echo "== tier-1: pytest (fast tier; --all for the multi-minute batteries) =="
  python -m pytest -q -m "not slow" --durations=15
fi

echo "CI OK"
