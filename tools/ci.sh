#!/usr/bin/env bash
# One-command verify.
#   bash tools/ci.sh                # fast tier: tests minus the slow markers
#   bash tools/ci.sh --all          # everything: full pytest + example smokes
#   bash tools/ci.sh --fast         # alias of the default (kept for muscle memory)
#   bash tools/ci.sh --bench-smoke  # fig13 recovery + value-migration bench,
#                                   # distributed mode, few steps; writes
#                                   # bench_smoke_fig13.json, then the
#                                   # --detection mode (lease detection
#                                   # latency + online-vs-stop-the-world
#                                   # recovery) into
#                                   # bench_smoke_fig13_detection.json
#                                   # (CI uploads both)
#
# The fast tier includes the lease-detector battery
# (tests/test_lease_detection.py spawns tests/lease_selftest.py on 8 host
# devices): failure detection is availability-critical, so it is
# deliberately NOT behind the slow marker.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

if [[ "${1:-}" == "--all" ]]; then
  echo "== tier-1: pytest (full) =="
  python -m pytest -q
  echo "== smoke: examples/quickstart.py =="
  python examples/quickstart.py
  echo "== smoke: examples/histore_cluster.py (8 host devices) =="
  python examples/histore_cluster.py
elif [[ "${1:-}" == "--bench-smoke" ]]; then
  echo "== bench smoke: fig13 distributed recovery + value migration (8 host devices) =="
  XLA_FLAGS="--xla_force_host_platform_device_count=8${XLA_FLAGS:+ $XLA_FLAGS}" \
    python -m benchmarks.fig13_recovery --smoke --json bench_smoke_fig13.json
  echo "== bench smoke: fig13 lease detection + online catch-up (8 host devices) =="
  XLA_FLAGS="--xla_force_host_platform_device_count=8${XLA_FLAGS:+ $XLA_FLAGS}" \
    python -m benchmarks.fig13_recovery --detection --smoke \
      --json bench_smoke_fig13_detection.json
else
  echo "== tier-1: pytest (fast tier; --all for the multi-minute batteries) =="
  python -m pytest -q -m "not slow"
fi

echo "CI OK"
