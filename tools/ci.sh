#!/usr/bin/env bash
# One-command verify: tier-1 tests + example smoke runs.
#   bash tools/ci.sh            # full
#   bash tools/ci.sh --fast    # tests only
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1: pytest =="
python -m pytest -q

if [[ "${1:-}" != "--fast" ]]; then
  echo "== smoke: examples/quickstart.py =="
  python examples/quickstart.py
  echo "== smoke: examples/histore_cluster.py (8 host devices) =="
  python examples/histore_cluster.py
fi

echo "CI OK"
