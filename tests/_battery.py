"""Shared runner for the multi-device subprocess batteries.

The dry-run rule keeps the pytest process single-device: every
multi-device selftest is a standalone script spawned with
``XLA_FLAGS=--xla_force_host_platform_device_count=8``.  This helper
centralizes the spawn AND tees the battery's stdout/stderr to
``test-logs/<name>.{out,err}`` so a CI failure can upload the full
transcript as an artifact (the in-process assertion message only keeps
the tail).
"""
from __future__ import annotations

import os
import subprocess
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]
LOG_DIR = ROOT / "test-logs"


def _as_text(buf) -> str:
    if buf is None:
        return ""
    return buf.decode(errors="replace") if isinstance(buf, bytes) else buf


def _persist(name: str, stdout, stderr) -> None:
    LOG_DIR.mkdir(exist_ok=True)
    (LOG_DIR / f"{name}.out").write_text(_as_text(stdout))
    (LOG_DIR / f"{name}.err").write_text(_as_text(stderr))


def run_battery(script, name: str, extra_pythonpath=(), timeout: int = 900,
                devices: int = 8) -> subprocess.CompletedProcess:
    """Spawn ``script`` on ``devices`` host devices, capture its output,
    and persist it under test-logs/ regardless of outcome — including a
    HUNG battery: on TimeoutExpired the partial transcript is written
    before the exception propagates (a deadlock is exactly the failure
    the forensics artifacts exist for)."""
    env = dict(
        os.environ,
        PYTHONPATH=os.pathsep.join(
            [str(ROOT / "src"), *map(str, extra_pythonpath)]),
        XLA_FLAGS=f"--xla_force_host_platform_device_count={devices}")
    try:
        proc = subprocess.run(
            [sys.executable, str(script)], env=env, capture_output=True,
            text=True, timeout=timeout)
    except subprocess.TimeoutExpired as e:
        _persist(name, e.stdout,
                 _as_text(e.stderr) + f"\n[run_battery: killed after "
                 f"{timeout}s timeout]\n")
        raise
    _persist(name, proc.stdout, proc.stderr)
    return proc
