import os
if "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
"""Distributed fault-injection differential battery (8 host devices).

Spawned as a subprocess by tests/test_fault_injection.py (the dry-run
rule: only multi-device entrypoints force a host device count).  For each
seeded workload mix, the same op trace + fault schedule is replayed
through HiStoreClient/DistributedBackend and the plain-Python oracle:

  healthy segment -> fail device d (index state WIPED; keys owned by
  group d enter the primary-dead phase, keys of groups d-1/d-2 the
  backup-dead phase) -> degraded segment -> recover (hash rebuilt from a
  sorted replica, replicas re-cloned) -> post-recovery segment

Every GET/SCAN/DELETE observation must match the fault-oblivious oracle
result-for-result, recovery must restore hash/sorted parity on the failed
shard, and writes during the failure must report reduced replication.
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent))
sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax
import numpy as np

from repro.configs.histore import scaled
from repro.core import kvstore as kv
from repro.core.client import DistributedBackend, HiStoreClient
from repro.core.hashing import key_dtype

from oracle import Oracle, assert_equivalent, gen_ops, replay, splice_faults

CFG = scaled(log_capacity=512, async_apply_batch=128)
N_EVENTS = 12


def run_mix(mesh, mix: str, seed: int, dead_dev: int) -> None:
    G = mesh.devices.size
    ops = gen_ops(seed, mix, n_events=N_EVENTS, batch=3 * G)
    trace = splice_faults(ops, [(N_EVENTS // 3, "fail", dead_dev),
                                (2 * N_EVENTS // 3, "recover", dead_dev)])
    client = HiStoreClient(
        DistributedBackend(mesh, CFG, 4096, capacity_q=64, scan_limit=128),
        batch_quantum=4 * G, max_retries=32)
    oracle = Oracle(value_words=CFG.value_words)
    assert_equivalent(replay(client, trace), replay(oracle, trace),
                      label=f"dist8/{mix}/seed{seed}")
    store = client.backend.store
    assert all(p["agree"] for p in kv.parity_report(store, CFG)), \
        f"{mix}: recovery must restore hash/sorted parity"

    # reduced replication is reported honestly while a holder is dead
    client.fail_server(dead_dev)
    wk = np.random.RandomState(seed + 999).choice(
        10 ** 6, 8 * G, replace=False) + 7 * 10 ** 7
    w = client.put(wk, np.arange(8 * G))
    assert w.all_ok
    own = np.asarray(kv.owner_group(jax.numpy.asarray(wk, key_dtype()), G))
    rep = np.asarray(w.replicas)
    hit = np.isin(own, [(dead_dev - 1) % G, (dead_dev - 2) % G])
    assert (rep[hit] == CFG.n_backups - 1).all(), \
        f"{mix}: dead-holder groups must report n_backups-1"
    assert (rep[~hit & (own != dead_dev)] == CFG.n_backups).all(), \
        f"{mix}: unaffected groups must keep full replication"
    client.recover_server(dead_dev)
    g = client.get(wk)
    assert g.all_found
    np.testing.assert_array_equal(np.asarray(g.values)[:, 0],
                                  np.arange(8 * G))
    assert all(p["agree"] for p in kv.parity_report(client.backend.store,
                                                    CFG))
    print(f"mix {mix} seed {seed} (dead dev {dead_dev}) ok", flush=True)


def main() -> int:
    mesh = jax.make_mesh((len(jax.devices()),), (kv.AXIS,))
    for mix, seed, dead in [("uniform", 11, 2), ("zipfian", 22, 5),
                            ("scan_heavy", 33, 7),
                            ("delete_heavy", 44, 3)]:
        run_mix(mesh, mix, seed, dead)
    print("FAULT-SELFTEST-OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
