import os
if "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
"""Distributed fault-injection differential battery (8 host devices).

Spawned as a subprocess by tests/test_fault_injection.py (the dry-run
rule: only multi-device entrypoints force a host device count).  For each
seeded workload mix, the same op trace + fault schedule is replayed
through HiStoreClient/DistributedBackend and the plain-Python oracle:

  healthy segment -> fail device d (index state WIPED; keys owned by
  group d enter the primary-dead phase, keys of groups d-1/d-2 the
  backup-dead phase) -> degraded segment -> recover (hash rebuilt from a
  sorted replica, replicas re-cloned, degraded-write values migrated
  home) -> post-recovery segment -> fail DATA server d2 (shard + hosted
  mirrors WIPED; reads served from surviving mirrors, writes displaced
  one hop) -> data-degraded segment -> recover (shard rebuilt from a
  mirror, allocator mark-swept, strays migrated home) -> final segment

Every GET/SCAN/DELETE observation must match the fault-oblivious oracle
result-for-result, the value-slot audit must balance after EVERY phase
(parity_report's value_slots entry), recovery must restore full
hash/sorted parity, post-recovery GETs must be one-RTT again
(GetResult.hops == 1 — second-hop fetch elision), and writes during the
failure must report reduced replication.
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent))
sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax
import numpy as np

from repro.configs.histore import scaled
from repro.core import kvstore as kv
from repro.core import telemetry as tm
from repro.core.client import DistributedBackend, HiStoreClient
from repro.core.hashing import key_dtype

from oracle import Oracle, assert_equivalent, gen_ops, replay, splice_faults

CFG = scaled(log_capacity=512, async_apply_batch=128)
N_EVENTS = 12


def phase_parity_hook(client, event) -> None:
    """Run after every kill/recover event and at trace end: the value-slot
    audit must balance in EVERY phase; hash/replica agreement is asserted
    wherever both structures are alive.  The drain also pushes queued
    remote frees through the routed gc op — with every data server alive
    the queues must empty; frees addressed to a dead shard legitimately
    wait for its recovery."""
    client.drain()
    if not client.backend._data_dead:
        assert client.backend.pending_frees() == 0, \
            f"gc flush left frees queued after {event}"
    for p in kv.parity_report(client.backend.store, CFG):
        if p.get("kind") == "value_slots":
            assert p["agree"], f"value audit broke after {event}: {p}"
        elif p["primary_alive"] and p["holder_alive"]:
            assert p["agree"], f"live parity broke after {event}: {p}"
    # single failures leave every group >= 1 live holder, so scans must
    # report complete in EVERY phase (the completeness flag may only
    # trip when a whole group loses both holders)
    s = client.scan(0, 2 ** 31 - 1)
    assert s.complete is True and s.missing_groups == (), \
        f"scan completeness broke after {event}: {s.missing_groups}"


def run_mix(mesh, mix: str, seed: int, dead_dev: int) -> None:
    G = mesh.devices.size
    data_dev = (dead_dev + 3) % G
    ops = gen_ops(seed, mix, n_events=N_EVENTS, batch=3 * G)
    trace = splice_faults(ops, [
        (N_EVENTS // 4, "fail", dead_dev),
        (N_EVENTS // 2, "recover", dead_dev),
        (5 * N_EVENTS // 8, "fail_data", data_dev),
        (7 * N_EVENTS // 8, "recover_data", data_dev),
    ])
    client = HiStoreClient(
        DistributedBackend(mesh, CFG, 4096, capacity_q=64, scan_limit=128),
        batch_quantum=4 * G, max_retries=32)
    oracle = Oracle(value_words=CFG.value_words)
    assert_equivalent(replay(client, trace, phase_hook=phase_parity_hook),
                      replay(oracle, trace),
                      label=f"dist8/{mix}/seed{seed}")
    store = client.backend.store
    assert all(p["agree"] for p in kv.parity_report(store, CFG)), \
        f"{mix}: recovery must restore hash/sorted parity"
    # second-hop fetch elision: after recover + migration every live key
    # reads back in one RTT
    live = np.fromiter(oracle.model.keys(), np.int64)
    if len(live):
        g_all = client.get(live)
        assert g_all.all_found, f"{mix}: post-recovery readback"
        assert bool((np.asarray(g_all.hops) == 1).all()), \
            f"{mix}: migration must restore one-RTT GETs"

    # reduced replication is reported honestly while a holder is dead
    client.fail_server(dead_dev)
    wk = np.random.RandomState(seed + 999).choice(
        10 ** 6, 8 * G, replace=False) + 7 * 10 ** 7
    w = client.put(wk, np.arange(8 * G))
    assert w.all_ok
    own = np.asarray(kv.owner_group(jax.numpy.asarray(wk, key_dtype()), G))
    rep = np.asarray(w.replicas)
    hit = np.isin(own, [(dead_dev - 1) % G, (dead_dev - 2) % G])
    assert (rep[hit] == CFG.n_backups - 1).all(), \
        f"{mix}: dead-holder groups must report n_backups-1"
    assert (rep[~hit & (own != dead_dev)] == CFG.n_backups).all(), \
        f"{mix}: unaffected groups must keep full replication"
    client.recover_server(dead_dev)
    g = client.get(wk)
    assert g.all_found
    np.testing.assert_array_equal(np.asarray(g.values)[:, 0],
                                  np.arange(8 * G))
    assert all(p["agree"] for p in kv.parity_report(client.backend.store,
                                                    CFG))
    print(f"mix {mix} seed {seed} (dead dev {dead_dev}) ok", flush=True)


def run_gc_battery(mesh) -> None:
    """The routed gc op with real pending entries: degraded overwrites of
    a dead group's keys queue remote frees at the temporary primary;
    drain() must route every one home and clear the allocator bits."""
    G = mesh.devices.size
    backend = DistributedBackend(mesh, CFG, 512, capacity_q=64)
    client = HiStoreClient(backend, batch_quantum=4 * G, max_retries=32,
                           migrate_on_recover=False)
    rng = np.random.RandomState(7)
    ks = rng.choice(10 ** 6, 20 * G, replace=False) + 1
    assert client.put(ks, np.arange(20 * G)).all_ok
    dead = 2
    client.fail_server(dead)
    own = np.asarray(kv.owner_group(jax.numpy.asarray(ks, key_dtype()), G))
    dk = ks[own == dead]
    assert len(dk) > 0
    assert client.put(dk, np.arange(len(dk)) + 777).all_ok
    assert backend.pending_frees() == len(dk), \
        "degraded overwrites must queue their home-shard frees"
    used_before = int(np.asarray(backend.store.data.used[dead]).sum())
    client.drain()
    assert backend.pending_frees() == 0, "gc op must deliver every free"
    assert (int(np.asarray(backend.store.data.used[dead]).sum())
            == used_before - len(dk)), "delivered frees clear the bits"
    report = kv.parity_report(backend.store, CFG)
    assert report[-1]["agree"], report[-1]
    # ship this battery's counter state with the CI artifacts: a later
    # hang or failure in the suite still leaves the forensics behind
    logs = Path(__file__).resolve().parents[1] / "test-logs"
    logs.mkdir(exist_ok=True)
    tm.dump_metrics(client.metrics(), logs / "fault_selftest.metrics.json")
    print(f"gc battery ok ({len(dk)} routed frees delivered; metrics -> "
          "test-logs/fault_selftest.metrics.json)", flush=True)


def main() -> int:
    mesh = jax.make_mesh((len(jax.devices()),), (kv.AXIS,))
    for mix, seed, dead in [("uniform", 11, 2), ("zipfian", 22, 5),
                            ("scan_heavy", 33, 7),
                            ("delete_heavy", 44, 3)]:
        run_mix(mesh, mix, seed, dead)
    run_gc_battery(mesh)
    print("FAULT-SELFTEST-OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
