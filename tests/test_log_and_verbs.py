"""Update-log ring semantics + routing-verb building blocks."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import log as lg
from repro.core.hashing import key_dtype
from repro.core.verbs import route_build

KD = key_dtype()


def test_log_append_take_order():
    log = lg.create(16)
    k = jnp.arange(1, 6, dtype=KD)
    a = jnp.arange(5, dtype=jnp.int32)
    ops = jnp.ones((5,), jnp.int8)
    log, ok = lg.append(log, k, a, ops)
    assert bool(ok.all())
    assert int(lg.pending_count(log)) == 5
    keys, addrs, o, log = lg.take_pending(log, 3)
    np.testing.assert_array_equal(np.asarray(keys), [1, 2, 3])
    assert int(lg.pending_count(log)) == 2
    keys, addrs, o, log = lg.take_pending(log, 8)
    np.testing.assert_array_equal(np.asarray(keys)[:2], [4, 5])
    assert (np.asarray(o)[2:] == 0).all()          # padding marked invalid
    assert int(lg.pending_count(log)) == 0


def test_log_ring_wraps_and_overflow_pushback():
    log = lg.create(8)
    for i in range(3):                       # 3 x 4 appends with drains
        k = jnp.arange(i * 4, i * 4 + 4, dtype=KD)
        log, ok = lg.append(log, k, k.astype(jnp.int32),
                            jnp.ones((4,), jnp.int8))
        assert bool(ok.all())
        _, _, _, log = lg.take_pending(log, 4)
    # now overflow: 10 entries into capacity-8 pending window
    k = jnp.arange(100, 110, dtype=KD)
    log, ok = lg.append(log, k, k.astype(jnp.int32),
                        jnp.ones((10,), jnp.int8))
    assert int(ok.sum()) == 8 and not bool(ok[8:].any())
    keys, _, o, log = lg.take_pending(log, 8)
    np.testing.assert_array_equal(np.asarray(keys), np.arange(100, 108))


def test_route_build_capacity_and_slots():
    dest = jnp.array([0, 1, 0, 1, 0, 2], jnp.int32)
    payload = jnp.arange(6, dtype=jnp.int32) * 10
    bufs, slot, ok = route_build(dest, {"p": (payload, -1)}, 4, 2)
    p = np.asarray(bufs["p"]).reshape(4, 2)
    # dest 0 got entries 0,2 (capacity 2; third dropped)
    assert set(p[0].tolist()) == {0, 20}
    assert set(p[1].tolist()) == {10, 30}
    assert p[2][0] == 50 and p[2][1] == -1
    assert not bool(ok[4])                   # third dest-0 entry overflowed
    assert bool(ok[jnp.array([0, 1, 2, 3, 5])].all())
    # slots point back into the exchange buffer
    flat = np.asarray(bufs["p"])
    for i, s in enumerate(np.asarray(slot)):
        if bool(ok[i]):
            assert flat[s] == i * 10
