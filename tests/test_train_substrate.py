"""Training substrate tests: data determinism, checkpoint save/restore +
crash/restart resume, loss-goes-down, compression error feedback."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import ShapeSpec
from repro.configs.tiny import tiny_config
from repro.checkpoint.checkpoint import (latest_step, restore_checkpoint,
                                         save_checkpoint)
from repro.data.pipeline import SyntheticLM
from repro.optim.compression import compress, decompress, ef_state

SHAPE = ShapeSpec("tiny", 32, 4, "train")


def test_data_deterministic_and_stateless():
    ds = SyntheticLM(256, 32, 4, seed=3)
    a = ds.batch(7)
    b = ds.batch(7)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = ds.batch(8)
    assert (a["tokens"] != c["tokens"]).any()
    # targets are next-token shifted with -1 padding at the end
    np.testing.assert_array_equal(a["targets"][:, :-1], a["tokens"][:, 1:])
    assert (a["targets"][:, -1] == -1).all()


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": {"c": jnp.ones((4,), jnp.bfloat16)},
            "t": (jnp.zeros((2,), jnp.int32),)}
    save_checkpoint(tmp_path, 5, tree)
    assert latest_step(tmp_path) == 5
    like = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)
    back = restore_checkpoint(tmp_path, 5, like)
    for x, y in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(x, np.float32),
                                      np.asarray(y, np.float32))


def test_train_loss_goes_down_and_restart_resumes(tmp_path):
    from repro.launch.mesh import make_local_mesh
    from repro.train.trainer import train
    cfg = tiny_config("musicgen-large")
    mesh = make_local_mesh()
    # crash at step 3 after a checkpoint at step 2.  The resume point is
    # deliberately EARLY: at lr=3e-3 the tiny model hits the synthetic
    # data's entropy floor (~3.0) within ~4 steps, after which per-step
    # losses are noise around the floor — the seed version resumed at
    # step 4 and compared two single post-floor samples, which failed
    # nondeterministically.  Resuming at step 2 (pre-floor, loss ~3.6)
    # leaves genuine headroom to descend.
    with pytest.raises(RuntimeError, match="injected failure"):
        train(cfg, mesh, SHAPE, steps=10, ckpt_dir=tmp_path, ckpt_every=2,
              lr=3e-3, fail_at=3, log_every=1)
    assert latest_step(tmp_path) == 2
    out = train(cfg, mesh, SHAPE, steps=14, ckpt_dir=tmp_path, ckpt_every=4,
                lr=3e-3, log_every=1)
    hist = out["history"]
    assert hist[0]["step"] == 2            # resumed, not restarted
    losses = [h["loss"] for h in hist]
    assert all(np.isfinite(l) for l in losses), losses
    # progress = the best post-resume loss beats the resume point (a
    # single last-step sample is noise-dominated at the floor)
    assert min(losses[1:]) < losses[0], losses


def test_compression_error_feedback_converges():
    rng = np.random.RandomState(0)
    g_true = jnp.asarray(rng.randn(64, 32), jnp.float32) * 0.01
    err = jnp.zeros_like(g_true)
    acc_q = jnp.zeros_like(g_true)
    acc_t = jnp.zeros_like(g_true)
    for _ in range(50):
        q, scale, err = compress(g_true, err)
        acc_q = acc_q + decompress(q, scale)
        acc_t = acc_t + g_true
    # error feedback: accumulated quantised grads track the true sum
    rel = float(jnp.abs(acc_q - acc_t).max() / jnp.abs(acc_t).max())
    assert rel < 0.01, rel
    # single-shot int8 is ~8x smaller
    assert q.dtype == jnp.int8
