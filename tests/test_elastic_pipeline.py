"""Elastic re-mesh + pipeline-parallel + compressed-DP protocol tests
(8-device subprocess; see src/repro/train/elastic_selftest.py)."""
from pathlib import Path

import pytest

from _battery import run_battery

ROOT = Path(__file__).resolve().parents[1]


@pytest.mark.slow
def test_elastic_pipeline_compression():
    proc = run_battery(ROOT / "src/repro/train/elastic_selftest.py",
                       "elastic_selftest")
    assert proc.returncode == 0, proc.stdout[-2000:] + proc.stderr[-2000:]
    assert "ELASTIC-SELFTEST-OK" in proc.stdout
