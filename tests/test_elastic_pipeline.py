"""Elastic re-mesh + pipeline-parallel + compressed-DP protocol tests
(8-device subprocess; see src/repro/train/elastic_selftest.py)."""
import os
import subprocess
import sys
from pathlib import Path

import pytest

ROOT = Path(__file__).resolve().parents[1]


@pytest.mark.slow
def test_elastic_pipeline_compression():
    env = dict(os.environ,
               PYTHONPATH=str(ROOT / "src"),
               XLA_FLAGS="--xla_force_host_platform_device_count=8")
    proc = subprocess.run(
        [sys.executable, str(ROOT / "src/repro/train/elastic_selftest.py")],
        env=env, capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stdout[-2000:] + proc.stderr[-2000:]
    assert "ELASTIC-SELFTEST-OK" in proc.stdout
