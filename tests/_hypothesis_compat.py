"""Import hypothesis when available; otherwise provide stand-ins so the
test modules still collect and the property tests SKIP instead of erroring
(the rest of each module runs normally).  See requirements-dev.txt."""
import pytest

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised when hypothesis absent
    HAVE_HYPOTHESIS = False

    class _Strategy:
        """Stands in for hypothesis.strategies: every attribute is a factory
        returning another _Strategy, so decoration-time expressions like
        st.lists(st.tuples(...), max_size=8) evaluate without hypothesis."""

        def __getattr__(self, name):
            return lambda *a, **k: _Strategy()

        def __call__(self, *a, **k):
            return _Strategy()

        def map(self, fn):
            return self

        def filter(self, fn):
            return self

    st = _Strategy()

    def given(*a, **k):
        def deco(fn):
            return pytest.mark.skip(
                reason="hypothesis not installed (see requirements-dev.txt)"
            )(fn)
        return deco

    def settings(*a, **k):
        return lambda fn: fn
