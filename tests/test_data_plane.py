"""Data-plane tests: the slot allocator (alloc/free round-trip, no double
allocation, honesty when full), the wrap-at-capacity regression that the
seed's monotone ring cursor fails (ROADMAP's value-slot GC item), the
``GetResult.hops`` channel, and the free-queue fill/push-back round-trip
(a full queue pushes ops back instead of dropping frees).

The wrap trace is the acceptance bar of the data-plane issue: cumulative
puts exceed 2x the value capacity with deletes interleaved, the store
replays result-for-result against the fault-oblivious oracle, and the
value-slot audit balances exactly — while a simulation of the OLD
ring-cursor allocator on the very same trace demonstrably wraps onto
slots still referenced by live keys.
"""
import jax
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.configs.histore import scaled
from repro.core import data_plane as dpl
from repro.core import hash_index as hix
from repro.core import kvstore as kv
from repro.core.client import (DistributedBackend, HiStoreClient,
                               LocalBackend)

from oracle import Oracle, assert_equivalent, replay

CFG = scaled(log_capacity=1 << 10, async_apply_batch=256)


# ---------------------------------------------------------------------------
# Allocator properties.  The _check_* helpers hold the real properties so
# the fixed-example smokes exercise them when hypothesis is absent.
# ---------------------------------------------------------------------------
def _check_alloc_free_roundtrip(cap, n_first, free_idx):
    used = jnp.zeros((cap,), bool)
    want = jnp.arange(cap) < n_first
    used, slots, ok = dpl.alloc(used, want)
    n_got = min(n_first, cap)
    assert int(ok.sum()) == n_got
    got = np.asarray(slots)[np.asarray(ok)]
    assert len(set(got.tolist())) == n_got, "no double allocation"
    assert int(used.sum()) == n_got
    # free a subset, re-allocate: freed slots are reused, nothing else
    to_free = np.unique([i % max(n_got, 1) for i in free_idx]) if n_got else []
    fs = jnp.asarray(got[list(to_free)] if len(to_free) else [], jnp.int32)
    used = dpl.free_slots(used, fs, jnp.ones(fs.shape, bool))
    assert int(used.sum()) == n_got - len(to_free)
    used, slots2, ok2 = dpl.alloc(used, jnp.arange(cap) < len(to_free))
    assert int(ok2.sum()) == len(to_free)
    re_got = set(np.asarray(slots2)[np.asarray(ok2)].tolist())
    assert re_got == set(got[list(to_free)].tolist()), \
        "freed slots are exactly what re-allocation hands out"


def _check_no_double_alloc_interleaved(script, cap=16):
    """Model-based: whatever the alloc/free interleaving, a live slot is
    never handed out twice and the bitmap balances the model."""
    used = jnp.zeros((cap,), bool)
    live: set = set()
    for do_alloc, n in script:
        if do_alloc:
            want = jnp.arange(cap) < (n % (cap + 1))
            nfree = cap - len(live)
            used, slots, ok = dpl.alloc(used, want)
            got = np.asarray(slots)[np.asarray(ok)].tolist()
            assert int(np.asarray(ok).sum()) == min(n % (cap + 1), nfree), \
                "alloc honesty: exactly min(wanted, free) granted"
            assert not (set(got) & live), "no double allocation"
            live |= set(got)
        elif live:
            victim = sorted(live)[n % len(live)]
            live.discard(victim)
            used = dpl.free_slots(used, jnp.asarray([victim], jnp.int32),
                                  jnp.ones((1,), bool))
        assert int(used.sum()) == len(live), "bitmap balances the model"


@settings(deadline=None, max_examples=30)
@given(st.integers(0, 24), st.lists(st.integers(0, 23), max_size=8))
def test_alloc_free_roundtrip_prop(n_first, free_idx):
    _check_alloc_free_roundtrip(16, n_first, free_idx)


@settings(deadline=None, max_examples=30)
@given(st.lists(st.tuples(st.booleans(), st.integers(0, 30)), max_size=24))
def test_no_double_alloc_interleaved_prop(script):
    _check_no_double_alloc_interleaved(script)


def test_alloc_free_fixed_smokes():
    _check_alloc_free_roundtrip(16, 10, [0, 3, 7])
    _check_alloc_free_roundtrip(8, 12, [1, 1, 2])     # over-ask: shard full
    _check_no_double_alloc_interleaved(
        [(True, 9), (False, 2), (True, 5), (False, 0), (False, 1),
         (True, 30), (False, 3), (True, 4)])


def test_winner_spread_duplicates():
    from repro.core.hashing import key_dtype
    keys = jnp.asarray([5, 9, 5, 7, 5], key_dtype())
    valid = jnp.asarray([True, True, True, True, False])
    w = dpl.winner_mask(keys, valid)
    np.testing.assert_array_equal(np.asarray(w),
                                  [False, True, True, True, False])
    addr_lane = jnp.asarray([-1, 40, 10, 70, -1], jnp.int32)
    spread = dpl.spread_winner_addr(keys, valid, w, addr_lane)
    np.testing.assert_array_equal(np.asarray(spread), [10, 40, 10, 70, -1])


# ---------------------------------------------------------------------------
# Wrap-at-capacity regression (the seed's ring cursor corrupts here)
# ---------------------------------------------------------------------------
def gen_wrap_trace(seed: int, capacity: int, rounds: int = 8):
    """Cumulative puts > 2x value capacity with deletes interleaved while
    the live set stays comfortably below capacity: overwrite a RANDOM half
    of a persistent working set each round (the un-overwritten rest pins
    its slots, so a wrapping cursor must eventually land on one) and churn
    a fresh key window through put+delete.  Returns (trace, total_puts)."""
    rng = np.random.RandomState(seed)
    ws = np.arange(1, capacity // 2 + 1).astype(np.int64)
    events, total = [], 0
    for i in range(rounds):
        if i == 0:
            part = ws.copy()
        else:
            part = np.sort(rng.choice(ws, len(ws) // 2, replace=False))
        events.append(("put", part,
                       rng.randint(1, 1 << 20, len(part)).astype(np.int64)))
        total += len(part)
        extra = (np.arange(1, 17) + 10 ** 6 + 1000 * i).astype(np.int64)
        events.append(("put", extra,
                       rng.randint(1, 1 << 20, 16).astype(np.int64)))
        total += 16
        events.append(("get", ws[rng.choice(len(ws), 16, replace=False)]))
        events.append(("delete", extra))
    events.append(("get", ws))
    return events, total


def ring_cursor_corrupts(trace, capacity: int) -> bool:
    """Simulate the SEED's allocator on a trace: a monotone cursor, slots
    never reclaimed on DELETE or overwrite.  Returns True when an
    allocation lands on a slot still referenced by a live key — the
    wrap corruption the bitmap allocator exists to prevent."""
    cursor = 0
    slot_of: dict = {}
    owner_of: dict = {}
    for ev in trace:
        if ev[0] == "put":
            for k in ev[1].tolist():
                s = cursor % capacity
                cursor += 1
                holder = owner_of.get(s)
                if holder is not None and holder != k:
                    return True          # wrapped onto a live key's slot
                old = slot_of.pop(k, None)
                if old is not None and owner_of.get(old) == k:
                    del owner_of[old]    # the index now points elsewhere
                slot_of[k] = s
                owner_of[s] = k
        elif ev[0] == "delete":
            for k in ev[1].tolist():
                s = slot_of.pop(k, None)
                if s is not None and owner_of.get(s) == k:
                    del owner_of[s]      # ...but the ring never reuses it
    return False


def test_wrap_trace_corrupts_ring_cursor():
    trace, total = gen_wrap_trace(17, 64)
    assert total > 2 * 64, "trace must exceed 2x capacity cumulatively"
    assert ring_cursor_corrupts(trace, 64), \
        "the seed's ring cursor must demonstrably corrupt on this trace"
    # sanity: an infinite ring never corrupts — the checker is not trivially
    # True — and the allocator's capacity bound is the only difference
    assert not ring_cursor_corrupts(trace, 10 ** 9)


def test_wrap_trace_local_vs_oracle():
    """2x-capacity churn on the LocalBackend: exact oracle equivalence and
    balanced slot accounting (used == hash-live, nothing leaked)."""
    trace, total = gen_wrap_trace(17, 64)
    backend = LocalBackend(64, CFG)
    client = HiStoreClient(backend, batch_quantum=16)
    oracle = Oracle(value_words=CFG.value_words)
    assert_equivalent(replay(client, trace), replay(oracle, trace),
                      label="wrap/local")
    n_live = int(hix.n_items(backend.group.hash))
    assert int(backend.used.sum()) == n_live == len(oracle.model), \
        "every live key holds exactly one slot; churn leaked nothing"


def test_wrap_trace_dist_vs_oracle():
    """The same 2x-capacity churn through the shard_map'd store (this
    process's mesh): oracle equivalence plus a clean value-slot audit."""
    mesh = jax.make_mesh((len(jax.devices()),), (kv.AXIS,))
    trace, _ = gen_wrap_trace(23, 64)
    client = HiStoreClient(
        DistributedBackend(mesh, CFG, 64, capacity_q=64, scan_limit=128),
        batch_quantum=16, max_retries=32)
    oracle = Oracle(value_words=CFG.value_words)
    assert_equivalent(replay(client, trace), replay(oracle, trace),
                      label="wrap/dist")
    report = kv.parity_report(client.backend.store, CFG)
    assert all(p["agree"] for p in report), report
    audit = report[-1]
    assert audit["kind"] == "value_slots"
    assert audit["live"] == len(oracle.model)
    assert audit["orphaned"] == 0 and audit["double"] == 0


# ---------------------------------------------------------------------------
# Free-queue fill/push-back round-trip (prolonged data-outage bugfix)
# ---------------------------------------------------------------------------
def _check_freeq_fill_pushback_roundtrip(cap, script):
    """Model-based property of the free queue ring: an append is accepted
    only while the pending window has room (overflow reported, never
    silent), and every ACCEPTED address drains exactly once, in order —
    so no free can be dropped or duplicated whatever the fill/drain
    interleaving."""
    from collections import deque

    from repro.core import log as lg
    from repro.core.hashing import key_dtype

    q = lg.create(cap, key_dtype())
    model: deque = deque()
    next_addr = 0
    for do_append, n in script:
        n = n % (cap + 2)
        if do_append:
            addrs = jnp.arange(next_addr, next_addr + n, dtype=jnp.int32)
            next_addr += n
            q, ok = lg.append(q, jnp.zeros((n,), q.keys.dtype), addrs,
                              jnp.ones((n,), jnp.int8))
            acc = np.asarray(ok)
            room = cap - len(model)
            assert int(acc.sum()) == min(n, room), \
                "append honesty: exactly min(batch, room) accepted"
            model.extend(np.asarray(addrs)[acc].tolist())
        else:
            k, a, o, q = lg.take_pending(q, max(n, 1))
            taken = np.asarray(a)[np.asarray(o) > 0].tolist()
            expect = [model.popleft() for _ in range(len(taken))]
            assert taken == expect, "drain order = accept order"
        assert int(lg.pending_count(q)) == len(model), \
            "ring pending balances the model"
    while model:
        k, a, o, q = lg.take_pending(q, cap)
        taken = np.asarray(a)[np.asarray(o) > 0].tolist()
        assert taken == [model.popleft() for _ in range(len(taken))]
    assert int(lg.pending_count(q)) == 0


@settings(deadline=None, max_examples=30)
@given(st.lists(st.tuples(st.booleans(), st.integers(0, 40)), max_size=24))
def test_freeq_fill_pushback_roundtrip_prop(script):
    _check_freeq_fill_pushback_roundtrip(8, script)


def test_freeq_fill_pushback_fixed_smokes():
    _check_freeq_fill_pushback_roundtrip(
        8, [(True, 5), (False, 2), (True, 9), (True, 3), (False, 30),
            (True, 8), (False, 1)])
    _check_freeq_fill_pushback_roundtrip(4, [(True, 10), (True, 1)])


def test_full_freeq_pushes_back_instead_of_dropping():
    """A delete whose value slot must queue a remote free is NACKED while
    the free queue is full (visible push-back the client retries after GC
    rounds make room) — never acked with the free silently dropped.  The
    dead data shard makes every slot free 'remote' (undeliverable), and
    the queue is pre-filled to the brim host-side."""
    mesh = jax.make_mesh((len(jax.devices()),), (kv.AXIS,))
    backend = DistributedBackend(mesh, CFG, 256, capacity_q=64)
    client = HiStoreClient(backend, batch_quantum=16, max_retries=32)
    keys = np.arange(1, 17)
    assert client.put(keys, keys).all_ok
    import warnings as _w
    with _w.catch_warnings():
        _w.simplefilter("ignore")      # 1-dev mesh: mask-only warning
        client.fail_data_server(0)
    # brim-fill the free queue: pending == capacity, zero room
    st = backend.store
    fq = st.data.freeq
    backend.store = st._replace(data=st.data._replace(
        freeq=fq._replace(tail=fq.applied + jnp.int32(fq.keys.shape[1]))))
    ok, found, _ = backend.delete(jnp.asarray(keys, keys_dtype()),
                                  jnp.ones((16,), bool))
    assert not bool(np.asarray(ok).any()), \
        "full free queue must push the deletes back, not drop their frees"
    audit = kv.parity_report(backend.store, CFG)[-1]
    assert audit["fq_spill"] == 0, "push-back means nothing ever spilled"
    # duplicate-key batch: the nacked winner must take its whole group
    # with it (a re-elected loser lane would append to the full queue)
    dup = np.repeat(keys[:8], 2)
    ok_d, _, _ = backend.delete(jnp.asarray(dup, keys_dtype()),
                                jnp.ones((16,), bool))
    assert not bool(np.asarray(ok_d).any()), \
        "a pushed-back winner must nack its duplicate lanes too"
    audit = kv.parity_report(backend.store, CFG)[-1]
    assert audit["fq_spill"] == 0 and audit["orphaned"] == 0, audit
    # the client's retry loop interleaves GC rounds that reclaim queue
    # room, so the same deletes eventually land — with the frees intact
    res = client.delete(keys)
    assert bool(np.asarray(res.ok).all()) and bool(
        np.asarray(res.found).all())
    assert kv.parity_report(backend.store, CFG)[-1]["agree"]


def keys_dtype():
    from repro.core.hashing import key_dtype
    return key_dtype()


# ---------------------------------------------------------------------------
# hops reporting
# ---------------------------------------------------------------------------
def test_get_hops_local_and_dist():
    """Healthy stores serve every value in one hop, and the hops channel
    survives the client's pad/retry plumbing."""
    for backend in (LocalBackend(256, CFG),
                    DistributedBackend(
                        jax.make_mesh((len(jax.devices()),), (kv.AXIS,)),
                        CFG, 256, capacity_q=64)):
        client = HiStoreClient(backend, batch_quantum=16)
        keys = np.arange(1, 41)
        assert client.put(keys, keys).all_ok
        r = client.get(keys)
        assert r.all_found and r.one_rtt
        np.testing.assert_array_equal(np.asarray(r.hops), np.ones(40))
        miss = client.get(keys + 10 ** 6)
        assert not bool(miss.found.any())
        np.testing.assert_array_equal(np.asarray(miss.hops), np.ones(40))
