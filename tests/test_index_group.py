"""Index-group tests: consistency guarantees (§3.2.3), async apply,
degraded reads and recovery (§3.3, §4.3)."""
import jax
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.configs.histore import scaled
from repro.core.hashing import key_dtype

KD = key_dtype()
from repro.core import index_group as ig
from repro.core import log as lg
from repro.core import sorted_index as si
from repro.core.hashing import key_dtype

KD = key_dtype()

CFG = scaled(log_capacity=256, async_apply_batch=64)


def _put(g, ks, as_):
    return ig.put(g, jnp.array(ks, KD), jnp.array(as_, jnp.int32), CFG)


def test_put_then_get_serializable():
    """Written items are visible to GET immediately (hash is synchronous)."""
    g = ig.create(2048, CFG)
    g, ok = _put(g, [3, 1, 4, 1, 5], [30, 10, 40, 11, 50])
    assert bool(ok.all())
    addr, found, acc = ig.get(g, jnp.array([1, 3, 4, 5, 9], KD), CFG)
    np.testing.assert_array_equal(np.asarray(found),
                                  [True, True, True, True, False])
    np.testing.assert_array_equal(np.asarray(addr)[:4], [11, 30, 40, 50])


def test_scan_sees_all_writes():
    """SCAN drains pending log entries first (strong consistency)."""
    g = ig.create(2048, CFG)
    g, _ = _put(g, list(range(10, 100, 10)), list(range(9)))
    assert int(lg.pending_count(jax.tree.map(lambda a: a[0], g.blogs))) > 0
    (k, a, n), g = ig.scan(g, KD(15), KD(75), 16, CFG)
    assert int(n) == 6            # 20,30,40,50,60,70
    np.testing.assert_array_equal(np.asarray(k)[:6], [20, 30, 40, 50, 60, 70])


def test_hash_and_sorted_agree_after_drain():
    g = ig.create(2048, CFG)
    keys = list(np.random.RandomState(1).choice(10000, 200, replace=False))
    g, _ = _put(g, keys, list(range(200)))
    g, _ = ig.delete(g, jnp.array(keys[:50], KD), CFG)
    g = ig.drain(g, CFG)
    for rep in range(CFG.n_backups):
        srt = jax.tree.map(lambda a: a[rep], g.sorted)
        assert int(srt.size) == 150
        addr_s, found_s, _ = si.search(srt, jnp.array(keys, KD))
        addr_h, found_h, _ = ig.get(g, jnp.array(keys, KD), CFG)
        np.testing.assert_array_equal(np.asarray(found_s), np.asarray(found_h))


def test_degraded_get_after_primary_failure():
    """Primary down -> GET served from sorted replica + pending log."""
    g = ig.create(2048, CFG)
    g, _ = _put(g, [7, 8, 9], [70, 80, 90])
    g = ig.apply_async(g, CFG)                 # applied to replicas
    g, _ = _put(g, [9, 11], [91, 110])         # still pending in logs
    g = ig.fail(g, 0)
    addr, found, acc = ig.get(g, jnp.array([7, 9, 11, 12], KD), CFG)
    np.testing.assert_array_equal(np.asarray(found), [True, True, True, False])
    np.testing.assert_array_equal(np.asarray(addr)[:3], [70, 91, 110])


def test_degraded_delete_visible_in_log():
    g = ig.create(2048, CFG)
    g, _ = _put(g, [5], [50])
    g = ig.apply_async(g, CFG)
    g, _ = ig.delete(g, jnp.array([5], KD), CFG)   # pending DEL
    g = ig.fail(g, 0)
    addr, found, _ = ig.get(g, jnp.array([5], KD), CFG)
    assert not bool(found[0])


def test_recover_primary_rebuilds_hash():
    g = ig.create(2048, CFG)
    keys = list(range(100, 300))
    g, _ = _put(g, keys, [k - 100 for k in keys])
    g = ig.fail(g, 0)
    g = ig.recover_primary(g, CFG)
    assert bool(g.alive[0])
    addr, found, _ = ig.get(g, jnp.array(keys, KD), CFG)
    assert bool(found.all())
    np.testing.assert_array_equal(np.asarray(addr),
                                  [k - 100 for k in keys])


def test_recover_backup_copies_replica():
    g = ig.create(2048, CFG)
    g, _ = _put(g, [1, 2, 3], [10, 20, 30])
    g = ig.fail(g, 2)                          # backup 1 down
    g, _ = _put(g, [4], [40])
    g = ig.recover_backup(g, 1, CFG)
    assert bool(g.alive.all())
    g = ig.drain(g, CFG)
    srt = jax.tree.map(lambda a: a[1], g.sorted)
    got, found, _ = si.search(srt, jnp.array([1, 2, 3, 4], KD))
    assert bool(found.all())


def test_scan_with_backup_failure():
    g = ig.create(2048, CFG)
    g, _ = _put(g, [10, 20, 30], [1, 2, 3])
    g = ig.fail(g, 1)                          # backup 0 down -> use backup 1
    (k, a, n), g = ig.scan(g, KD(10), KD(30), 8, CFG)
    assert int(n) == 3


def test_fail_wipes_primary_state():
    """fail(0) models real loss: the hash table and primary log are gone,
    not merely masked (benchmarks time a genuine rebuild, §4.3)."""
    from repro.core import hash_index as hi
    g = ig.create(2048, CFG)
    g, _ = _put(g, [1, 2, 3], [10, 20, 30])
    assert int(hi.n_items(g.hash)) == 3
    g = ig.fail(g, 0)
    assert not bool(g.alive[0])
    assert int(hi.n_items(g.hash)) == 0
    assert int(lg.pending_count(g.plog)) == 0


def test_get_static_liveness_hints_agree():
    """The primary_alive=True/False/None compilations of GET must return
    the same answers once the replicas are drained (the hints only pick
    which path compiles, never what it answers)."""
    g = ig.create(2048, CFG)
    g, _ = _put(g, [5, 6, 7], [50, 60, 70])
    g = ig.drain(g, CFG)
    probe = jnp.array([5, 6, 7, 8], KD)
    a_t, f_t, _ = ig.get(g, probe, CFG, primary_alive=True)
    a_n, f_n, _ = ig.get(g, probe, CFG, primary_alive=None)
    a_f, f_f, _ = ig.get(g, probe, CFG, primary_alive=False)
    np.testing.assert_array_equal(np.asarray(f_t), np.asarray(f_n))
    np.testing.assert_array_equal(np.asarray(f_t), np.asarray(f_f))
    np.testing.assert_array_equal(np.asarray(a_t), np.asarray(a_n))
    np.testing.assert_array_equal(np.asarray(a_t), np.asarray(a_f))


def test_put_skips_dead_backup_and_recovery_resyncs():
    """put(backups_alive=...) must leave the dead backup's log untouched
    (the paper's PUT speed-up under backup failure) and recover_backup
    must re-sync the replica from the survivor."""
    g = ig.create(2048, CFG)
    g, _ = _put(g, [1, 2, 3], [10, 20, 30])
    g = ig.drain(g, CFG)
    g = ig.fail(g, 1)                       # backup 0 down (wiped)
    g, ok = ig.put(g, jnp.array([4], KD), jnp.array([40], jnp.int32), CFG,
                   backups_alive=(False, True))
    assert bool(ok.all())
    assert int(lg.pending_count(
        jax.tree.map(lambda a: a[0], g.blogs))) == 0, "dead log untouched"
    assert int(lg.pending_count(
        jax.tree.map(lambda a: a[1], g.blogs))) == 1
    g = ig.recover_backup(g, 0, CFG)
    assert bool(g.alive.all())
    g = ig.drain(g, CFG)
    srt = jax.tree.map(lambda a: a[0], g.sorted)
    _, found, _ = si.search(srt, jnp.array([1, 2, 3, 4], KD))
    assert bool(found.all()), "re-cloned replica must hold every write"


def test_degraded_write_delete_recover_primary_roundtrip():
    """Writes and deletes during a primary outage: served from the replica
    + pending log while down (with honest DELETE found), then fully
    present in the rebuilt hash after recover_primary."""
    g = ig.create(2048, CFG)
    g, _ = _put(g, [1, 2], [10, 20])
    g = ig.fail(g, 0)
    g, _ = _put(g, [3], [30])               # write during the outage
    g, found = ig.delete(g, jnp.array([1, 9], KD), CFG)
    np.testing.assert_array_equal(np.asarray(found), [True, False])
    addr, found, _ = ig.get(g, jnp.array([1, 2, 3], KD), CFG,
                            primary_alive=False)
    np.testing.assert_array_equal(np.asarray(found), [False, True, True])
    g = ig.recover_primary(g, CFG)
    assert bool(g.alive[0])
    addr, found, _ = ig.get(g, jnp.array([1, 2, 3], KD), CFG,
                            primary_alive=True)
    np.testing.assert_array_equal(np.asarray(found), [False, True, True])
    np.testing.assert_array_equal(np.asarray(addr)[1:], [20, 30])


def test_delete_with_dead_backups_recovers_consistent():
    """delete(backups_alive=...) skips the dead log; after recovery and a
    drain both replicas agree the key is gone."""
    g = ig.create(2048, CFG)
    g, _ = _put(g, [7, 8], [70, 80])
    g = ig.drain(g, CFG)
    g = ig.fail(g, 2)                       # backup 1 down
    g, found = ig.delete(g, jnp.array([7], KD), CFG,
                         backups_alive=(True, False))
    assert bool(found[0])
    g = ig.recover_backup(g, 1, CFG)
    g = ig.drain(g, CFG)
    for r in range(CFG.n_backups):
        srt = jax.tree.map(lambda a: a[r], g.sorted)
        _, f, _ = si.search(srt, jnp.array([7, 8], KD))
        np.testing.assert_array_equal(np.asarray(f), [False, True])


@settings(max_examples=15, deadline=None)
@given(st.lists(st.tuples(st.sampled_from(["put", "del", "apply"]),
                          st.integers(0, 40), st.integers(0, 99)),
                min_size=1, max_size=25))
def test_group_linearizable_vs_model(ops):
    """Property: GET/SCAN always reflect every completed write, regardless
    of how many async applies have happened in between."""
    g = ig.create(1024, CFG)
    model: dict[int, int] = {}
    for kind, k, a in ops:
        if kind == "put":
            g, ok = _put(g, [k], [a])
            if bool(ok[0]):
                model[k] = a
        elif kind == "del":
            g, _ = ig.delete(g, jnp.array([k], KD), CFG)
            model.pop(k, None)
        else:
            g = ig.apply_async(g, CFG)
    probe = jnp.array(sorted(set(k for _, k, _ in ops)), KD)
    addr, found, _ = ig.get(g, probe, CFG)
    for i, k in enumerate(probe.tolist()):
        assert bool(found[i]) == (k in model), (k, model)
        if k in model:
            assert int(addr[i]) == model[k]
    # scan agrees with the model too
    (ks, _, n), g = ig.scan(g, KD(0), KD(99), 64, CFG)
    assert int(n) == len(model)
