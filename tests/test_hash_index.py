"""Hash index unit + property tests against a Python-dict model."""
import jax
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.configs.histore import scaled
from repro.core.hashing import key_dtype

KD = key_dtype()
from repro.core import hash_index as hi

CFG = scaled()


def _np(x):
    return np.asarray(x)


def test_insert_lookup_roundtrip():
    idx = hi.create(4096, CFG)
    keys = jnp.arange(1, 1001, dtype=KD) * 7919
    addrs = jnp.arange(1000, dtype=jnp.int32)
    idx, ok = hi.insert(idx, keys, addrs, CFG)
    assert bool(ok.all())
    got, found, acc = hi.lookup(idx, keys, CFG)
    assert bool(found.all())
    np.testing.assert_array_equal(_np(got), _np(addrs))
    assert int(acc.max()) <= CFG.max_chain
    # misses
    miss = keys + 1
    _, found_m, _ = hi.lookup(idx, miss, CFG)
    assert not bool(found_m.any())


def test_update_in_place_and_batch_dup_last_wins():
    idx = hi.create(1024, CFG)
    keys = jnp.array([5, 9, 5, 9, 5], dtype=KD)
    addrs = jnp.array([1, 2, 3, 4, 5], dtype=jnp.int32)
    idx, ok = hi.insert(idx, keys, addrs, CFG)
    assert bool(ok.all())
    got, found, _ = hi.lookup(idx, jnp.array([5, 9], dtype=KD), CFG)
    assert bool(found.all())
    np.testing.assert_array_equal(_np(got), [5, 4])
    # second batch updates in place (no new slots)
    fill_before = int(idx.fill.sum())
    idx, ok = hi.insert(idx, jnp.array([5], dtype=KD),
                        jnp.array([77], dtype=jnp.int32), CFG)
    assert bool(ok.all())
    assert int(idx.fill.sum()) == fill_before
    got, _, _ = hi.lookup(idx, jnp.array([5], dtype=KD), CFG)
    assert int(got[0]) == 77


def test_delete_tombstones():
    idx = hi.create(1024, CFG)
    keys = jnp.arange(1, 101, dtype=KD)
    idx, _ = hi.insert(idx, keys, keys.astype(jnp.int32), CFG)
    idx, found = hi.delete(idx, keys[:50], CFG)
    assert bool(found.all())
    _, found2, _ = hi.lookup(idx, keys, CFG)
    np.testing.assert_array_equal(_np(found2), [False] * 50 + [True] * 50)
    assert int(hi.n_items(idx)) == 50


def test_chain_overflow_reports_not_ok():
    tiny = scaled(slots_per_bucket=2, max_chain=1, load_factor=8.0)
    idx = hi.create(8, tiny)   # nb small -> chains overflow quickly
    keys = jnp.arange(1, 201, dtype=KD)
    idx, ok = hi.insert(idx, keys, keys.astype(jnp.int32), tiny)
    assert not bool(ok.all())          # some rejected
    # every accepted key is findable
    got, found, _ = hi.lookup(idx, keys, tiny)
    np.testing.assert_array_equal(_np(found), _np(ok))


@settings(max_examples=25, deadline=None)
@given(st.lists(st.tuples(st.sampled_from(["put", "del"]),
                          st.integers(1, 50),
                          st.integers(0, 1000)),
                min_size=1, max_size=12))
def test_matches_dict_model(ops):
    """Property: batched put/delete sequence behaves like a python dict."""
    idx = hi.create(512, CFG)
    model: dict[int, int] = {}
    for kind, k, a in ops:
        if kind == "put":
            idx, ok = hi.insert(idx, jnp.array([k], KD),
                                jnp.array([a], jnp.int32), CFG)
            if bool(ok[0]):
                model[k] = a
        else:
            idx, _ = hi.delete(idx, jnp.array([k], KD), CFG)
            model.pop(k, None)
    probe = jnp.array(sorted(set(k for _, k, _ in ops)), KD)
    got, found, _ = hi.lookup(idx, probe, CFG)
    for i, k in enumerate(probe.tolist()):
        assert bool(found[i]) == (k in model)
        if k in model:
            assert int(got[i]) == model[k]
