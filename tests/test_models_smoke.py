"""Per-architecture smoke tests: reduced same-family config, one forward /
train step / decode step on CPU; asserts output shapes and finiteness.
(The FULL configs are exercised only via the dry-run — ShapeDtypeStruct,
no allocation.)"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, layer_plan
from repro.configs.tiny import tiny_config
from repro.models.transformer import (apply_model, count_params, decode_step,
                                      init_cache, init_params)
from repro.optim.adamw import adamw_init
from repro.serving.serve_step import prefill
from repro.train.step import train_step

B, S = 2, 32


def _batch(cfg, key):
    if cfg.frontend == "embed":
        inputs = {"embeds": jax.random.normal(key, (B, S, cfg.d_model),
                                              cfg.param_dtype)}
    else:
        inputs = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size)}
    inputs["targets"] = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    return inputs


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_and_train_step(arch):
    cfg = tiny_config(arch)
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    assert count_params(params) > 0
    batch = _batch(cfg, key)
    hidden, aux = apply_model(cfg, params, batch)
    assert hidden.shape == (B, S, cfg.d_model)
    assert np.isfinite(np.asarray(hidden, np.float32)).all()
    opt = adamw_init(params)
    params2, opt2, metrics = jax.jit(
        lambda p, o, b: train_step(cfg, p, o, b))(params, opt, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    # params actually changed
    delta = jax.tree.map(lambda a, b_: float(jnp.abs(a.astype(jnp.float32)
                                                     - b_.astype(jnp.float32)).max()),
                         params, params2)
    assert max(jax.tree.leaves(delta)) > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_step(arch):
    cfg = tiny_config(arch)
    key = jax.random.PRNGKey(1)
    params = init_params(cfg, key)
    cache = init_cache(cfg, B, S)
    if cfg.frontend == "embed":
        inputs = {"embeds": jax.random.normal(key, (B, 1, cfg.d_model),
                                              cfg.param_dtype)}
    else:
        inputs = {"tokens": jax.random.randint(key, (B, 1), 0, cfg.vocab_size)}
    inputs["pos"] = jnp.zeros((B,), jnp.int32)
    logits, cache2 = jax.jit(lambda p, c, i: decode_step(cfg, p, c, i))(
        params, cache, inputs)
    assert logits.shape == (B, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    # cache structure preserved
    assert jax.tree.structure(cache) == jax.tree.structure(cache2)


@pytest.mark.parametrize("arch", ["mistral-nemo-12b", "gemma3-27b",
                                  "falcon-mamba-7b", "deepseek-v2-lite-16b"])
def test_prefill(arch):
    cfg = tiny_config(arch)
    key = jax.random.PRNGKey(2)
    params = init_params(cfg, key)
    batch = _batch(cfg, key)
    logits = prefill(cfg, params, batch)
    assert logits.shape == (B, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all()


def test_layer_plan_counts():
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        plan = layer_plan(cfg)
        n = sum(st.n_rep * len(st.pattern) for st in plan)
        assert n == cfg.n_layers, (arch, n, cfg.n_layers)


def test_full_config_param_counts():
    """Sanity: full (unallocated) param counts are in the advertised range."""
    import numpy as np
    expect = {
        "mistral-large-123b": (110e9, 135e9),
        "command-r-35b": (30e9, 40e9),
        "mistral-nemo-12b": (10e9, 14e9),
        "kimi-k2-1t-a32b": (0.9e12, 1.15e12),
        "falcon-mamba-7b": (6e9, 9e9),
        "zamba2-7b": (6e9, 9e9),
        "deepseek-v2-lite-16b": (13e9, 18e9),
        "internvl2-76b": (65e9, 80e9),
        "gemma3-27b": (22e9, 32e9),
        "musicgen-large": (2.5e9, 5e9),
    }
    key = jax.random.PRNGKey(0)
    for arch, (lo, hi) in expect.items():
        cfg = get_config(arch)
        shapes = jax.eval_shape(lambda k, c=cfg: init_params(c, k), key)
        n = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(shapes))
        assert lo <= n <= hi, f"{arch}: {n/1e9:.1f}B params out of range"
