"""Per-kernel validation: shape/dtype sweeps, assert_allclose (exact for
integer kernels) against the ref.py pure-jnp oracles, plus integration with
the core index structures."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.configs.histore import scaled
from repro.core import hash_index as hi
from repro.core import sorted_index as si
from repro.core.hashing import bucket_of, key_dtype, sig_fp_of
from repro.kernels import ops, ref

CFG = scaled()
KD = key_dtype()


# ---------------------------------------------------------------------------
# hash_probe
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("n_keys,q", [(100, 64), (1000, 256), (5000, 512)])
def test_hash_probe_matches_ref_and_core(n_keys, q):
    rng = np.random.RandomState(n_keys)
    idx = hi.create(max(n_keys * 2, 1024), CFG)
    keys = jnp.asarray(rng.choice(10 ** 6, n_keys, replace=False), KD)
    addrs = jnp.arange(n_keys, dtype=jnp.int32)
    idx, ok = hi.insert(idx, keys, addrs, CFG)
    assert bool(ok.all())
    queries = jnp.concatenate([keys[:q // 2],
                               keys[:q - q // 2] + 10 ** 7])  # hits + misses
    b = bucket_of(queries, idx.sig.shape[0])
    sig, fp = sig_fp_of(queries)
    r_addr, r_found, r_acc = ref.ref_hash_probe(
        b, sig, fp, idx.sig, idx.fp, idx.addr,
        slots_per_bucket=CFG.slots_per_bucket)
    k_addr, k_found, k_acc = ops.hash_probe(idx, queries, CFG, q_block=64)
    np.testing.assert_array_equal(np.asarray(k_addr), np.asarray(r_addr))
    np.testing.assert_array_equal(np.asarray(k_found),
                                  np.asarray(r_found).astype(bool))
    np.testing.assert_array_equal(np.asarray(k_acc), np.asarray(r_acc))
    # agreement with the pure-jnp core lookup
    c_addr, c_found, c_acc = hi.lookup(idx, queries, CFG)
    np.testing.assert_array_equal(np.asarray(k_addr), np.asarray(c_addr))
    np.testing.assert_array_equal(np.asarray(k_found), np.asarray(c_found))
    np.testing.assert_array_equal(np.asarray(k_acc), np.asarray(c_acc))


def test_hash_probe_chain_shapes_sweep():
    for spb, chain in [(4, 2), (8, 4), (8, 2)]:
        cfg = scaled(slots_per_bucket=spb, max_chain=chain)
        idx = hi.create(512, cfg)
        keys = jnp.arange(1, 257, dtype=KD) * 31
        idx, _ = hi.insert(idx, keys, keys.astype(jnp.int32), cfg)
        k_addr, k_found, _ = ops.hash_probe(idx, keys, cfg, q_block=128)
        assert bool(k_found.all())
        np.testing.assert_array_equal(np.asarray(k_addr), np.asarray(keys))


# ---------------------------------------------------------------------------
# sorted_search
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("cap,n", [(256, 100), (4096, 1000), (1 << 15, 5000)])
def test_sorted_search_matches_ref(cap, n):
    rng = np.random.RandomState(cap)
    idx = si.create(cap, dtype=jnp.int32)
    keys = jnp.asarray(np.sort(rng.choice(10 ** 6, n, replace=False)),
                       jnp.int32)
    idx = si.bulk_load(idx, keys, jnp.arange(n, dtype=jnp.int32))
    m = min(128, n)
    queries = jnp.concatenate([keys[:m], keys[:m] + 1])
    r = ref.ref_sorted_search(queries, idx.keys, idx.addrs)
    k = ops.sorted_search(idx, queries, q_block=64)
    np.testing.assert_array_equal(np.asarray(k[0]), np.asarray(r[0]))
    np.testing.assert_array_equal(np.asarray(k[1]),
                                  np.asarray(r[1]).astype(bool))
    np.testing.assert_array_equal(np.asarray(k[2]), np.asarray(r[2]))
    # semantics: hits found with correct addr; true misses not found
    assert bool(k[1][:m].all())
    keyset = set(np.asarray(keys).tolist())
    true_miss = np.array([int(qq) not in keyset
                          for qq in np.asarray(queries[m:])])
    assert not bool(np.asarray(k[1][m:])[true_miss].any())


# ---------------------------------------------------------------------------
# bitonic_sort
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("rows,T", [(8, 64), (16, 256), (4, 1024)])
def test_bitonic_sort_matches_ref(rows, T):
    rng = np.random.RandomState(rows * T)
    keys = jnp.asarray(rng.randint(0, 10 ** 6, (rows, T)), jnp.int32)
    vals = jnp.asarray(rng.randint(0, 10 ** 6, (rows, T)), jnp.int32)
    rk, rv = ref.ref_bitonic_sort(keys, vals)
    kk, kv = ops.sort_pairs(keys, vals, row_block=min(rows, 8))
    np.testing.assert_array_equal(np.asarray(kk), np.asarray(rk))
    # payload permutation is key-consistent (ties may permute freely)
    np.testing.assert_array_equal(np.sort(np.asarray(kv), axis=1),
                                  np.sort(np.asarray(rv), axis=1))
    # exact payload equality where keys are unique
    uniq = np.asarray(jnp.sort(keys, axis=1))
    has_dup = (np.diff(uniq, axis=1) == 0).any()
    if not has_dup:
        np.testing.assert_array_equal(np.asarray(kv), np.asarray(rv))


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2 ** 31 - 2), st.integers(3, 6))
def test_bitonic_sort_property(seed, logt):
    T = 2 ** logt
    rng = np.random.RandomState(seed % 10 ** 6)
    keys = jnp.asarray(rng.randint(0, 100, (4, T)), jnp.int32)
    vals = jnp.arange(4 * T, dtype=jnp.int32).reshape(4, T)
    kk, kv = ops.sort_pairs(keys, vals, row_block=4)
    k = np.asarray(kk)
    assert (np.diff(k, axis=1) >= 0).all()
    # permutation property: payload sets preserved per row
    for r in range(4):
        assert set(np.asarray(kv)[r].tolist()) == set(
            np.asarray(vals)[r].tolist())


# ---------------------------------------------------------------------------
# mamba_scan (fused selective scan)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("B,S,di,N", [(2, 64, 128, 8), (1, 256, 256, 16),
                                      (3, 32, 384, 4)])
def test_mamba_scan_matches_ref(B, S, di, N):
    from repro.kernels.mamba_scan import mamba_scan_kernel
    rng = np.random.RandomState(B * S)
    x = jnp.asarray(rng.randn(B, S, di), jnp.float32)
    dt = jnp.asarray(np.abs(rng.randn(B, S, di)) * 0.05, jnp.float32)
    Bs = jnp.asarray(rng.randn(B, S, N), jnp.float32)
    Cs = jnp.asarray(rng.randn(B, S, N), jnp.float32)
    A = -jnp.exp(jnp.asarray(rng.rand(di, N), jnp.float32))
    want = ref.ref_mamba_scan(x, dt, Bs, Cs, A)
    got = mamba_scan_kernel(x, dt, Bs, Cs, A, d_block=min(128, di),
                            seq_chunk=min(64, S), interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_mamba_scan_in_model_prefill():
    """ssm_impl=pallas gives the same prefill output as the jnp path."""
    from repro.configs.tiny import tiny_config
    from repro.models.transformer import apply_model, init_params
    cfg = tiny_config("falcon-mamba-7b", ssm_chunk=8)
    params = init_params(cfg, jax.random.PRNGKey(0))
    x = {"tokens": jnp.arange(2 * 32).reshape(2, 32) % cfg.vocab_size}
    h_ref, _ = apply_model(cfg, params, x)
    cfg_k = cfg.scaled(ssm_impl="pallas")
    h_krn, _ = apply_model(cfg_k, params, x)
    np.testing.assert_allclose(np.asarray(h_ref, np.float32),
                               np.asarray(h_krn, np.float32),
                               rtol=5e-4, atol=5e-4)
