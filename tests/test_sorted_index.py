"""Sorted index (TPU skiplist) unit + property tests."""
import jax
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.core import sorted_index as si
from repro.core.hashing import key_dtype

KD = key_dtype()


def test_bulk_load_and_search():
    idx = si.create(1 << 12)
    keys = jnp.array(sorted(np.random.RandomState(0).choice(
        10 ** 6, 1000, replace=False)), KD)
    addrs = jnp.arange(1000, dtype=jnp.int32)
    idx = si.bulk_load(idx, keys, addrs)
    got, found, acc = si.search(idx, keys)
    assert bool(found.all())
    np.testing.assert_array_equal(np.asarray(got), np.asarray(addrs))
    assert int(acc[0]) == si.directory_levels(1 << 12, 128)
    _, found_m, _ = si.search(idx, keys + 1)
    assert not bool(found_m.any())


def test_merge_put_overwrite_delete():
    idx = si.create(256)
    idx = si.bulk_load(idx, jnp.array([10, 20, 30], KD),
                       jnp.array([1, 2, 3], jnp.int32))
    keys = jnp.array([20, 25, 30, 25], KD)
    addrs = jnp.array([22, 55, -1, 66], jnp.int32)
    ops = jnp.array([si.OP_PUT, si.OP_PUT, si.OP_DEL, si.OP_PUT], jnp.int8)
    idx = si.merge(idx, keys, addrs, ops)
    assert int(idx.size) == 3            # 10, 20(new), 25(last wins)
    got, found, _ = si.search(idx, jnp.array([10, 20, 25, 30], KD))
    np.testing.assert_array_equal(np.asarray(found), [True, True, True, False])
    np.testing.assert_array_equal(np.asarray(got)[:3], [1, 22, 66])


def test_range_query():
    idx = si.create(512)
    keys = jnp.arange(0, 500, 5, dtype=KD)     # 0,5,...,495
    idx = si.bulk_load(idx, keys, (keys // 5).astype(jnp.int32))
    k, a, n = si.range_query(idx, KD(12), KD(52), 16)
    assert int(n) == 8                                 # 15..50
    np.testing.assert_array_equal(np.asarray(k)[:8],
                                  [15, 20, 25, 30, 35, 40, 45, 50])
    # limit truncation
    k, a, n = si.range_query(idx, KD(0), KD(499), 16)
    assert int(n) == 16


@settings(max_examples=20, deadline=None)
@given(st.lists(st.tuples(st.sampled_from([1, 2]),     # OP_PUT / OP_DEL
                          st.integers(0, 60),
                          st.integers(0, 100)),
                min_size=1, max_size=40))
def test_merge_matches_dict_model(entries):
    idx = si.create(256)
    model: dict[int, int] = {}
    # apply in batches of 8 (asynchronous batched apply, like the log)
    for i in range(0, len(entries), 8):
        batch = entries[i:i + 8]
        keys = jnp.array([k for _, k, _ in batch], KD)
        addrs = jnp.array([a for _, _, a in batch], jnp.int32)
        ops = jnp.array([o for o, _, _ in batch], jnp.int8)
        idx = si.merge(idx, keys, addrs, ops)
        for o, k, a in batch:
            if o == 1:
                model[k] = a
            else:
                model.pop(k, None)
    assert int(idx.size) == len(model)
    if model:
        probe = jnp.array(sorted(model), KD)
        got, found, _ = si.search(idx, probe)
        assert bool(found.all())
        np.testing.assert_array_equal(
            np.asarray(got), [model[k] for k in sorted(model)])
    # sortedness invariant
    k = np.asarray(idx.keys)
    assert (np.diff(k) >= 0).all()
