"""Sorted index (TPU skiplist) unit + property tests."""
import jax
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.core import sorted_index as si
from repro.core.hashing import key_dtype

KD = key_dtype()


def test_bulk_load_and_search():
    idx = si.create(1 << 12)
    keys = jnp.array(sorted(np.random.RandomState(0).choice(
        10 ** 6, 1000, replace=False)), KD)
    addrs = jnp.arange(1000, dtype=jnp.int32)
    idx = si.bulk_load(idx, keys, addrs)
    got, found, acc = si.search(idx, keys)
    assert bool(found.all())
    np.testing.assert_array_equal(np.asarray(got), np.asarray(addrs))
    assert int(acc[0]) == si.directory_levels(1 << 12, 128)
    _, found_m, _ = si.search(idx, keys + 1)
    assert not bool(found_m.any())


def test_merge_put_overwrite_delete():
    idx = si.create(256)
    idx = si.bulk_load(idx, jnp.array([10, 20, 30], KD),
                       jnp.array([1, 2, 3], jnp.int32))
    keys = jnp.array([20, 25, 30, 25], KD)
    addrs = jnp.array([22, 55, -1, 66], jnp.int32)
    ops = jnp.array([si.OP_PUT, si.OP_PUT, si.OP_DEL, si.OP_PUT], jnp.int8)
    idx = si.merge(idx, keys, addrs, ops)
    assert int(idx.size) == 3            # 10, 20(new), 25(last wins)
    got, found, _ = si.search(idx, jnp.array([10, 20, 25, 30], KD))
    np.testing.assert_array_equal(np.asarray(found), [True, True, True, False])
    np.testing.assert_array_equal(np.asarray(got)[:3], [1, 22, 66])


def test_range_query():
    idx = si.create(512)
    keys = jnp.arange(0, 500, 5, dtype=KD)     # 0,5,...,495
    idx = si.bulk_load(idx, keys, (keys // 5).astype(jnp.int32))
    k, a, n = si.range_query(idx, KD(12), KD(52), 16)
    assert int(n) == 8                                 # 15..50
    np.testing.assert_array_equal(np.asarray(k)[:8],
                                  [15, 20, 25, 30, 35, 40, 45, 50])
    # limit truncation
    k, a, n = si.range_query(idx, KD(0), KD(499), 16)
    assert int(n) == 16


# ---------------------------------------------------------------------------
# Property checks.  The _check_* helpers hold the actual properties so the
# fixed-example smoke tests below exercise the same logic when hypothesis
# is not installed (the @given tests then skip via _hypothesis_compat).
# ---------------------------------------------------------------------------
def _check_last_writer_wins(entries):
    """One merge batch with duplicate keys: the LAST occurrence of each
    key must win (arrival order = log order)."""
    idx = si.create(256)
    keys = jnp.array([k for k, _ in entries], KD)
    addrs = jnp.array([a for _, a in entries], jnp.int32)
    ops = jnp.full((len(entries),), si.OP_PUT, jnp.int8)
    idx = si.merge(idx, keys, addrs, ops)
    model = {}
    for k, a in entries:
        model[k] = a
    assert int(idx.size) == len(model)
    probe = jnp.array(sorted(model), KD)
    got, found, _ = si.search(idx, probe)
    assert bool(found.all())
    np.testing.assert_array_equal(np.asarray(got),
                                  [model[k] for k in sorted(model)])


def _check_delete_compaction(puts, dels):
    """DELETE entries compact away: deleted keys vanish, the packed array
    keeps live entries in a sorted prefix with INF padding after."""
    idx = si.create(256)
    idx = si.merge(idx, jnp.array(puts, KD),
                   jnp.arange(len(puts), dtype=jnp.int32),
                   jnp.full((len(puts),), si.OP_PUT, jnp.int8))
    idx = si.merge(idx, jnp.array(dels, KD),
                   jnp.full((len(dels),), -1, jnp.int32),
                   jnp.full((len(dels),), si.OP_DEL, jnp.int8))
    live = sorted(set(puts) - set(dels))
    assert int(idx.size) == len(live)
    k = np.asarray(idx.keys)
    INF = np.iinfo(k.dtype).max
    np.testing.assert_array_equal(k[: len(live)], live)
    assert (k[len(live):] == INF).all(), "compaction must pack the prefix"
    if dels:
        _, found_d, _ = si.search(idx, jnp.array(sorted(set(dels)), KD))
        assert not bool(found_d.any())


def _check_search_agrees_with_searchsorted(keys, probes):
    """The hierarchical directory must agree with jnp.searchsorted over
    the same packed array: found iff present, addr = position's addr."""
    keys = sorted(set(keys))
    idx = si.create(1 << 10)
    idx = si.bulk_load(idx, jnp.array(keys, KD),
                       jnp.arange(len(keys), dtype=jnp.int32))
    probe = jnp.array(probes, KD)
    got, found, _ = si.search(idx, probe)
    pos = np.asarray(jnp.searchsorted(idx.keys, probe))
    karr = np.asarray(idx.keys)
    ref_found = (pos < len(keys)) & (karr[np.minimum(pos, len(karr) - 1)]
                                     == np.asarray(probe))
    np.testing.assert_array_equal(np.asarray(found), ref_found)
    np.testing.assert_array_equal(np.asarray(got)[ref_found],
                                  pos[ref_found])


def _check_range_query_matches_model(keys, lo, hi, limit):
    keys = sorted(set(keys))
    idx = si.create(512)
    idx = si.bulk_load(idx, jnp.array(keys, KD),
                       jnp.arange(len(keys), dtype=jnp.int32))
    k, a, n = si.range_query(idx, KD(lo), KD(hi), limit)
    ref = [x for x in keys if lo <= x <= hi][:limit]
    assert int(n) == len(ref)
    np.testing.assert_array_equal(np.asarray(k)[: len(ref)], ref)


@settings(max_examples=25, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 60), st.integers(0, 100)),
                min_size=1, max_size=40))
def test_prop_merge_last_writer_wins(entries):
    _check_last_writer_wins(entries)


@settings(max_examples=25, deadline=None)
@given(st.lists(st.integers(0, 80), min_size=1, max_size=40),
       st.lists(st.integers(0, 80), min_size=0, max_size=40))
def test_prop_merge_delete_compaction(puts, dels):
    _check_delete_compaction(puts, dels)


@settings(max_examples=25, deadline=None)
@given(st.lists(st.integers(0, 10 ** 6), min_size=1, max_size=200),
       st.lists(st.integers(0, 10 ** 6), min_size=1, max_size=64))
def test_prop_search_agrees_with_searchsorted(keys, probes):
    # probe a mix of present and absent keys
    _check_search_agrees_with_searchsorted(keys, probes + keys[:8])


@settings(max_examples=25, deadline=None)
@given(st.lists(st.integers(0, 500), min_size=1, max_size=60),
       st.integers(0, 500), st.integers(0, 500), st.integers(1, 32))
def test_prop_range_query_matches_model(keys, a, b, limit):
    _check_range_query_matches_model(keys, min(a, b), max(a, b), limit)


def test_property_smokes_fixed_examples():
    """Run the property bodies on fixed adversarial examples so the
    invariants are exercised even without hypothesis installed."""
    _check_last_writer_wins([(5, 1), (5, 2), (3, 9), (5, 7), (3, 0)])
    _check_delete_compaction([1, 2, 3, 4, 5], [2, 4, 9])
    _check_delete_compaction([7], [7])
    _check_search_agrees_with_searchsorted(
        list(range(0, 1000, 7)), [0, 1, 7, 693, 994, 10 ** 6])
    _check_range_query_matches_model(list(range(0, 500, 5)), 12, 52, 16)
    _check_range_query_matches_model([3], 0, 500, 2)


@settings(max_examples=20, deadline=None)
@given(st.lists(st.tuples(st.sampled_from([1, 2]),     # OP_PUT / OP_DEL
                          st.integers(0, 60),
                          st.integers(0, 100)),
                min_size=1, max_size=40))
def test_merge_matches_dict_model(entries):
    idx = si.create(256)
    model: dict[int, int] = {}
    # apply in batches of 8 (asynchronous batched apply, like the log)
    for i in range(0, len(entries), 8):
        batch = entries[i:i + 8]
        keys = jnp.array([k for _, k, _ in batch], KD)
        addrs = jnp.array([a for _, _, a in batch], jnp.int32)
        ops = jnp.array([o for o, _, _ in batch], jnp.int8)
        idx = si.merge(idx, keys, addrs, ops)
        for o, k, a in batch:
            if o == 1:
                model[k] = a
            else:
                model.pop(k, None)
    assert int(idx.size) == len(model)
    if model:
        probe = jnp.array(sorted(model), KD)
        got, found, _ = si.search(idx, probe)
        assert bool(found.all())
        np.testing.assert_array_equal(
            np.asarray(got), [model[k] for k in sorted(model)])
    # sortedness invariant
    k = np.asarray(idx.keys)
    assert (np.diff(k) >= 0).all()
