"""End-to-end system tests: train -> checkpoint -> restore -> serve, and
the full KV-store lifecycle against a reference model."""
import numpy as np
import jax
import jax.numpy as jnp

from repro.configs.base import ShapeSpec
from repro.configs.histore import scaled
from repro.configs.tiny import tiny_config
from repro.core import index_group as ig
from repro.core.hashing import key_dtype
from repro.launch.mesh import make_local_mesh
from repro.serving.engine import ServingEngine
from repro.train.trainer import train

KD = key_dtype()


def test_train_then_serve_roundtrip(tmp_path):
    """Train a tiny model, checkpoint, restore, and serve generations with
    the engine — the full lifecycle a deployment runs."""
    cfg = tiny_config("musicgen-large")
    shape = ShapeSpec("tiny", 32, 4, "train")
    out = train(cfg, make_local_mesh(), shape, steps=8, ckpt_dir=tmp_path,
                ckpt_every=8, lr=3e-3, log_every=4)
    params = jax.tree.map(np.asarray, out["params"])
    eng = ServingEngine(cfg, jax.tree.map(jnp.asarray, params),
                        batch_slots=2, max_len=64, page_size=8)
    eng.submit([1, 2, 3], max_new=5)
    eng.submit([4, 5], max_new=5)
    eng.run()
    assert eng.stats["decode_steps"] > 0
    assert eng.stats["pages_registered"] >= 1
    assert eng.stats["pages_freed"] >= 1


def test_kvstore_lifecycle_vs_model():
    """Mixed PUT/GET/DELETE/SCAN trace on one index group with failure and
    recovery in the middle, validated against a dict."""
    cfg = scaled(log_capacity=1 << 10, async_apply_batch=256)
    g = ig.create(4096, cfg)
    model = {}
    rng = np.random.RandomState(7)

    def put(ks):
        nonlocal g
        ks = list(ks)
        a = rng.randint(0, 1000, len(ks))
        g, ok = ig.put(g, jnp.asarray(ks, KD), jnp.asarray(a, jnp.int32), cfg)
        for i, k in enumerate(ks):
            if bool(ok[i]):
                model[k] = int(a[i])

    put(rng.choice(10 ** 6, 300, replace=False))
    # delete a third
    dels = list(model)[:100]
    g, _ = ig.delete(g, jnp.asarray(dels, KD), cfg)
    for k in dels:
        model.pop(k)
    # primary failure mid-stream
    g = ig.fail(g, 0)
    probe = jnp.asarray(sorted(model)[:64], KD)
    addr, found, _ = ig.get(g, probe, cfg, primary_alive=False)
    assert bool(found.all())
    np.testing.assert_array_equal(
        np.asarray(addr), [model[int(k)] for k in probe])
    # recover and continue
    g = ig.recover_primary(g, cfg)
    put(rng.choice(10 ** 6, 200, replace=False) + 2 * 10 ** 6)
    # full scan agrees with the model
    (ks, _, n), g = ig.scan(g, jnp.asarray(0, KD),
                            jnp.asarray(np.iinfo(np.int32).max - 1, KD),
                            1024, cfg)
    assert int(n) == len(model)
    got = sorted(np.asarray(ks[:int(n)]).tolist())
    assert got == sorted(model)
