"""Unit tests for the CI bench-regression gate (tools/bench_check.py).

Pure-stdlib (no jax): the gate itself must stay runnable on any CI
runner before the heavy deps install.  Exercised through the CLI (the
exact surface ci.sh calls) on synthetic JSON files, including the
acceptance case: a 2x-regressed run must exit non-zero.
"""
import json
import subprocess
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]
CHECK = ROOT / "tools" / "bench_check.py"

BASELINE = [
    {"name": "fig13_dist_recover_server", "n": 4000, "seconds": 10.0},
    {"name": "fig13_post_migration_get", "us_per_op": 800.0,
     "mean_hops": 1.0, "one_rtt": True},
    {"name": "fig13_detection_latency", "rounds": 3, "seconds": 2.0,
     "detected": True},
]


def _run(tmp_path, new_rows, base_rows=BASELINE, extra=()):
    new = tmp_path / "new.json"
    base = tmp_path / "base.json"
    new.write_text(json.dumps(new_rows))
    base.write_text(json.dumps(base_rows))
    return subprocess.run(
        [sys.executable, str(CHECK), str(new), str(base), *extra],
        capture_output=True, text=True)


def test_identical_run_passes(tmp_path):
    p = _run(tmp_path, BASELINE)
    assert p.returncode == 0, p.stderr
    assert "bench-check OK" in p.stdout


def test_regression_within_threshold_passes(tmp_path):
    rows = json.loads(json.dumps(BASELINE))
    rows[0]["seconds"] = 11.0           # +10% < 25% gate
    assert _run(tmp_path, rows).returncode == 0


def test_two_x_latency_regression_fails(tmp_path):
    """The acceptance case: a synthetic 2x-regressed JSON exits
    non-zero and names the offending row."""
    rows = json.loads(json.dumps(BASELINE))
    rows[0]["seconds"] = 20.0
    p = _run(tmp_path, rows)
    assert p.returncode != 0
    assert "fig13_dist_recover_server.seconds" in p.stderr
    assert "regression" in p.stderr


def test_lost_capability_flag_fails(tmp_path):
    rows = json.loads(json.dumps(BASELINE))
    rows[1]["one_rtt"] = False          # GETs no longer one-RTT
    p = _run(tmp_path, rows)
    assert p.returncode != 0
    assert "one_rtt" in p.stderr and "capability" in p.stderr


def test_missing_row_and_newly_skipped_fail(tmp_path):
    p = _run(tmp_path, BASELINE[:2])    # detection row vanished
    assert p.returncode != 0
    assert "missing" in p.stderr
    rows = json.loads(json.dumps(BASELINE))
    rows[2] = {"name": "fig13_detection_latency",
               "skipped": "needs >=3 devices, have 1"}
    p = _run(tmp_path, rows)
    assert p.returncode != 0
    assert "skipped" in p.stderr


def test_speedups_and_extra_rows_never_fail(tmp_path):
    rows = json.loads(json.dumps(BASELINE))
    rows[0]["seconds"] = 1.0            # 10x faster
    rows.append({"name": "fig13_new_metric", "seconds": 99.0})
    assert _run(tmp_path, rows).returncode == 0


def test_rtol_flag_overrides_default(tmp_path):
    rows = json.loads(json.dumps(BASELINE))
    rows[0]["seconds"] = 14.0           # +40%: fails at 0.25, ok at 0.5
    assert _run(tmp_path, rows).returncode != 0
    assert _run(tmp_path, rows, extra=("--rtol", "0.5")).returncode == 0


def test_small_absolute_noise_is_absorbed(tmp_path):
    """Sub-atol timings are scheduler noise: 0.01s -> 0.02s is a '2x
    regression' only nominally — the absolute slack must absorb it."""
    base = [{"name": "tiny", "seconds": 0.01}]
    rows = [{"name": "tiny", "seconds": 0.02}]
    assert _run(tmp_path, rows, base_rows=base).returncode == 0


def test_wall_idle_row_gates_on_flag_not_timing(tmp_path):
    """fig13_wall_idle_detection's wall time is a fixed lease timeout
    plus thread scheduling, not code speed: a descheduled-ticker 3x
    'regression' must pass, but losing detected_idle must still fail."""
    base = [{"name": "fig13_wall_idle_detection", "seconds": 0.47,
             "detected_idle": True}]
    slow = [{"name": "fig13_wall_idle_detection", "seconds": 1.6,
             "detected_idle": True}]
    assert _run(tmp_path, slow, base_rows=base).returncode == 0
    lost = [{"name": "fig13_wall_idle_detection", "seconds": 0.47,
             "detected_idle": False}]
    p = _run(tmp_path, lost, base_rows=base)
    assert p.returncode != 0 and "detected_idle" in p.stderr


def test_zero_baseline_reports_without_crashing(tmp_path):
    """A 0.0 baseline timing (round(t, 4) of a very fast row) must gate
    through the absolute slack and report cleanly — no
    ZeroDivisionError swallowing the failure list."""
    base = [{"name": "zed", "seconds": 0.0}]
    rows = [{"name": "zed", "seconds": 0.9}]
    p = _run(tmp_path, rows, base_rows=base)
    assert p.returncode != 0
    assert "zed.seconds" in p.stderr and "Traceback" not in p.stderr


def test_non_gating_rows_are_skipped(tmp_path):
    """Rows flagged non_gating (single-pass phase timings, e.g. the
    fig12 load/run split) never fail the gate — not on regression, not
    on disappearing."""
    base = [{"name": "fig12_load_histore", "non_gating": True,
             "seconds": 1.0},
            {"name": "fig13_dist_recover_server", "seconds": 10.0}]
    rows = [{"name": "fig12_load_histore", "non_gating": True,
             "seconds": 50.0},
            {"name": "fig13_dist_recover_server", "seconds": 10.0}]
    assert _run(tmp_path, rows, base_rows=base).returncode == 0
    gone = [{"name": "fig13_dist_recover_server", "seconds": 10.0}]
    assert _run(tmp_path, gone, base_rows=base).returncode == 0


def test_flag_mismatch_skips_row(tmp_path):
    """Rows whose measurement-environment stamps differ (use_kernels /
    platform — benchmarks/common.py env_fields) are a configuration
    mismatch: a 10x 'regression' against a differently-stamped baseline
    must be skipped, and so must that row's capability flags."""
    base = [{"name": "fig9b_get_histore", "us_per_op": 100.0,
             "use_kernels": "off", "platform": "cpu", "served": True}]
    rows = [{"name": "fig9b_get_histore", "us_per_op": 1000.0,
             "use_kernels": "on", "platform": "cpu", "served": False}]
    p = _run(tmp_path, rows, base_rows=base)
    assert p.returncode == 0, p.stderr
    assert "use_kernels differs" in p.stdout


def test_flag_match_still_gates(tmp_path):
    """Identical stamps gate exactly as unstamped rows do."""
    base = [{"name": "fig9b_get_histore", "us_per_op": 100.0,
             "use_kernels": "on", "platform": "cpu"}]
    rows = [{"name": "fig9b_get_histore", "us_per_op": 1000.0,
             "use_kernels": "on", "platform": "cpu"}]
    p = _run(tmp_path, rows, base_rows=base)
    assert p.returncode != 0
    assert "fig9b_get_histore.us_per_op" in p.stderr


def test_missing_flag_on_one_side_still_gates(tmp_path):
    """The skip needs the stamp on BOTH rows: pre-stamp baselines keep
    gating new (stamped) runs — no silent gate loss on upgrade."""
    base = [{"name": "fig13_dist_recover_server", "seconds": 10.0}]
    rows = [{"name": "fig13_dist_recover_server", "seconds": 40.0,
             "use_kernels": "on", "platform": "cpu"}]
    assert _run(tmp_path, rows, base_rows=base).returncode != 0


# ---------------------------------------------------------------------------
# Trend mode (--trend): monotone drift across a run history
# ---------------------------------------------------------------------------
def _run_trend(tmp_path, histories, extra=()):
    hist = tmp_path / "bench-history"
    hist.mkdir(exist_ok=True)
    for i, rows in enumerate(histories):
        (hist / f"2026010{i}T000000_fig13.json").write_text(
            json.dumps(rows))
    out = tmp_path / "bench_trend.json"
    p = subprocess.run(
        [sys.executable, str(CHECK), "--trend", str(hist),
         "--trend-out", str(out), *extra],
        capture_output=True, text=True)
    return p, out


def _series(seconds_list, name="fig13_dist_recover_server"):
    return [[{"name": name, "seconds": s}] for s in seconds_list]


def test_trend_monotone_creep_fails(tmp_path):
    """Three consecutive +10% steps (each under the 25% single-baseline
    gate) compound past it — the trend gate must catch the drift."""
    p, out = _run_trend(tmp_path, _series([10.0, 11.0, 12.1, 13.3]))
    assert p.returncode != 0
    assert "monotone creep" in p.stderr
    report = json.loads(out.read_text())
    assert report["failures"]
    assert report["series"]["fig13_dist_recover_server.seconds"] == \
        [10.0, 11.0, 12.1, 13.3]


def test_trend_stable_history_passes(tmp_path):
    p, out = _run_trend(tmp_path, _series([10.0, 10.4, 9.8, 10.2, 10.1]))
    assert p.returncode == 0, p.stderr
    assert "bench-trend OK" in p.stdout
    assert json.loads(out.read_text())["failures"] == []


def test_trend_short_history_passes(tmp_path):
    """Fewer than 3 runs: nothing to call a trend yet."""
    p, _ = _run_trend(tmp_path, _series([10.0, 13.3]))
    assert p.returncode == 0, p.stderr
    p, _ = _run_trend(tmp_path, [])
    assert p.returncode == 0, p.stderr


def test_trend_growth_within_rtol_passes(tmp_path):
    """Monotone but small: total growth under rtol+atol is not drift."""
    p, _ = _run_trend(tmp_path, _series([10.0, 10.2, 10.4, 10.6]))
    assert p.returncode == 0, p.stderr


def test_trend_skips_non_gating_and_ungated_rows(tmp_path):
    creep = [2.0, 3.0, 4.5, 7.0]
    hist = [[{"name": "fig12_load_histore", "non_gating": True,
              "seconds": s},
             {"name": "fig13_wall_idle_detection", "seconds": s,
              "detected_idle": True}] for s in creep]
    p, out = _run_trend(tmp_path, hist)
    assert p.returncode == 0, p.stderr
    assert json.loads(out.read_text())["series"] == {}


def test_trend_separates_series_by_env_stamp(tmp_path):
    """A history alternating jnp and kernel runs (each stable, kernel
    slower) must form two flat per-stamp series, not one sawtooth that
    the monotone filter could misread as creep."""
    hist = []
    for i in range(6):
        knob = "off" if i % 2 == 0 else "on"
        s = 10.0 if knob == "off" else 14.0
        hist.append([{"name": "fig9b_get_histore", "us_per_op": s * 100,
                      "use_kernels": knob, "platform": "cpu"}])
    p, out = _run_trend(tmp_path, hist)
    assert p.returncode == 0, p.stderr
    series = json.loads(out.read_text())["series"]
    assert any("use_kernels=off" in k for k in series)
    assert any("use_kernels=on" in k for k in series)


def test_trend_window_limits_lookback(tmp_path):
    """--window examines only the newest N files: old fast runs outside
    the window must not manufacture a creep verdict."""
    p, _ = _run_trend(tmp_path, _series([1.0, 10.0, 10.1, 10.2]),
                      extra=("--window", "3"))
    assert p.returncode == 0, p.stderr
