"""Ops telemetry plane: histograms, counters, traces, and the knob.

The counters are verified DIFFERENTIALLY: a seeded oracle trace is
replayed through an instrumented client and every telemetry counter must
equal the ground truth recomputed from the trace itself (op counts,
client-observed retries, demotions delivered only through severed
heartbeats — ``oracle_kills == 0``).  The "off" mode is held to a hard
contract: a snapshot taken before a workload equals one taken after.
"""
import json
import time
import warnings

import jax
import numpy as np
import pytest

from repro.configs.histore import scaled
from repro.core import kvstore as kv
from repro.core import telemetry as tm
from repro.core.client import (DistributedBackend, HiStoreClient,
                               LocalBackend)

from oracle import FaultInjector, gen_ops, replay, splice_faults

CFG = scaled(log_capacity=1 << 10, async_apply_batch=256)


def _local_client(telemetry="counters", capacity=4096):
    cfg = scaled(log_capacity=1 << 10, async_apply_batch=256,
                 telemetry=telemetry)
    return HiStoreClient(LocalBackend(capacity, cfg), batch_quantum=16)


def _one_dev_client(cfg, **kw):
    mesh = jax.make_mesh((len(jax.devices()),), (kv.AXIS,))
    return HiStoreClient(DistributedBackend(mesh, cfg, 512, capacity_q=64),
                         batch_quantum=16, **kw)


# ---------------------------------------------------------------------------
# Histogram unit behaviour
# ---------------------------------------------------------------------------
def test_histogram_percentiles_log_buckets():
    """p50/p95/p99 come from the log2 bucket walk: conservative (upper
    bucket edge) but clipped to the exact observed max."""
    h = tm.LatencyHistogram()
    for us in [1, 1, 2, 3, 100, 1000]:
        h.record(us * 1e-6)
    s = h.snapshot()
    assert s.count == 6
    assert s.max == pytest.approx(1e-3)
    # p50: 3rd of 6 samples lands in the [2,4)us bucket -> edge 4us
    assert s.p50 == pytest.approx(4e-6)
    # p99 -> last sample's bucket edge (1024us) clipped to max (1000us)
    assert s.p99 == pytest.approx(1e-3)
    assert s.mean == pytest.approx(s.total / 6)


def test_histogram_empty_and_submicro():
    h = tm.LatencyHistogram()
    assert h.snapshot() == tm.LatencySnapshot(0, 0.0, 0.0, 0.0, 0.0,
                                              0.0, 0.0)
    h.record(2e-7)                      # sub-microsecond -> bucket 0
    s = h.snapshot()
    assert s.count == 1 and s.p50 == pytest.approx(2e-7)  # clipped to max


def test_optrace_ring_is_bounded():
    tr = tm.OpTrace(capacity=4)
    for i in range(10):
        tr.record({"i": i})
    assert len(tr) == 4
    assert [s["i"] for s in tr.spans()] == [6, 7, 8, 9]


def test_invalid_mode_rejected_at_construction():
    with pytest.raises(ValueError, match="telemetry"):
        tm.Telemetry("verbose")
    with pytest.raises(ValueError, match="telemetry"):
        _local_client(telemetry="on")


# ---------------------------------------------------------------------------
# Differential: counters vs the oracle trace ground truth
# ---------------------------------------------------------------------------
def test_counters_match_trace_ground_truth():
    """Replay a seeded mixed trace with a kill schedule; every counter
    must equal the value recomputed from the trace itself."""
    n_events = 16
    ops = gen_ops(3, "uniform", n_events=n_events, batch=16)
    schedule = [(n_events // 4, "fail", 0),
                (n_events // 2, "recover", 0)]
    trace = splice_faults(ops, schedule)
    client = _local_client()
    replay(client, trace)
    c = client.metrics().counters
    truth = {"put": 0, "get": 0, "delete": 0, "scan": 0}
    for ev in ops:
        if ev[0] == "scan":
            truth["scan"] += 1
        else:
            truth[ev[0]] += len(ev[1])
    assert c.get("put_ops", 0) == truth["put"] == client.stats["puts"]
    assert c.get("get_ops", 0) == truth["get"] == client.stats["gets"]
    assert c.get("delete_ops", 0) == truth["delete"]
    assert c.get("scan_ops", 0) == truth["scan"]
    assert c.get("retries", 0) == client.stats["retries"]
    assert c.get("index_demotions", 0) == 1     # the one scheduled kill
    assert c.get("index_recoveries", 0) == 1
    assert c.get("hops2_gets", 0) == 0          # healthy local data plane
    lat = client.metrics().latency
    assert lat["put"].count > 0 and lat["get"].count > 0


def test_detector_demotions_with_zero_oracle_kills():
    """The lease-detector differential: the only kill is a severed
    heartbeat, so demotions come from DETECTION — the injector proves no
    oracle fail_server ever ran."""
    cfg = scaled(log_capacity=1 << 10, async_apply_batch=256,
                 lease_misses=3, lease_clock="rounds")
    client = _one_dev_client(cfg)
    backend = client.backend
    inj = FaultInjector(client)
    keys = np.arange(1, 17)
    assert client.put(keys, keys).all_ok
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")     # 1-dev mask-only warning
        inj.sever(0)
    client.get(keys)                        # retries age the lease
    assert backend.detected == [0]
    inj.recover(0)
    c = client.metrics().counters
    assert inj.oracle_kills == 0
    assert c.get("index_demotions", 0) == 1
    assert c.get("index_recoveries", 0) == 1
    assert c.get("retries", 0) == client.stats["retries"] > 0
    assert c.get("lease_ticks", 0) > 0


def test_off_mode_records_nothing():
    """cfg.telemetry="off": a snapshot before the workload equals one
    after — no counters, no histograms, no trace, no gauges."""
    client = _local_client(telemetry="off")
    before = client.metrics()
    trace = gen_ops(5, "uniform", n_events=8, batch=16)
    replay(client, trace)
    after = client.metrics()
    assert before == after
    assert after.counters == {} and after.latency == {}
    assert after.gauges == {} and after.trace_len == 0
    assert client.stats["puts"] > 0     # the workload itself did run


def test_trace_mode_spans_and_dump(tmp_path):
    client = _local_client(telemetry="trace")
    keys = np.arange(1, 33)
    assert client.put(keys, keys).all_ok
    client.get(keys)
    client.scan(1, 100, 16)
    spans = client.telemetry.trace_spans()
    assert {s["op"] for s in spans} >= {"put", "get", "scan"}
    put_span = next(s for s in spans if s["op"] == "put")
    phases = [e["phase"] for e in put_span["events"]]
    assert phases[0] == "route" and "dispatch" in phases
    out = tmp_path / "trace.json"
    client.dump_trace(out)
    assert {s["op"] for s in json.loads(out.read_text())} \
        == {s["op"] for s in spans}


def test_counters_mode_has_no_trace():
    client = _local_client(telemetry="counters")
    keys = np.arange(1, 17)
    assert client.put(keys, keys).all_ok
    assert client.telemetry.trace_spans() == []
    assert client.metrics().trace_len == 0


# ---------------------------------------------------------------------------
# Gauges, exposition format, overhead
# ---------------------------------------------------------------------------
def test_gauges_reflect_backend_state():
    client = _local_client()
    keys = np.arange(1, 33)
    assert client.put(keys, keys).all_ok
    g = client.metrics().gauges
    assert g["live_index_servers"] == 1 + CFG.n_backups
    assert g["pending_log_ops"] == client.backend.pending_ops() > 0
    client.backend.fail_server(1)
    assert client.metrics().gauges["live_index_servers"] == CFG.n_backups
    client.backend.recover_server(1)


def test_gauges_distributed_device_counters():
    cfg = scaled(log_capacity=1 << 10, async_apply_batch=256)
    client = _one_dev_client(cfg)
    keys = np.arange(1, 33)
    assert client.put(keys, keys).all_ok
    g = client.metrics().gauges
    G = len(jax.devices())
    assert g["live_index_servers"] == G
    assert g["live_data_servers"] == G
    assert g["pending_log_ops"] > 0
    client.drain()
    assert client.metrics().gauges["pending_log_ops"] == 0


def test_prometheus_text_format():
    client = _local_client()
    keys = np.arange(1, 17)
    assert client.put(keys, keys).all_ok
    text = client.metrics_text()
    assert "# TYPE histore_put_ops_total counter" in text
    assert "histore_put_ops_total 16" in text
    assert "# TYPE histore_live_index_servers gauge" in text
    assert '# TYPE histore_op_latency_seconds summary' in text
    assert 'histore_op_latency_seconds{op="put",quantile="0.99"}' in text
    assert 'histore_op_latency_seconds_count{op="put"} 1' in text


def test_record_path_is_cheap_and_allocation_free():
    """The hot-path budget: record() touches a preallocated bucket array
    only — its array object identity never changes and a million records
    stay well under a second."""
    h = tm.LatencyHistogram()
    buckets = h.buckets
    t0 = time.perf_counter()
    for _ in range(100_000):
        h.record(3.2e-6)
    dt = time.perf_counter() - t0
    assert h.buckets is buckets and h.n == 100_000
    assert dt < 1.0, f"100k records took {dt:.3f}s"


def test_enabled_overhead_smoke():
    """Counters mode must not change the op path's complexity class: the
    same trace replayed with telemetry on stays within a loose envelope
    of the off-mode run (3x + absolute slack for scheduler noise)."""
    trace = gen_ops(7, "uniform", n_events=10, batch=16)
    timings = {}
    for mode in ("off", "counters"):
        client = _local_client(telemetry=mode)
        replay(client, trace)               # warm (compile)
        client2 = _local_client(telemetry=mode)
        t0 = time.perf_counter()
        replay(client2, trace)
        timings[mode] = time.perf_counter() - t0
    assert timings["counters"] <= timings["off"] * 3.0 + 0.5, timings


# ---------------------------------------------------------------------------
# Ticker error surfacing (the give-up latch)
# ---------------------------------------------------------------------------
def test_ticker_gave_up_is_latched_and_counted():
    """A ticker that dies after 3 consecutive tick errors must say so:
    ticker_errors/ticker_gave_up counters, start_ticker() returning
    False while latched, stop_ticker() clearing the latch."""
    wcfg = scaled(log_capacity=1 << 10, async_apply_batch=256,
                  lease_misses=3, lease_clock="wall",
                  lease_timeout_s=0.5, lease_interval_s=0.05)
    client = _one_dev_client(cfg=wcfg)
    backend = client.backend

    def boom(bump=False):
        raise RuntimeError("injected tick failure")

    backend._lease_tick = boom
    backend._last_traffic_t = time.monotonic() - 999.0
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")     # the loop's RuntimeWarning
        assert client.start_ticker()
        t = backend._ticker
        t.join(timeout=30.0)
    assert not t.is_alive(), "3 consecutive errors must end the loop"
    c = client.metrics().counters
    assert c.get("ticker_errors", 0) == 3
    assert c.get("ticker_gave_up", 0) == 1
    assert backend._ticker_gave_up is True
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        assert client.start_ticker() is False, \
            "a gave-up ticker must not silently restart"
    client.stop_ticker()                    # explicit stop clears the latch
    assert backend._ticker_gave_up is False
