import os
if "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
"""Lease-based failure-detection battery (8 host devices).

Spawned as a subprocess by tests/test_lease_detection.py (the dry-run
rule: only multi-device entrypoints force a host device count).  Every
kill in here is delivered by SEVERING HEARTBEATS (tests/oracle.py's
FaultInjector) — there is not a single oracle ``fail_server`` call; the
client must discover each failure through its lease detector (paper §5):

  * detection bound — after a sever, the client demotes the server to
    degraded routing in EXACTLY ``cfg.lease_misses`` observation rounds
    (heartbeat counters bumped on the mesh, aged host-side) — the
    rounds-clock regression guard: wall-clock leases (the default) must
    not change the deterministic bound of ``lease_clock="rounds"``;
  * idle wall-clock detection — ``lease_clock="wall"``: a severed server
    is demoted by the background ticker alone, with ZERO foreground ops,
    within ``lease_timeout_s`` plus one tick interval;
  * data-server leases — a DATA-server kill delivered only through cut
    heartbeats: GETs fail over to mirror-served second-hop fetches
    immediately, the data lease expires within the bound, displaced PUTs
    land post-detection, and recovery from the DETECTED state (plus
    migration) restores one-RTT GETs — zero oracle kills;
  * scan completeness — while BOTH holders of a group are severed, SCAN
    names the uncovered group (``ScanResult.complete=False``) instead of
    silently omitting its range; the retry loop drives detection, and
    recovery restores ``complete=True``;
  * differential trace — a seeded op trace with sever/recover events
    spliced in replays result-for-result against the fault-oblivious
    oracle: pre-detection timeouts are retried, post-detection degraded
    routing serves, recovery restores parity;
  * online catch-up — recovery clones snapshots and returns with the
    pending-log delta still streaming (``RecoverResult.catch_up_pending
    > 0``); foreground PUT/GET traffic interleaves DURING the catch-up
    and stays oracle-equivalent, then the debt drains and parity holds;
  * multi-failure — an adjacent double sever (both replica holders of
    one group) and the triple that previously raised a bare ValueError:
    recovery now falls back to the primary's hash + the keys stored
    with the data items (paper: rebuild fetches keys from the data
    servers), re-replication restores R copies, and parity is clean;
    a truly-lost configuration raises the typed RecoveryError with
    actionable blockers instead.
"""
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent))
sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.histore import scaled
from repro.core import kvstore as kv
from repro.core import telemetry as tm
from repro.core.client import DistributedBackend, HiStoreClient
from repro.core.hashing import key_dtype

from oracle import (FaultInjector, Oracle, assert_equivalent, gen_ops,
                    replay, splice_faults)

# rounds clock: the deterministic detection bound these phases assert;
# run_idle_wall_clock builds its own wall-clock config
CFG = scaled(log_capacity=512, async_apply_batch=128, lease_misses=3,
             lease_clock="rounds")
CAP = 512
N_EVENTS = 10


def make_client(mesh, cfg=CFG, **kw):
    return HiStoreClient(
        DistributedBackend(mesh, cfg, CAP, capacity_q=64, scan_limit=128),
        batch_quantum=4 * mesh.devices.size, max_retries=32, **kw)


def owned_by(keys, dev, G, invert=False):
    own = np.asarray(kv.owner_group(jnp.asarray(keys, key_dtype()), G))
    return keys[(own != dev) if invert else (own == dev)]


def run_detection_bound(mesh) -> None:
    """Exactly lease_misses observation rounds after a sever, the client
    demotes — no sooner (no spurious demotions), no later (the bound)."""
    G = mesh.devices.size
    client = make_client(mesh)
    backend = client.backend
    keys = np.random.RandomState(1).choice(10 ** 6, 8 * G,
                                           replace=False) + 1
    assert client.put(keys, np.arange(8 * G)).all_ok
    dead = 3
    probe = owned_by(keys, dead, G, invert=True)[:G]  # no retry loops
    inj = FaultInjector(client)
    inj.sever(dead)
    for i in range(CFG.lease_misses):
        assert dead not in backend._dead, \
            f"demoted after only {i} rounds (lease bound is " \
            f"{CFG.lease_misses})"
        client.get(probe)          # one observation round
    assert backend.detected == [dead], \
        "the detector (and nothing else) must demote the severed server"
    assert inj.oracle_kills == 0
    # degraded routing now serves the dead group's keys from backups
    dk = owned_by(keys, dead, G)
    if len(dk):
        r = client.get(dk)
        assert r.all_found, "post-detection degraded GETs must serve"
    inj.recover(dead)
    assert dead not in backend._dead and not backend._severed
    assert all(p["agree"] for p in kv.parity_report(backend.store, CFG))
    print(f"detection bound ok (demoted dev {dead} in exactly "
          f"{CFG.lease_misses} rounds)", flush=True)


def run_detector_trace(mesh, mix: str, seed: int, dead_dev: int) -> None:
    """Differential replay where the kill arrives only through severed
    heartbeats: the store must stay indistinguishable from the healthy
    oracle across the undetected, degraded and post-recovery phases."""
    G = mesh.devices.size
    ops = gen_ops(seed, mix, n_events=N_EVENTS, batch=3 * G)
    trace = splice_faults(ops, [
        (N_EVENTS // 3, "sever", dead_dev),
        (2 * N_EVENTS // 3, "recover", dead_dev),
    ])
    assert not any(ev[0] == "fail" for ev in trace), \
        "detector schedule must contain zero oracle fail_server events"
    client = make_client(mesh)
    oracle = Oracle(value_words=CFG.value_words)

    def hook(c, event):
        c.drain()
        for p in kv.parity_report(c.backend.store, CFG):
            if p.get("kind") == "value_slots":
                assert p["agree"], f"value audit broke after {event}: {p}"
            elif p["primary_alive"] and p["holder_alive"]:
                assert p["agree"], f"live parity broke after {event}: {p}"

    assert_equivalent(replay(client, trace, phase_hook=hook),
                      replay(oracle, trace),
                      label=f"lease/{mix}/seed{seed}")
    assert client.backend.detected == [dead_dev], \
        "the kill must have been DISCOVERED by the lease detector"
    assert all(p["agree"]
               for p in kv.parity_report(client.backend.store, CFG))
    live = np.fromiter(oracle.model.keys(), np.int64)
    if len(live):
        g_all = client.get(live)
        assert g_all.all_found and bool(
            (np.asarray(g_all.hops) == 1).all())
    print(f"detector trace {mix} seed {seed} ok "
          f"(detected {client.backend.detected})", flush=True)


def run_online_catch_up(mesh) -> None:
    """Online recovery: the rebuild returns with pending-log debt still
    streaming; foreground ops interleave DURING the catch-up and match
    the oracle; the debt then drains through ordinary applies."""
    G = mesh.devices.size
    client = make_client(mesh, migrate_on_recover=False)
    backend = client.backend
    model = {}
    rng = np.random.RandomState(7)
    keys = rng.choice(10 ** 6, 16 * G, replace=False) + 1
    assert client.put(keys, np.arange(16 * G)).all_ok
    model.update(zip(keys.tolist(), range(16 * G)))
    client.drain()
    dead = 2
    inj = FaultInjector(client)
    inj.sever(dead)
    # ops until the lease expires (puts to live owners also build the
    # pending backlog the recovery will have to stream)
    other = owned_by(keys, dead, G, invert=True)
    w = 0
    while dead not in backend._dead:
        batch = other[w % len(other):][:2 * G]
        assert client.put(batch, np.arange(len(batch)) + 50_000).all_ok
        model.update(zip(batch.tolist(),
                         (np.arange(len(batch)) + 50_000).tolist()))
        w += 2 * G
        assert w < 100 * G, "detector must fire"
    rec = backend.recover_server(dead)        # online by default
    assert rec.online and rec.catch_up_pending > 0, \
        "online recovery must return with the catch-up still streaming " \
        f"(got {rec})"
    # foreground traffic DURING catch-up: correct answers while the
    # rebuilt replicas are still behind their cloned logs
    mid = client.get(keys[: 8 * G])
    assert mid.all_found
    np.testing.assert_array_equal(
        np.asarray(mid.values)[:, 0],
        [model[k] for k in keys[: 8 * G].tolist()])
    fresh = rng.choice(10 ** 6, 4 * G, replace=False) + 2 * 10 ** 6
    assert client.put(fresh, np.arange(4 * G)).all_ok
    model.update(zip(fresh.tolist(), range(4 * G)))
    assert int(backend.pending_ops()) > 0, \
        "catch-up must overlap the foreground ops, not precede them"
    client.drain()                             # end of the catch-up
    assert all(p["agree"] for p in kv.parity_report(backend.store, CFG))
    allk = np.fromiter(model.keys(), np.int64)
    g_all = client.get(allk)
    assert g_all.all_found
    np.testing.assert_array_equal(np.asarray(g_all.values)[:, 0],
                                  [model[k] for k in allk.tolist()])
    assert inj.oracle_kills == 0
    print(f"online catch-up ok (pending {rec.catch_up_pending} at "
          "recovery return)", flush=True)


def run_multi_failure(mesh) -> None:
    """Adjacent double sever (both replica holders of group 1) and the
    triple that previously raised: hash + data-item-key fallbacks
    rebuild every copy, re-replication restores R live copies, parity is
    clean after every phase.  A truly-lost configuration raises the
    typed RecoveryError naming its blockers."""
    G = mesh.devices.size
    client = make_client(mesh)
    backend = client.backend
    rng = np.random.RandomState(9)
    keys = rng.choice(10 ** 6, 16 * G, replace=False) + 1
    vals = np.arange(16 * G)
    assert client.put(keys, vals).all_ok
    client.drain()
    inj = FaultInjector(client)

    def detect_all(devs):
        probe = keys[np.isin(
            np.asarray(kv.owner_group(jnp.asarray(keys, key_dtype()), G)),
            devs, invert=True)][:G]
        for _ in range(CFG.lease_misses + 1):
            client.get(probe)
        assert set(devs) <= backend._dead

    # -- double failure: devs 2 and 3 = BOTH holders of group 1 ----------
    inj.sever(2)
    inj.sever(3)
    detect_all([2, 3])
    # degraded traffic across the hole (group 2 served by holder 4 etc.)
    r = client.get(keys)
    assert r.all_found, "degraded GETs must survive the double failure"
    inj.recover(2)      # group 1's replica here rebuilds from hash+data
    inj.recover(3)
    assert all(p["agree"] for p in kv.parity_report(backend.store, CFG)), \
        "double failure: recovery must restore full parity"
    # -- triple failure: group 2 loses hash AND both replicas ------------
    for d in (2, 3, 4):
        inj.sever(d)
    detect_all([2, 3, 4])
    inj.recover(2)      # previously: bare ValueError (no live holder);
    inj.recover(3)      # now: data-plane key scan rebuilds group 2
    inj.recover(4)
    assert all(p["agree"] for p in kv.parity_report(backend.store, CFG)), \
        "triple failure: data-plane fallback must restore full parity"
    g_all = client.get(keys)
    assert g_all.all_found
    np.testing.assert_array_equal(np.asarray(g_all.values)[:, 0], vals)
    assert inj.oracle_kills == 0, "no oracle fail_server anywhere"
    # -- truly lost: the fallback's blocker is typed and actionable ------
    for d in (2, 3, 4):
        inj.sever(d)
    detect_all([2, 3, 4])
    client.fail_data_server(6)   # the data-plane scan now cannot answer
    try:
        backend.recover_server(2)
    except kv.RecoveryError as e:
        assert e.blockers == ["data server 6"], e.blockers
    else:
        raise AssertionError("truly-lost recovery must raise the typed "
                             "RecoveryError")
    client.recover_data_server(6)
    inj.recover(2)
    inj.recover(3)
    inj.recover(4)
    assert all(p["agree"] for p in kv.parity_report(backend.store, CFG))
    print("multi-failure ok (double + triple recovered, typed error on "
          "truly-lost)", flush=True)


def run_data_server_detection(mesh) -> None:
    """Value-plane liveness: a data-server kill delivered ONLY through
    cut heartbeats.  Pre-detection GETs of the severed shard's keys are
    mirror-served (second-hop fetch, right answers, hops == 2); the data
    lease expires within the rounds bound; post-detection PUTs displace
    one hop and land; recovery from the DETECTED state + migration
    restores one-RTT reads — with zero oracle kills and zero spurious
    index demotions."""
    G = mesh.devices.size
    client = make_client(mesh)
    backend = client.backend
    rng = np.random.RandomState(13)
    keys = rng.choice(10 ** 6, 16 * G, replace=False) + 1
    vals = np.arange(16 * G)
    assert client.put(keys, vals).all_ok
    client.drain()
    dead = 4
    inj = FaultInjector(client)
    inj.sever_data(dead)
    assert dead not in backend._data_dead, \
        "sever_data must NOT update the routing view"
    dk = owned_by(keys, dead, G)
    assert len(dk), "need keys homed on the severed shard"
    r = client.get(dk)
    assert r.all_found, "pre-detection GETs must be mirror-served"
    assert bool((np.asarray(r.hops) == 2).all()), \
        "severed-shard values must arrive via the second-hop fetch"
    probe = owned_by(keys, dead, G, invert=True)[:G]
    rounds = 0
    while dead not in backend._data_dead:
        client.get(probe)
        rounds += 1
        assert rounds <= 2 * CFG.lease_misses, \
            "data lease must expire within the bound"
    assert backend.detected_data == [dead], \
        "the detector (and nothing else) must demote the data server"
    assert backend.detected == [] and not backend._dead, \
        "no index server may be demoted by a data-server failure"
    # post-detection: the degraded put variant displaces writes off the
    # dead shard (the neighbour holds them until migration)
    nk = rng.choice(10 ** 6, 8 * G, replace=False) + 3 * 10 ** 6
    nv = np.arange(8 * G) + 100
    assert client.put(nk, nv).all_ok, "displaced PUTs must land"
    assert client.get(nk).all_found
    inj.recover_data(dead)          # operator repair of a DETECTED fail
    assert dead not in backend._data_dead and not backend._data_severed
    model = dict(zip(keys.tolist(), vals.tolist()))
    model.update(zip(nk.tolist(), nv.tolist()))
    allk = np.fromiter(model.keys(), np.int64)
    g_all = client.get(allk)
    assert g_all.all_found
    np.testing.assert_array_equal(np.asarray(g_all.values)[:, 0],
                                  [model[k] for k in allk.tolist()])
    assert bool((np.asarray(g_all.hops) == 1).all()), \
        "post-recovery migration must restore one-RTT GETs"
    assert inj.oracle_kills == 0
    client.drain()
    assert all(p["agree"] for p in kv.parity_report(backend.store, CFG))
    print(f"data-server detection ok (demoted data dev {dead} in "
          f"{rounds} rounds, mirror-served through the window)",
          flush=True)


def run_idle_wall_clock(mesh) -> None:
    """Wall-clock leases with an IDLE client: after the sever, not one
    foreground op runs — the background ticker alone must age the lease
    and demote within lease_timeout_s + one tick interval (+ scheduling
    slack for a loaded CI host)."""
    wcfg = scaled(log_capacity=512, async_apply_batch=128, lease_misses=3,
                  lease_clock="wall", lease_timeout_s=0.8,
                  lease_interval_s=0.2)
    client = make_client(mesh, cfg=wcfg)
    backend = client.backend
    rng = np.random.RandomState(17)
    keys = rng.choice(10 ** 6, 8 * mesh.devices.size, replace=False) + 1
    assert client.put(keys, np.arange(len(keys))).all_ok
    client.drain()
    backend._lease_tick(bump=True)   # compile the tick op pre-sever
    assert client.start_ticker(), "wall cfg must start a ticker"
    try:
        dead = 3
        inj = FaultInjector(client)
        inj.sever(dead)
        stats0 = dict(client.stats)
        budget = wcfg.lease_timeout_s + wcfg.lease_interval_s + 3.0
        t0 = time.monotonic()
        while dead not in backend._dead:
            time.sleep(0.02)
            assert time.monotonic() - t0 <= budget, \
                f"idle detection must fire within {budget:.1f}s"
        t_detect = time.monotonic() - t0
        assert backend.detected == [dead]
        assert dict(client.stats) == stats0, \
            "detection must have used ZERO foreground ops"
        assert inj.oracle_kills == 0
    finally:
        client.stop_ticker()
    inj.recover(dead)
    assert client.get(keys).all_found
    assert all(p["agree"] for p in kv.parity_report(backend.store, wcfg))
    print(f"idle wall-clock detection ok ({t_detect:.2f}s elapsed, "
          f"timeout {wcfg.lease_timeout_s}s + tick "
          f"{wcfg.lease_interval_s}s, zero foreground ops)", flush=True)


def run_scan_completeness(mesh) -> None:
    """While BOTH holders of group 1 (devices 2 and 3) are severed, SCAN
    must name the uncovered group instead of silently omitting its range;
    the completeness retries double as observation rounds (the detector
    demotes the dead holders), and recovery restores complete=True with
    the full key set back."""
    G = mesh.devices.size
    client = make_client(mesh)
    backend = client.backend
    rng = np.random.RandomState(19)
    keys = rng.choice(10 ** 6, 16 * G, replace=False) + 1
    assert client.put(keys, np.arange(16 * G)).all_ok
    client.drain()
    s0 = client.scan(0, 10 ** 7, limit=CAP)
    assert s0.complete is True and s0.missing_groups == ()
    n0 = int(s0.count)
    inj = FaultInjector(client)
    inj.sever(2)
    inj.sever(3)                     # group 1 now has zero live holders
    s1 = client.scan(0, 10 ** 7, limit=CAP)
    assert s1.complete is False and s1.missing_groups == (1,), \
        f"scan must name the uncovered group (got {s1.missing_groups})"
    assert int(s1.count) < n0, "the missing group's range is absent"
    assert {2, 3} <= set(backend.detected), \
        "the completeness retries must have driven detection"
    inj.recover(2)
    inj.recover(3)
    s2 = client.scan(0, 10 ** 7, limit=CAP)
    assert s2.complete is True and s2.missing_groups == ()
    assert int(s2.count) == n0, "recovery must restore the full range"
    assert inj.oracle_kills == 0
    assert all(p["agree"] for p in kv.parity_report(backend.store, CFG))
    print(f"scan completeness ok (named group 1 while holders 2+3 were "
          f"severed; {n0 - int(s1.count)} keys honestly reported "
          "missing)", flush=True)


def run_telemetry_differential(mesh) -> None:
    """Telemetry counters vs the trace ground truth on the real 8-device
    protocol, kills delivered only through severed heartbeats: hops==2
    GETs counted exactly, demotions == the schedule's kills (one per
    plane), retries == the client's own accounting, zero oracle kills —
    and the final snapshot lands in test-logs/ as the CI artifact."""
    G = mesh.devices.size
    client = make_client(mesh)
    backend = client.backend
    rng = np.random.RandomState(23)
    keys = rng.choice(10 ** 6, 16 * G, replace=False) + 1
    vals = np.arange(16 * G)
    assert client.put(keys, vals).all_ok
    client.drain()
    inj = FaultInjector(client)
    # -- data-server sever: mirror-served GETs count as hops2 ------------
    dead_data = 5
    inj.sever_data(dead_data)
    dk = owned_by(keys, dead_data, G)
    assert len(dk), "need keys homed on the severed data shard"
    hops2_truth = 0
    r = client.get(dk)                  # mirror-served (undetected window)
    assert r.all_found
    hops2_truth += int((np.asarray(r.hops) == 2).sum())
    probe = owned_by(keys, dead_data, G, invert=True)[:G]
    rounds = 0
    while dead_data not in backend._data_dead:
        r = client.get(probe)
        hops2_truth += int((np.asarray(r.hops) == 2).sum())
        rounds += 1
        assert rounds <= 2 * CFG.lease_misses, "data detector must fire"
    inj.recover_data(dead_data)
    # -- index-server sever: detected demotion, then recovery ------------
    dead_idx = 2
    inj.sever(dead_idx)
    rounds = 0
    while dead_idx not in backend._dead:
        r = client.get(probe)
        hops2_truth += int((np.asarray(r.hops) == 2).sum())
        rounds += 1
        assert rounds <= 2 * CFG.lease_misses, "index detector must fire"
    inj.recover(dead_idx)
    g_all = client.get(keys)
    assert g_all.all_found
    hops2_truth += int((np.asarray(g_all.hops) == 2).sum())
    # -- the differential: counters == trace ground truth ----------------
    snap = client.metrics()
    c = snap.counters
    assert inj.oracle_kills == 0, "no oracle fail_server anywhere"
    assert c.get("put_ops", 0) == client.stats["puts"] == 16 * G
    assert c.get("get_ops", 0) == client.stats["gets"]
    assert c.get("retries", 0) == client.stats["retries"]
    assert c.get("hops2_gets", 0) == hops2_truth > 0, \
        (c.get("hops2_gets"), hops2_truth)
    assert c.get("data_demotions", 0) == 1, \
        "exactly the schedule's one data-plane kill"
    assert c.get("index_demotions", 0) == 1, \
        "exactly the schedule's one index-plane kill"
    assert c.get("data_recoveries", 0) == 1
    assert c.get("index_recoveries", 0) == 1
    assert c.get("lease_ticks", 0) > 0
    assert snap.gauges["live_index_servers"] == G
    assert snap.gauges["live_data_servers"] == G
    lat = snap.latency
    assert lat["put"].count > 0 and lat["get"].count > 0
    assert lat["get"].p99 >= lat["get"].p50 > 0.0
    logs = Path(__file__).resolve().parents[1] / "test-logs"
    logs.mkdir(exist_ok=True)
    tm.dump_metrics(snap, logs / "lease_selftest.metrics.json")
    print(f"telemetry differential ok (hops2 {hops2_truth}, retries "
          f"{c.get('retries', 0)}, one demotion per plane, zero oracle "
          "kills; snapshot -> test-logs/lease_selftest.metrics.json)",
          flush=True)


def main() -> int:
    mesh = jax.make_mesh((len(jax.devices()),), (kv.AXIS,))
    run_detection_bound(mesh)
    run_detector_trace(mesh, "uniform", 21, 5)
    run_online_catch_up(mesh)
    run_multi_failure(mesh)
    run_data_server_detection(mesh)
    run_idle_wall_clock(mesh)
    run_scan_completeness(mesh)
    run_telemetry_differential(mesh)
    print("LEASE-SELFTEST-OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
