import os
if "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
"""Lease-based failure-detection battery (8 host devices).

Spawned as a subprocess by tests/test_lease_detection.py (the dry-run
rule: only multi-device entrypoints force a host device count).  Every
kill in here is delivered by SEVERING HEARTBEATS (tests/oracle.py's
FaultInjector) — there is not a single oracle ``fail_server`` call; the
client must discover each failure through its lease detector (paper §5):

  * detection bound — after a sever, the client demotes the server to
    degraded routing in EXACTLY ``cfg.lease_misses`` observation rounds
    (heartbeat counters bumped on the mesh, aged host-side);
  * differential trace — a seeded op trace with sever/recover events
    spliced in replays result-for-result against the fault-oblivious
    oracle: pre-detection timeouts are retried, post-detection degraded
    routing serves, recovery restores parity;
  * online catch-up — recovery clones snapshots and returns with the
    pending-log delta still streaming (``RecoverResult.catch_up_pending
    > 0``); foreground PUT/GET traffic interleaves DURING the catch-up
    and stays oracle-equivalent, then the debt drains and parity holds;
  * multi-failure — an adjacent double sever (both replica holders of
    one group) and the triple that previously raised a bare ValueError:
    recovery now falls back to the primary's hash + the keys stored
    with the data items (paper: rebuild fetches keys from the data
    servers), re-replication restores R copies, and parity is clean;
    a truly-lost configuration raises the typed RecoveryError with
    actionable blockers instead.
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent))
sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.histore import scaled
from repro.core import kvstore as kv
from repro.core.client import DistributedBackend, HiStoreClient
from repro.core.hashing import key_dtype

from oracle import (FaultInjector, Oracle, assert_equivalent, gen_ops,
                    replay, splice_faults)

CFG = scaled(log_capacity=512, async_apply_batch=128, lease_misses=3)
CAP = 512
N_EVENTS = 10


def make_client(mesh, **kw):
    return HiStoreClient(
        DistributedBackend(mesh, CFG, CAP, capacity_q=64, scan_limit=128),
        batch_quantum=4 * mesh.devices.size, max_retries=32, **kw)


def owned_by(keys, dev, G, invert=False):
    own = np.asarray(kv.owner_group(jnp.asarray(keys, key_dtype()), G))
    return keys[(own != dev) if invert else (own == dev)]


def run_detection_bound(mesh) -> None:
    """Exactly lease_misses observation rounds after a sever, the client
    demotes — no sooner (no spurious demotions), no later (the bound)."""
    G = mesh.devices.size
    client = make_client(mesh)
    backend = client.backend
    keys = np.random.RandomState(1).choice(10 ** 6, 8 * G,
                                           replace=False) + 1
    assert client.put(keys, np.arange(8 * G)).all_ok
    dead = 3
    probe = owned_by(keys, dead, G, invert=True)[:G]  # no retry loops
    inj = FaultInjector(client)
    inj.sever(dead)
    for i in range(CFG.lease_misses):
        assert dead not in backend._dead, \
            f"demoted after only {i} rounds (lease bound is " \
            f"{CFG.lease_misses})"
        client.get(probe)          # one observation round
    assert backend.detected == [dead], \
        "the detector (and nothing else) must demote the severed server"
    assert inj.oracle_kills == 0
    # degraded routing now serves the dead group's keys from backups
    dk = owned_by(keys, dead, G)
    if len(dk):
        r = client.get(dk)
        assert r.all_found, "post-detection degraded GETs must serve"
    inj.recover(dead)
    assert dead not in backend._dead and not backend._severed
    assert all(p["agree"] for p in kv.parity_report(backend.store, CFG))
    print(f"detection bound ok (demoted dev {dead} in exactly "
          f"{CFG.lease_misses} rounds)", flush=True)


def run_detector_trace(mesh, mix: str, seed: int, dead_dev: int) -> None:
    """Differential replay where the kill arrives only through severed
    heartbeats: the store must stay indistinguishable from the healthy
    oracle across the undetected, degraded and post-recovery phases."""
    G = mesh.devices.size
    ops = gen_ops(seed, mix, n_events=N_EVENTS, batch=3 * G)
    trace = splice_faults(ops, [
        (N_EVENTS // 3, "sever", dead_dev),
        (2 * N_EVENTS // 3, "recover", dead_dev),
    ])
    assert not any(ev[0] == "fail" for ev in trace), \
        "detector schedule must contain zero oracle fail_server events"
    client = make_client(mesh)
    oracle = Oracle(value_words=CFG.value_words)

    def hook(c, event):
        c.drain()
        for p in kv.parity_report(c.backend.store, CFG):
            if p.get("kind") == "value_slots":
                assert p["agree"], f"value audit broke after {event}: {p}"
            elif p["primary_alive"] and p["holder_alive"]:
                assert p["agree"], f"live parity broke after {event}: {p}"

    assert_equivalent(replay(client, trace, phase_hook=hook),
                      replay(oracle, trace),
                      label=f"lease/{mix}/seed{seed}")
    assert client.backend.detected == [dead_dev], \
        "the kill must have been DISCOVERED by the lease detector"
    assert all(p["agree"]
               for p in kv.parity_report(client.backend.store, CFG))
    live = np.fromiter(oracle.model.keys(), np.int64)
    if len(live):
        g_all = client.get(live)
        assert g_all.all_found and bool(
            (np.asarray(g_all.hops) == 1).all())
    print(f"detector trace {mix} seed {seed} ok "
          f"(detected {client.backend.detected})", flush=True)


def run_online_catch_up(mesh) -> None:
    """Online recovery: the rebuild returns with pending-log debt still
    streaming; foreground ops interleave DURING the catch-up and match
    the oracle; the debt then drains through ordinary applies."""
    G = mesh.devices.size
    client = make_client(mesh, migrate_on_recover=False)
    backend = client.backend
    model = {}
    rng = np.random.RandomState(7)
    keys = rng.choice(10 ** 6, 16 * G, replace=False) + 1
    assert client.put(keys, np.arange(16 * G)).all_ok
    model.update(zip(keys.tolist(), range(16 * G)))
    client.drain()
    dead = 2
    inj = FaultInjector(client)
    inj.sever(dead)
    # ops until the lease expires (puts to live owners also build the
    # pending backlog the recovery will have to stream)
    other = owned_by(keys, dead, G, invert=True)
    w = 0
    while dead not in backend._dead:
        batch = other[w % len(other):][:2 * G]
        assert client.put(batch, np.arange(len(batch)) + 50_000).all_ok
        model.update(zip(batch.tolist(),
                         (np.arange(len(batch)) + 50_000).tolist()))
        w += 2 * G
        assert w < 100 * G, "detector must fire"
    rec = backend.recover_server(dead)        # online by default
    assert rec.online and rec.catch_up_pending > 0, \
        "online recovery must return with the catch-up still streaming " \
        f"(got {rec})"
    # foreground traffic DURING catch-up: correct answers while the
    # rebuilt replicas are still behind their cloned logs
    mid = client.get(keys[: 8 * G])
    assert mid.all_found
    np.testing.assert_array_equal(
        np.asarray(mid.values)[:, 0],
        [model[k] for k in keys[: 8 * G].tolist()])
    fresh = rng.choice(10 ** 6, 4 * G, replace=False) + 2 * 10 ** 6
    assert client.put(fresh, np.arange(4 * G)).all_ok
    model.update(zip(fresh.tolist(), range(4 * G)))
    assert int(backend.pending_ops()) > 0, \
        "catch-up must overlap the foreground ops, not precede them"
    client.drain()                             # end of the catch-up
    assert all(p["agree"] for p in kv.parity_report(backend.store, CFG))
    allk = np.fromiter(model.keys(), np.int64)
    g_all = client.get(allk)
    assert g_all.all_found
    np.testing.assert_array_equal(np.asarray(g_all.values)[:, 0],
                                  [model[k] for k in allk.tolist()])
    assert inj.oracle_kills == 0
    print(f"online catch-up ok (pending {rec.catch_up_pending} at "
          "recovery return)", flush=True)


def run_multi_failure(mesh) -> None:
    """Adjacent double sever (both replica holders of group 1) and the
    triple that previously raised: hash + data-item-key fallbacks
    rebuild every copy, re-replication restores R live copies, parity is
    clean after every phase.  A truly-lost configuration raises the
    typed RecoveryError naming its blockers."""
    G = mesh.devices.size
    client = make_client(mesh)
    backend = client.backend
    rng = np.random.RandomState(9)
    keys = rng.choice(10 ** 6, 16 * G, replace=False) + 1
    vals = np.arange(16 * G)
    assert client.put(keys, vals).all_ok
    client.drain()
    inj = FaultInjector(client)

    def detect_all(devs):
        probe = keys[np.isin(
            np.asarray(kv.owner_group(jnp.asarray(keys, key_dtype()), G)),
            devs, invert=True)][:G]
        for _ in range(CFG.lease_misses + 1):
            client.get(probe)
        assert set(devs) <= backend._dead

    # -- double failure: devs 2 and 3 = BOTH holders of group 1 ----------
    inj.sever(2)
    inj.sever(3)
    detect_all([2, 3])
    # degraded traffic across the hole (group 2 served by holder 4 etc.)
    r = client.get(keys)
    assert r.all_found, "degraded GETs must survive the double failure"
    inj.recover(2)      # group 1's replica here rebuilds from hash+data
    inj.recover(3)
    assert all(p["agree"] for p in kv.parity_report(backend.store, CFG)), \
        "double failure: recovery must restore full parity"
    # -- triple failure: group 2 loses hash AND both replicas ------------
    for d in (2, 3, 4):
        inj.sever(d)
    detect_all([2, 3, 4])
    inj.recover(2)      # previously: bare ValueError (no live holder);
    inj.recover(3)      # now: data-plane key scan rebuilds group 2
    inj.recover(4)
    assert all(p["agree"] for p in kv.parity_report(backend.store, CFG)), \
        "triple failure: data-plane fallback must restore full parity"
    g_all = client.get(keys)
    assert g_all.all_found
    np.testing.assert_array_equal(np.asarray(g_all.values)[:, 0], vals)
    assert inj.oracle_kills == 0, "no oracle fail_server anywhere"
    # -- truly lost: the fallback's blocker is typed and actionable ------
    for d in (2, 3, 4):
        inj.sever(d)
    detect_all([2, 3, 4])
    client.fail_data_server(6)   # the data-plane scan now cannot answer
    try:
        backend.recover_server(2)
    except kv.RecoveryError as e:
        assert e.blockers == ["data server 6"], e.blockers
    else:
        raise AssertionError("truly-lost recovery must raise the typed "
                             "RecoveryError")
    client.recover_data_server(6)
    inj.recover(2)
    inj.recover(3)
    inj.recover(4)
    assert all(p["agree"] for p in kv.parity_report(backend.store, CFG))
    print("multi-failure ok (double + triple recovered, typed error on "
          "truly-lost)", flush=True)


def main() -> int:
    mesh = jax.make_mesh((len(jax.devices()),), (kv.AXIS,))
    run_detection_bound(mesh)
    run_detector_trace(mesh, "uniform", 21, 5)
    run_online_catch_up(mesh)
    run_multi_failure(mesh)
    print("LEASE-SELFTEST-OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
