"""Differential-testing oracle for the HiStore client surface.

A plain-Python reference model (dict + sorted list) that consumes the same
Put/Get/Delete/Scan trace as a real backend, plus:

  * ``gen_ops``      — seeded trace generator with workload mixes
                       (uniform / zipfian / scan_heavy / delete_heavy);
  * ``splice_faults``— deterministic fault schedule: kill/recover events
                       inserted at trace offsets;
  * ``replay``       — drive any client-shaped system through a trace,
                       recording normalized observations;
  * ``assert_equivalent`` — result-for-result comparison of two replays.

The oracle is FAULT-OBLIVIOUS: kill/recover events are no-ops for it.
That is the point — HiStore's availability claim (paper §4.3) is that
GET/SCAN/DELETE answers are indistinguishable from a healthy store in the
degraded and post-recovery phases, so the reference model never needs to
know a failure happened.

Used by tests/test_fault_injection.py (in-process, LocalBackend and the
single-device DistributedBackend) and tests/fault_selftest.py (8-device
subprocess battery).
"""
from __future__ import annotations

import numpy as np

from repro.core.results import (DeleteResult, GetResult, PutResult,
                                ScanResult)

MIXES = {
    #                 put   get   delete scan
    "uniform":      (0.45, 0.35, 0.10, 0.10),
    "zipfian":      (0.45, 0.35, 0.10, 0.10),
    "scan_heavy":   (0.35, 0.20, 0.10, 0.35),
    "delete_heavy": (0.35, 0.20, 0.35, 0.10),
}


class Oracle:
    """dict + sorted-list reference model with the HiStoreClient result
    API, so ``replay`` can drive it interchangeably with a real client."""

    def __init__(self, value_words: int = 4):
        self.model: dict[int, int] = {}
        self.value_words = value_words

    def put(self, keys, values) -> PutResult:
        keys = np.asarray(keys)
        values = np.asarray(values)
        for k, v in zip(keys.tolist(), values.tolist()):
            self.model[int(k)] = int(v)
        q = keys.shape[0]
        return PutResult(np.ones((q,), bool), np.full((q,), -1, np.int32),
                         0, None)

    def get(self, keys) -> GetResult:
        keys = np.asarray(keys)
        q = keys.shape[0]
        found = np.array([int(k) in self.model for k in keys], bool)
        vals = np.zeros((q, self.value_words), np.int32)
        for i, k in enumerate(keys.tolist()):
            if int(k) in self.model:
                vals[i, :] = self.model[int(k)]
        return GetResult(np.full((q,), -1, np.int32), found,
                         np.zeros((q,), np.int32), vals)

    def delete(self, keys) -> DeleteResult:
        keys = np.asarray(keys)
        found = []
        for k in keys.tolist():
            found.append(int(k) in self.model)
            self.model.pop(int(k), None)
        return DeleteResult(np.ones((keys.shape[0],), bool),
                            np.array(found, bool), 0, None)

    def scan(self, lo, hi, limit: int) -> ScanResult:
        ks = sorted(k for k in self.model if int(lo) <= k <= int(hi))[:limit]
        return ScanResult(np.array(ks, np.int64),
                          np.full((len(ks),), -1, np.int32),
                          np.int32(len(ks)), True, ())

    # fault events are no-ops: the model IS the always-healthy truth
    def fail_server(self, server: int) -> None:
        pass

    def sever_server(self, server: int) -> None:
        pass

    def recover_server(self, server: int) -> None:
        pass

    def fail_data_server(self, server: int) -> None:
        pass

    def sever_data_server(self, server: int) -> None:
        pass

    def recover_data_server(self, server: int) -> None:
        pass


class FaultInjector:
    """Heartbeat-severing fault injector: kills are delivered by cutting
    a server's heartbeats (``sever``), NEVER by calling the oracle
    ``fail_server`` — the client under test must DISCOVER each failure
    through its lease detector (paper §5).  Wraps the system so a replay
    trace's "sever" events route here, and records every injection so a
    battery can assert zero oracle kills happened."""

    def __init__(self, system):
        self.system = system
        self.injected: list = []

    def sever(self, server: int):
        self.injected.append(("sever", server))
        return self.system.sever_server(server)

    def sever_data(self, server: int):
        """Value-plane kill through cut heartbeats: the client's data
        lease must expire before its routing view changes (the unified
        liveness plane's detector covers data servers too)."""
        self.injected.append(("sever_data", server))
        return self.system.sever_data_server(server)

    def fail(self, server: int):
        """Oracle kill (client told instantly) — recorded so a detector
        schedule's ``oracle_kills == 0`` assertion is falsifiable."""
        self.injected.append(("fail", server))
        return self.system.fail_server(server)

    def fail_data(self, server: int):
        """Oracle data-server kill — also counted against the detector
        schedule's ``oracle_kills == 0`` assertion."""
        self.injected.append(("fail_data", server))
        return self.system.fail_data_server(server)

    def recover(self, server: int):
        """Operator-initiated repair (detection is the client's job;
        re-provisioning a machine is not)."""
        self.injected.append(("recover", server))
        return self.system.recover_server(server)

    def recover_data(self, server: int):
        self.injected.append(("recover_data", server))
        return self.system.recover_data_server(server)

    @property
    def oracle_kills(self) -> int:
        """Count of direct fail_server/fail_data_server calls made
        through this injector — a detector schedule asserts it stays 0."""
        return sum(1 for k, _ in self.injected
                   if k in ("fail", "fail_data"))


# ---------------------------------------------------------------------------
# Trace generation
# ---------------------------------------------------------------------------
def _draw_keys(rng, pool, batch, universe, mix, hit_rate=0.7):
    """A batch of keys: mostly re-reads of written keys (hits), the rest
    fresh draws (probable misses); zipfian skews toward hot ranks."""
    out = []
    for _ in range(batch):
        if pool and rng.rand() < hit_rate:
            out.append(pool[rng.randint(len(pool))])
        elif mix == "zipfian":
            rank = int(rng.zipf(1.3))
            out.append(1 + (rank * 48271) % universe)
        else:
            out.append(1 + int(rng.randint(universe)))
    return np.array(out, np.int64)


def gen_ops(seed: int, mix: str = "uniform", n_events: int = 12,
            batch: int = 24, universe: int = 10 ** 6,
            scan_limit: int = 128) -> list:
    """Deterministic op trace for one workload mix.  Every batch op uses
    the same ``batch`` size so jitted backends compile each op once.
    Events: ("put", keys, vals) / ("get", keys) / ("delete", keys) /
    ("scan", lo, hi, limit)."""
    assert mix in MIXES, f"unknown mix {mix!r}"
    p_put, p_get, p_del, p_scan = MIXES[mix]
    rng = np.random.RandomState(seed)
    pool: list[int] = []
    events = []
    for i in range(n_events):
        kind = rng.choice(["put", "get", "delete", "scan"],
                          p=[p_put, p_get, p_del, p_scan])
        if i == 0:
            kind = "put"            # something to read back
        if kind == "put":
            keys = _draw_keys(rng, pool, batch, universe, mix)
            vals = rng.randint(1, 1 << 20, batch).astype(np.int64)
            pool.extend(int(k) for k in keys)
            pool = pool[-5000:]
            events.append(("put", keys, vals))
        elif kind == "get":
            events.append(("get", _draw_keys(rng, pool, batch, universe,
                                             mix)))
        elif kind == "delete":
            # sequential oracle semantics vs batched backend semantics
            # diverge on duplicate keys within one batch: dedupe here
            keys = _draw_keys(rng, pool, batch, universe, mix)
            _, first = np.unique(keys, return_index=True)
            events.append(("delete", keys[np.sort(first)]))
        else:
            lo = int(rng.randint(universe))
            hi = min(universe, lo + int(rng.randint(1, universe // 2)))
            events.append(("scan", lo, hi, scan_limit))
    return events


FAULT_KINDS = ("fail", "sever", "recover", "fail_data", "sever_data",
               "recover_data")


def splice_faults(events: list, schedule: list) -> list:
    """Insert ("fail"|"sever"|"recover"|"fail_data"|"sever_data"|
    "recover_data", server) events at trace offsets — index-server and
    data-server failures are separate domains (paper §2), and
    "sever"/"sever_data" deliver a kill through cut heartbeats that the
    client must detect itself (no oracle fail_server).  ``schedule``: [(offset, kind,
    server), ...]; offsets index the ORIGINAL op trace, so a schedule is
    portable across backends."""
    out = list(events)
    for off, kind, server in sorted(schedule, reverse=True):
        assert kind in FAULT_KINDS
        out.insert(off, (kind, server))
    return out


# ---------------------------------------------------------------------------
# Replay + comparison
# ---------------------------------------------------------------------------
def replay(system, trace: list, phase_hook=None) -> list:
    """Drive a client-shaped system through a trace.  Returns one
    normalized observation per event (plain Python, comparable with ==):

      put    -> ("put", ok...)
      get    -> ("get", found..., value-if-found...)
      delete -> ("delete", ok..., found...)
      scan   -> ("scan", count, keys...)
      fail / recover / fail_data / recover_data -> echoed marker

    ``phase_hook(system, event)``, if given, runs after every fault event
    (each phase boundary) and once at the end of the trace — the hook the
    fault harness uses to assert parity / value-slot accounting per
    phase."""
    obs = []
    for ev in trace:
        kind = ev[0]
        if kind == "put":
            r = system.put(ev[1], ev[2])
            obs.append(("put", tuple(np.asarray(r.ok).tolist())))
        elif kind == "get":
            r = system.get(ev[1])
            f = np.asarray(r.found).astype(bool)
            v = np.asarray(r.values)[:, 0] * f
            obs.append(("get", tuple(f.tolist()), tuple(int(x) for x in v)))
        elif kind == "delete":
            r = system.delete(ev[1])
            obs.append(("delete", tuple(np.asarray(r.ok).tolist()),
                        tuple(np.asarray(r.found).astype(bool).tolist())))
        elif kind == "scan":
            r = system.scan(ev[1], ev[2], ev[3])
            n = int(r.count)
            obs.append(("scan", n,
                        tuple(int(k) for k in np.asarray(r.keys)[:n])))
        elif kind in FAULT_KINDS:
            getattr(system, kind + "_server")(ev[1])
            obs.append((kind, ev[1]))
            if phase_hook is not None:
                phase_hook(system, ev)
        else:  # pragma: no cover
            raise ValueError(f"unknown event {kind!r}")
    if phase_hook is not None:
        phase_hook(system, ("end",))
    return obs


def assert_equivalent(obs_a: list, obs_b: list, label: str = "") -> None:
    """Result-for-result equality of two replays of the same trace."""
    assert len(obs_a) == len(obs_b), (len(obs_a), len(obs_b))
    for i, (a, b) in enumerate(zip(obs_a, obs_b)):
        assert a == b, (
            f"{label} diverged at event {i} ({a[0]}):\n  A={a}\n  B={b}")
