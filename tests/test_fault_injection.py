"""Trace-driven differential fault-injection tests (paper §4.3).

The same seeded op trace + deterministic fault schedule is replayed
through a real backend and the plain-Python oracle (tests/oracle.py); the
store must be indistinguishable from an always-healthy reference across
the healthy, primary-dead, backup-dead and post-recovery phases, and
recovery must restore hash/sorted parity on the failed shard.

Three rigs:
  * LocalBackend, in-process — full fault schedule (primary + backup
    kill/recover) against the one index group;
  * DistributedBackend on this process's single-device mesh — healthy
    differential (routing / exchange / fetch paths; a 1-device mesh folds
    every replica onto the failing server, so faults are not meaningful);
  * the 8-device subprocess battery (tests/fault_selftest.py) — the real
    distributed kill/recover protocol, marked ``slow``.
"""
from pathlib import Path

import jax
import numpy as np
import pytest

from repro.configs.histore import scaled
from repro.core import hash_index as hi
from repro.core import index_group as ig
from repro.core import kvstore as kv
from repro.core import sorted_index as si
from repro.core.client import (DistributedBackend, HiStoreClient,
                               LocalBackend)

from oracle import Oracle, assert_equivalent, gen_ops, replay, splice_faults

ROOT = Path(__file__).resolve().parents[1]
CFG = scaled(log_capacity=1 << 10, async_apply_batch=256)
N_EVENTS = 16


def _local_parity_ok(backend: LocalBackend) -> bool:
    """After a drain, every sorted replica must hold exactly the hash
    table's live items, with agreeing addresses — and the value-slot
    bitmap must hold exactly one allocated slot per live item."""
    g = ig.drain(backend.group, backend.cfg)
    n_hash = int(hi.n_items(g.hash))
    for r in range(backend.cfg.n_backups):
        srt = jax.tree.map(lambda a: a[r], g.sorted)
        keys, addrs, valid = si.items(srt)
        if int(valid.sum()) != n_hash:
            return False
        a_h, f_h, _ = hi.lookup(g.hash, keys, backend.cfg)
        if not bool(np.asarray(f_h | ~valid).all()):
            return False
        if not bool(np.asarray((a_h == addrs) | ~valid).all()):
            return False
    return _local_slots_ok(backend)


def _local_slots_ok(backend: LocalBackend) -> bool:
    """Value-slot accounting on the local shard: every live index address
    holds an allocated slot, no slot is double-referenced or orphaned.
    Authority is the hash table, or a live drained replica while the
    primary is masked dead — so the audit also holds mid-failure."""
    g = ig.drain(backend.group, backend.cfg)
    if backend._primary_alive:
        addrs = np.asarray(g.hash.addr)[np.asarray(hi.valid_mask(g.hash))]
    else:
        rep = next(i for i, a in enumerate(backend._backups_alive) if a)
        srt = jax.tree.map(lambda a: a[rep], g.sorted)
        _, addrs_all, valid = si.items(srt)
        addrs = np.asarray(addrs_all)[np.asarray(valid)]
    used = np.asarray(backend.used)
    return (int(used.sum()) == len(addrs)
            and len(np.unique(addrs)) == len(addrs)
            and bool(used[addrs].all() if len(addrs) else True))


def _local_phase_hook(client, _event):
    """Asserted after every kill/recover phase boundary: slot accounting
    never breaks, whatever the index plane's failure state."""
    if isinstance(client.backend, LocalBackend):
        assert _local_slots_ok(client.backend), \
            "value-slot accounting must hold across every phase"


def _dist_phase_hook(client, _event):
    """Mid-trace parity: the value-slot audit must hold in EVERY phase;
    hash/replica agreement is asserted for structures whose primary and
    holder are both alive (wiped structures rebuild at recovery)."""
    if not isinstance(client.backend, DistributedBackend):
        return
    for p in kv.parity_report(client.backend.store, client.backend.cfg):
        if p.get("kind") == "value_slots":
            assert p["agree"], f"value-slot audit broke mid-trace: {p}"
        elif p["primary_alive"] and p["holder_alive"]:
            assert p["agree"], f"live-structure parity broke mid-trace: {p}"


@pytest.mark.parametrize("mix,seed", [("uniform", 1), ("zipfian", 2),
                                      ("scan_heavy", 3),
                                      ("delete_heavy", 4)])
def test_local_vs_oracle_under_faults(mix, seed):
    """Full kill/recover schedule on the local group: primary dies (wiped)
    mid-trace and is rebuilt from a replica, then a backup dies and is
    re-cloned.  Every observation must match the fault-oblivious oracle."""
    ops = gen_ops(seed, mix, n_events=N_EVENTS, batch=16)
    schedule = [
        (N_EVENTS // 4, "fail", 0),          # primary down (hash wiped)
        (N_EVENTS // 2, "recover", 0),       # hash rebuilt from replica
        (5 * N_EVENTS // 8, "fail", 1),      # backup 0 down (replica wiped)
        (7 * N_EVENTS // 8, "recover", 1),   # replica re-cloned
    ]
    trace = splice_faults(ops, schedule)
    backend = LocalBackend(4096, CFG)
    client = HiStoreClient(backend, batch_quantum=16)
    oracle = Oracle(value_words=CFG.value_words)
    assert_equivalent(replay(client, trace, phase_hook=_local_phase_hook),
                      replay(oracle, trace), label=f"local/{mix}")
    assert _local_parity_ok(backend), \
        "recovery must restore hash/sorted parity"


@pytest.mark.parametrize("mix,seed", [("uniform", 5), ("zipfian", 6),
                                      ("delete_heavy", 7)])
def test_dist_single_device_vs_oracle(mix, seed):
    """The shard_map'd store on this process's 1-device mesh must be
    trace-equivalent to the oracle (healthy phases: routing, exchange,
    value fetch, scan drain)."""
    mesh = jax.make_mesh((len(jax.devices()),), (kv.AXIS,))
    trace = gen_ops(seed, mix, n_events=N_EVENTS, batch=16)
    client = HiStoreClient(
        DistributedBackend(mesh, CFG, 4096, capacity_q=64, scan_limit=128),
        batch_quantum=16, max_retries=32)
    oracle = Oracle(value_words=CFG.value_words)
    assert_equivalent(replay(client, trace, phase_hook=_dist_phase_hook),
                      replay(oracle, trace), label=f"dist1/{mix}")
    assert all(p["agree"]
               for p in kv.parity_report(client.backend.store, CFG))


def test_local_replication_reported_honestly():
    """PUT/DELETE report n_backups replicas healthy, fewer when a backup
    is masked dead, and full replication again after recovery."""
    backend = LocalBackend(2048, CFG)
    client = HiStoreClient(backend, batch_quantum=16)
    keys = np.arange(1, 17)
    assert bool((client.put(keys, keys).replicas == CFG.n_backups).all())
    client.fail_server(1)                     # backup 0 down
    r = client.put(keys + 100, keys)
    assert bool((r.replicas == CFG.n_backups - 1).all())
    d = client.delete(keys[:4])
    assert bool((d.replicas == CFG.n_backups - 1).all())
    client.recover_server(1)
    assert bool(
        (client.put(keys + 200, keys).replicas == CFG.n_backups).all())
    assert _local_parity_ok(backend)


@pytest.mark.slow
def test_fault_injection_distributed_8dev():
    """The real distributed kill/recover protocol, differentially checked
    against the oracle on an 8-device host mesh (subprocess)."""
    from _battery import run_battery
    proc = run_battery(ROOT / "tests/fault_selftest.py", "fault_selftest",
                       extra_pythonpath=[ROOT / "tests"])
    assert proc.returncode == 0, proc.stdout[-2000:] + proc.stderr[-2000:]
    assert "FAULT-SELFTEST-OK" in proc.stdout
