"""Distributed KV-store protocol test.

Runs the 8-device battery in a SUBPROCESS (the dry-run rule: only the
multi-device entrypoints force a host device count; this pytest process
keeps its 1-device view).  See src/repro/core/dist_selftest.py for the
checks: routed PUT/GET roundtrip, value payloads, SCAN serializability,
degraded GET/PUT under primary failure, recovery.
"""
from pathlib import Path

import pytest

from _battery import run_battery

ROOT = Path(__file__).resolve().parents[1]


@pytest.mark.slow
def test_distributed_kvstore_protocol():
    proc = run_battery(ROOT / "src/repro/core/dist_selftest.py",
                       "dist_selftest")
    assert proc.returncode == 0, proc.stdout[-2000:] + proc.stderr[-2000:]
    assert "DIST-SELFTEST-OK" in proc.stdout
