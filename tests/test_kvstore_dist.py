"""Distributed KV-store protocol test.

Runs the 8-device battery in a SUBPROCESS (the dry-run rule: only the
multi-device entrypoints force a host device count; this pytest process
keeps its 1-device view).  See src/repro/core/dist_selftest.py for the
checks: routed PUT/GET roundtrip, value payloads, SCAN serializability,
degraded GET/PUT under primary failure, recovery.
"""
import os
import subprocess
import sys
from pathlib import Path

import pytest

ROOT = Path(__file__).resolve().parents[1]


@pytest.mark.slow
def test_distributed_kvstore_protocol():
    env = dict(os.environ,
               PYTHONPATH=str(ROOT / "src"),
               XLA_FLAGS="--xla_force_host_platform_device_count=8")
    proc = subprocess.run(
        [sys.executable, str(ROOT / "src/repro/core/dist_selftest.py")],
        env=env, capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stdout[-2000:] + proc.stderr[-2000:]
    assert "DIST-SELFTEST-OK" in proc.stdout
