"""Serving engine tests: continuous batching, hybrid-index page directory
(PUT on page fill, SCAN-based release, prefix-reuse GET hits)."""
import jax
import numpy as np

from repro.configs.tiny import tiny_config
from repro.core import hash_index as hix
from repro.models.transformer import init_params
from repro.serving.engine import ServingEngine


def _engine(arch="musicgen-large", **kw):
    cfg = tiny_config(arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    return ServingEngine(cfg, params, batch_slots=3, max_len=64,
                         page_size=8, **kw), cfg


def test_batched_generation_completes():
    eng, cfg = _engine()
    rids = [eng.submit([1, 2, 3, 4], max_new=6) for _ in range(5)]
    steps = eng.run()
    assert steps > 0
    assert not eng.queue and all(s is None for s in eng.slots)
    assert eng.stats["decode_steps"] >= 10   # 5 reqs over 3 slots -> 2 waves


def test_page_directory_put_scan_release():
    eng, cfg = _engine()
    eng.submit(list(range(1, 9)), max_new=16)   # 8 prompt + 16 new = 3 pages
    free_before = len(eng.free_pages)
    eng.run()
    s = eng.stats
    assert s["pages_registered"] >= 2
    assert s["index_scans"] >= 1                 # release went through SCAN
    assert s["pages_freed"] >= s["pages_registered"] - 1
    # all pages returned to the free pool
    assert len(eng.free_pages) >= free_before - 1
    # directory is empty again (deletes applied)
    assert int(hix.n_items(eng.directory.hash)) <= 1  # prefix key may remain


def test_prefix_reuse_hits():
    eng, cfg = _engine()
    prompt = [5, 6, 7, 8]
    eng.submit(prompt, max_new=4)
    eng.run()
    eng.submit(prompt, max_new=4)                # same prefix -> hash hit
    assert eng.stats["prefix_hits"] == 1
    eng.run()
    assert eng.stats["index_gets"] == 2
