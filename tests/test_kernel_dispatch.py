"""The kernel-dispatch parity battery (ISSUE: Pallas-kernelized hot path).

Every routed primitive in repro.kernels.ops must be BIT-EXACT across the
``use_kernels`` knob: the seeded loops below hold probe / search / merge /
range_query / sort / backup_probe / group_probe to array equality between
cfg.use_kernels="on" (Pallas, interpret mode off-TPU) and "off" (the
pure-jnp reference), including tombstones, pending-window collisions,
multi-selected replica lanes (the G==1 wrap), and INF edges.  On top:

  * knob resolution ("on"/"off"/"auto", HISTORE_USE_KERNELS env override,
    config validation);
  * hypothesis property tests of the fused kernels vs kernels/ref.py
    (skip when hypothesis isn't installed; the seeded loops always run);
  * client-level parity: identical seeded traces through HiStoreClient on
    BOTH backends under both knob settings, differential-oracle replay
    with kernels on, and parity_report agreement;
  * the Backend protocol contract (core/backend.py);
  * import-order regression (kernels<->core cycle) and the deprecation
    shims for the old per-kernel module homes.
"""
from __future__ import annotations

import subprocess
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from oracle import Oracle, assert_equivalent, gen_ops, replay
from repro.configs.histore import scaled
from repro.core import hash_index as hix
from repro.core import kvstore as kv
from repro.core import log as lg
from repro.core import sorted_index as six
from repro.core.backend import Backend
from repro.core.client import (DistributedBackend, HiStoreClient,
                               LocalBackend)
from repro.kernels import ops as kops
from repro.kernels import ref

ROOT = Path(__file__).resolve().parents[1]
I32 = jnp.int32
INF32 = jnp.iinfo(jnp.int32).max

CFG_ON = scaled(use_kernels="on")
CFG_OFF = scaled(use_kernels="off")


def _eq(xs, ys, label=""):
    for i, (x, y) in enumerate(zip(xs, ys)):
        np.testing.assert_array_equal(
            np.asarray(x), np.asarray(y),
            err_msg=f"{label}: output {i} diverges across use_kernels")


# ---------------------------------------------------------------------------
# knob resolution
# ---------------------------------------------------------------------------
def test_knob_resolution(monkeypatch):
    monkeypatch.delenv(kops.ENV_KNOB, raising=False)
    assert kops.kernels_enabled(CFG_ON) is True
    assert kops.kernels_enabled(CFG_OFF) is False
    auto = scaled(use_kernels="auto")
    assert kops.kernels_enabled(auto) == (jax.default_backend() == "tpu")
    monkeypatch.setenv(kops.ENV_KNOB, "on")
    assert kops.kernels_enabled(auto) is True
    assert kops.kernels_enabled(CFG_OFF) is False   # explicit beats env
    monkeypatch.setenv(kops.ENV_KNOB, "off")
    assert kops.kernels_enabled(auto) is False
    assert kops.kernels_enabled(CFG_ON) is True


def test_knob_validation():
    with pytest.raises(ValueError, match="use_kernels"):
        scaled(use_kernels="maybe")


def test_active_path():
    assert kops.active_path(CFG_OFF) == "jnp"
    assert kops.active_path(CFG_ON) == "kernel"
    assert kops.active_path(CFG_ON, key_dtype=jnp.int64) == "jnp"
    assert kops.active_path(CFG_ON, key_dtype=jnp.int32) == "kernel"


# ---------------------------------------------------------------------------
# seeded structures (tombstones, pending collisions) shared by the loops
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def seeded():
    rng = np.random.RandomState(11)
    hidx = hix.create(2048, CFG_ON)
    keys = jnp.asarray(rng.choice(10 ** 6, 900, replace=False).astype(
        np.int32))
    hidx, ok = hix.insert(hidx, keys, jnp.arange(900, dtype=I32), CFG_ON)
    assert bool(np.asarray(ok).all())
    hidx, _ = hix.delete(hidx, keys[:120], CFG_ON)   # tombstones
    # re-insert a few over the tombstones (slot reuse below fill)
    hidx, _ = hix.insert(hidx, keys[:30],
                         jnp.arange(30, dtype=I32) + 5000, CFG_ON)

    srt = six.create(1 << 13, dtype=jnp.int32)
    skeys = jnp.asarray(np.sort(rng.choice(10 ** 6, 3000,
                                           replace=False)).astype(np.int32))
    srt = six.bulk_load(srt, skeys, jnp.arange(3000, dtype=I32))

    R = CFG_ON.n_backups
    stack = lambda t: jax.tree.map(
        lambda a: jnp.broadcast_to(a[None], (R,) + a.shape).copy(), t)
    srt_r = stack(srt)
    blogs = stack(lg.create(512, jnp.int32))
    # pending-window collisions on replica 0: PUTs then newer DELs over
    # the same keys (newest-wins must pick the DEL), and a ring that has
    # already wrapped past applied > 0
    l0 = jax.tree.map(lambda a: a[0], blogs)
    l0, _ = lg.append(l0, skeys[:60], jnp.full((60,), 9000, I32),
                      jnp.full((60,), 1, jnp.int8))
    l0 = l0._replace(applied=l0.applied + 10)
    l0, _ = lg.append(l0, skeys[:25], jnp.full((25,), -1, I32),
                      jnp.full((25,), 2, jnp.int8))
    blogs = jax.tree.map(lambda f, v: f.at[0].set(v), blogs, l0)
    queries = jnp.concatenate(
        [keys[:150], skeys[:150], skeys[:100] + 1,
         jnp.asarray(rng.randint(0, 10 ** 6, 100).astype(np.int32))])
    return dict(hidx=hidx, keys=keys, srt=srt, skeys=skeys, srt_r=srt_r,
                blogs=blogs, queries=queries, rng=rng)


def test_probe_parity(seeded):
    _eq(kops.probe(CFG_ON, seeded["hidx"], seeded["queries"]),
        kops.probe(CFG_OFF, seeded["hidx"], seeded["queries"]), "probe")


def test_probe_parity_empty_index(seeded):
    empty = hix.create(2048, CFG_ON)
    _eq(kops.probe(CFG_ON, empty, seeded["queries"]),
        kops.probe(CFG_OFF, empty, seeded["queries"]), "probe/empty")


def test_search_parity(seeded):
    _eq(kops.search(CFG_ON, seeded["srt"], seeded["queries"]),
        kops.search(CFG_OFF, seeded["srt"], seeded["queries"]), "search")


def test_range_query_parity_edges(seeded):
    sk = np.asarray(seeded["skeys"])
    for lo in [int(sk[0]) - 5, int(sk[0]), int(sk[1500]), int(sk[-1]),
               int(sk[-1]) + 10, INF32]:
        hi = min(lo + 100000, INF32 - 1)
        _eq(kops.range_query(CFG_ON, seeded["srt"], lo, hi, 64),
            kops.range_query(CFG_OFF, seeded["srt"], lo, hi, 64),
            f"range_query lo={lo}")


def test_merge_parity(seeded):
    rng = np.random.RandomState(23)
    srt = seeded["srt"]
    sk = np.asarray(seeded["skeys"])
    for trial in range(4):
        m = [1, 7, 128, 300][trial]
        bk = jnp.asarray(np.concatenate(
            [sk[:m // 2], rng.choice(10 ** 6, m - m // 2)]).astype(np.int32))
        ba = jnp.asarray(rng.randint(0, 10 ** 6, m).astype(np.int32))
        bo = jnp.asarray(rng.choice([0, 1, 1, 2], m).astype(np.int8))
        a = kops.merge(CFG_ON, srt, bk, ba, bo)
        b = kops.merge(CFG_OFF, srt, bk, ba, bo)
        _eq(a, b, f"merge m={m}")
    # all-invalid batch (op 0 everywhere): a no-op apply round
    bo0 = jnp.zeros((16,), jnp.int8)
    _eq(kops.merge(CFG_ON, srt, bk[:16], ba[:16], bo0),
        kops.merge(CFG_OFF, srt, bk[:16], ba[:16], bo0), "merge noop")


def test_backup_probe_parity(seeded):
    rng = np.random.RandomState(31)
    q = seeded["queries"]
    R = CFG_ON.n_backups
    # random selections including zero-selected and multi-selected lanes
    # (the G==1 wrap: the LAST selected replica must answer)
    sel = jnp.asarray(rng.randint(0, 2, (q.shape[0], R)).astype(np.int32))
    _eq(kops.backup_probe(CFG_ON, seeded["srt_r"], seeded["blogs"], q, sel),
        kops.backup_probe(CFG_OFF, seeded["srt_r"], seeded["blogs"], q,
                          sel), "backup_probe")
    all_sel = jnp.ones((q.shape[0], R), I32)
    _eq(kops.backup_probe(CFG_ON, seeded["srt_r"], seeded["blogs"], q,
                          all_sel),
        kops.backup_probe(CFG_OFF, seeded["srt_r"], seeded["blogs"], q,
                          all_sel), "backup_probe/all-selected")


def test_group_probe_parity(seeded):
    rng = np.random.RandomState(37)
    q = seeded["queries"]
    R = CFG_ON.n_backups
    sel = jnp.asarray(rng.randint(0, 2, (q.shape[0], R)).astype(np.int32))
    _eq(kops.group_probe(CFG_ON, seeded["hidx"], seeded["srt_r"],
                         seeded["blogs"], q, sel),
        kops.group_probe(CFG_OFF, seeded["hidx"], seeded["srt_r"],
                         seeded["blogs"], q, sel), "group_probe")


def test_sort_parity_stability(seeded):
    rng = np.random.RandomState(41)
    keys = jnp.asarray(rng.randint(0, 13, (6, 256)).astype(np.int32))
    vals = jnp.arange(6 * 256, dtype=I32).reshape(6, 256)   # distinct ids
    _eq(kops.sort(CFG_ON, keys, vals), kops.sort(CFG_OFF, keys, vals),
        "sort")


def test_int64_keys_fall_back_to_jnp():
    """The raw-key kernels need the int32 codec: under jax_enable_x64 an
    int64 SortedIndex must serve through the jnp path (bit-exact with
    use_kernels=off) instead of crashing or truncating.  Runs in a
    subprocess — x64 is a process-wide switch."""
    code = """
import numpy as np, jax.numpy as jnp
from repro.configs.histore import scaled
from repro.core import sorted_index as six
from repro.kernels import ops as kops
CFG_ON, CFG_OFF = scaled(use_kernels="on"), scaled(use_kernels="off")
assert kops.active_path(CFG_ON, key_dtype=jnp.int64) == "jnp"
srt = six.create(1 << 10, dtype=jnp.int64)
keys = jnp.asarray(np.unique(np.random.RandomState(5).randint(
    0, 2 ** 40, 400).astype(np.int64))[:200])
srt = six.bulk_load(srt, keys, jnp.arange(200, dtype=jnp.int32))
assert srt.keys.dtype == jnp.int64
q = jnp.concatenate([keys[:50], keys[:50] + 1])
for a, b in zip(kops.search(CFG_ON, srt, q), kops.search(CFG_OFF, srt, q)):
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
for a, b in zip(kops.range_query(CFG_ON, srt, int(keys[3]), int(keys[-1]), 32),
                kops.range_query(CFG_OFF, srt, int(keys[3]), int(keys[-1]), 32)):
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
print('ok')
"""
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, cwd=ROOT,
                       env={**__import__('os').environ,
                            "PYTHONPATH": str(ROOT / "src"),
                            "JAX_ENABLE_X64": "1"})
    assert r.returncode == 0 and "ok" in r.stdout, r.stderr


# ---------------------------------------------------------------------------
# hypothesis property tests vs kernels/ref.py (skip without hypothesis)
# ---------------------------------------------------------------------------
@given(st.integers(0, 2 ** 31 - 2), st.integers(1, 96), st.integers(0, 40))
@settings(max_examples=20, deadline=None)
def test_prop_probe_vs_ref(seed, q, ndel):
    rng = np.random.RandomState(seed % (2 ** 31))
    hidx = hix.create(512, CFG_ON)
    keys = jnp.asarray(rng.choice(10 ** 5, 200, replace=False).astype(
        np.int32))
    hidx, _ = hix.insert(hidx, keys, jnp.arange(200, dtype=I32), CFG_ON)
    hidx, _ = hix.delete(hidx, keys[:ndel], CFG_ON)
    queries = jnp.asarray(rng.randint(0, 10 ** 5, q).astype(np.int32))
    b, sig, fp = hix.descriptors(hidx, queries)
    want = ref.ref_hash_probe(b, sig, fp, hidx.sig, hidx.fp, hidx.addr,
                              slots_per_bucket=CFG_ON.slots_per_bucket)
    got = kops.probe(CFG_ON, hidx, queries)
    _eq((got[0], got[1].astype(I32), got[2]), want, "prop probe vs ref")


@given(st.integers(0, 2 ** 31 - 2), st.integers(1, 96))
@settings(max_examples=20, deadline=None)
def test_prop_search_vs_ref(seed, q):
    rng = np.random.RandomState(seed % (2 ** 31))
    srt = six.create(1 << 11, dtype=jnp.int32)
    keys = jnp.asarray(np.sort(rng.choice(10 ** 5, 500,
                                          replace=False)).astype(np.int32))
    srt = six.bulk_load(srt, keys, jnp.arange(500, dtype=I32))
    queries = jnp.asarray(rng.randint(0, 10 ** 5, q).astype(np.int32))
    want = ref.ref_sorted_search(queries, srt.keys, srt.addrs,
                                 fanout=CFG_ON.fanout)
    got = kops.search(CFG_ON, srt, queries)
    _eq((got[0], got[1].astype(I32), got[2]), want, "prop search vs ref")


@given(st.integers(0, 2 ** 31 - 2), st.integers(1, 64), st.integers(0, 50))
@settings(max_examples=20, deadline=None)
def test_prop_backup_probe_vs_ref(seed, q, npend):
    """Pending-window collisions: PUTs shadowed by newer DELs over the
    same keys must resolve newest-wins, identically in-kernel and in the
    jnp oracle."""
    rng = np.random.RandomState(seed % (2 ** 31))
    R = 2
    srt = six.create(1 << 10, dtype=jnp.int32)
    keys = jnp.asarray(np.sort(rng.choice(10 ** 4, 300,
                                          replace=False)).astype(np.int32))
    srt = six.bulk_load(srt, keys, jnp.arange(300, dtype=I32))
    stack = lambda t: jax.tree.map(
        lambda a: jnp.broadcast_to(a[None], (R,) + a.shape).copy(), t)
    srt_r, blogs = stack(srt), stack(lg.create(256, jnp.int32))
    l0 = jax.tree.map(lambda a: a[0], blogs)
    l0, _ = lg.append(l0, keys[:npend], jnp.full((max(npend, 1),), 7, I32
                                                 )[:npend],
                      jnp.full((npend,), 1, jnp.int8))
    l0, _ = lg.append(l0, keys[:npend // 2], jnp.full((npend // 2,), -1,
                                                      I32),
                      jnp.full((npend // 2,), 2, jnp.int8))
    blogs = jax.tree.map(lambda f, v: f.at[0].set(v), blogs, l0)
    queries = jnp.asarray(rng.randint(0, 10 ** 4, q).astype(np.int32))
    sel = jnp.asarray(rng.randint(0, 2, (q, R)).astype(np.int32))
    lkeys, laddrs, lops, lwin = kops._log_stack(blogs)
    want = ref.ref_backup_probe(CFG_ON, srt_r.keys, srt_r.addrs, lkeys,
                                laddrs, lops, lwin, queries, sel)
    got = kops.backup_probe(CFG_ON, srt_r, blogs, queries, sel)
    _eq((got[0], got[1].astype(I32), got[2]), want, "prop backup vs ref")


@given(st.integers(0, 2 ** 31 - 2), st.integers(1, 200))
@settings(max_examples=20, deadline=None)
def test_prop_merge_vs_ref(seed, m):
    rng = np.random.RandomState(seed % (2 ** 31))
    srt = six.create(1 << 10, dtype=jnp.int32)
    keys = jnp.asarray(np.sort(rng.choice(10 ** 4, 400,
                                          replace=False)).astype(np.int32))
    srt = six.bulk_load(srt, keys, jnp.arange(400, dtype=I32))
    bk = jnp.asarray(rng.randint(0, 10 ** 4, m).astype(np.int32))
    ba = jnp.asarray(rng.randint(0, 10 ** 6, m).astype(np.int32))
    bo = jnp.asarray(rng.choice([0, 1, 2], m).astype(np.int8))
    want = ref.ref_merge(srt.keys, srt.addrs, bk, ba, bo.astype(I32))
    got = kops.merge(CFG_ON, srt, bk, ba, bo)
    _eq((got.keys, got.addrs, got.size), want, "prop merge vs ref")


# ---------------------------------------------------------------------------
# client-level parity: identical seeded traces under both knob settings
# ---------------------------------------------------------------------------
_CFG_TRACE = dict(log_capacity=1 << 10, async_apply_batch=256)


def _trace_obs(client, seed):
    trace = gen_ops(seed, "uniform", n_events=10, batch=16)
    return replay(client, trace), trace


@pytest.mark.parametrize("seed", [101, 202])
def test_client_parity_local(seed):
    obs = {}
    for knob in ("on", "off"):
        cfg = scaled(use_kernels=knob, **_CFG_TRACE)
        client = HiStoreClient(LocalBackend(4096, cfg), batch_quantum=16)
        obs[knob], trace = _trace_obs(client, seed)
    assert_equivalent(obs["on"], obs["off"], label="local on-vs-off")
    # ... and the kernel path also matches the fault-oblivious oracle
    oracle = Oracle(value_words=CFG_ON.value_words)
    assert_equivalent(obs["on"], replay(oracle, trace),
                      label="local kernel-vs-oracle")


@pytest.mark.parametrize("seed", [303])
def test_client_parity_dist(seed):
    mesh = jax.make_mesh((len(jax.devices()),), (kv.AXIS,))
    obs = {}
    for knob in ("on", "off"):
        cfg = scaled(use_kernels=knob, **_CFG_TRACE)
        client = HiStoreClient(
            DistributedBackend(mesh, cfg, 4096, capacity_q=64,
                               scan_limit=128),
            batch_quantum=16, max_retries=32)
        obs[knob], trace = _trace_obs(client, seed)
        if knob == "on":
            # parity_report drains REPLICA COPIES through the same
            # dispatch layer: hash/sorted agreement must hold with the
            # kernel path serving every probe and merge
            assert all(p["agree"]
                       for p in kv.parity_report(client.backend.store, cfg))
    assert_equivalent(obs["on"], obs["off"], label="dist on-vs-off")
    oracle = Oracle(value_words=CFG_ON.value_words)
    assert_equivalent(obs["on"], replay(oracle, trace),
                      label="dist kernel-vs-oracle")


# ---------------------------------------------------------------------------
# Backend protocol (core/backend.py)
# ---------------------------------------------------------------------------
def test_backend_protocol_runtime_checkable():
    cfg = scaled(**_CFG_TRACE)
    lb = LocalBackend(1024, cfg)
    assert isinstance(lb, Backend)
    mesh = jax.make_mesh((len(jax.devices()),), (kv.AXIS,))
    db = DistributedBackend(mesh, cfg, 1024, capacity_q=64, scan_limit=64)
    assert isinstance(db, Backend)

    class NotABackend:
        pass

    assert not isinstance(NotABackend(), Backend)


def test_local_backend_sever_raises():
    cfg = scaled(**_CFG_TRACE)
    client = HiStoreClient(LocalBackend(1024, cfg))
    with pytest.raises(NotImplementedError, match="lease detector"):
        client.sever_server(0)
    with pytest.raises(NotImplementedError, match="lease detector"):
        client.sever_data_server(0)
    assert client.backend.lease_stalled() is False


# ---------------------------------------------------------------------------
# import order + deprecation shims
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("first,second", [
    ("repro.kernels", "repro.core"), ("repro.core", "repro.kernels")])
def test_import_order(first, second):
    """The kernels<->core import cycle must resolve from either entry
    point (kernels/ops.py imports core leaf modules; core/kvstore.py,
    index_group.py and data_plane.py import kernels/ops)."""
    code = (f"import {first}; import {second}; "
            "import repro.core.client, repro.kernels.ops; print('ok')")
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, cwd=ROOT,
                       env={**__import__('os').environ,
                            "PYTHONPATH": str(ROOT / "src")})
    assert r.returncode == 0, r.stderr
    assert "ok" in r.stdout


@pytest.mark.parametrize("mod", ["hash_probe", "sorted_search",
                                 "bitonic_sort"])
def test_deprecated_module_shims_warn(mod):
    code = ("import warnings; "
            "warnings.simplefilter('error', DeprecationWarning); "
            f"import repro.kernels.{mod}")
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, cwd=ROOT,
                       env={**__import__('os').environ,
                            "PYTHONPATH": str(ROOT / "src")})
    assert r.returncode != 0 and "DeprecationWarning" in r.stderr, (
        f"importing repro.kernels.{mod} must warn deprecation: {r.stderr}")
