"""Lease-based failure detection: the client discovers failures itself.

Two rigs:
  * the 8-device subprocess battery (tests/lease_selftest.py) — the real
    thing: sever-only schedules, the exact detection bound, online
    catch-up with interleaved foreground ops, multi-failure fallback
    rebuilds.  Deliberately NOT marked ``slow``: the detector is this
    PR's tentpole and the battery is sized for the fast tier (one mix,
    short trace).
  * in-process single-device tests — the capability edge (a 1-device
    mesh cannot wipe: every replica lives on the failing device), the
    explicit FailResult/warning surface of that divergence, and the
    detector's demote-on-stalled-heartbeats logic.
"""
import os
import subprocess
import sys
import warnings
from pathlib import Path

import jax
import numpy as np
import pytest

from repro.configs.histore import scaled
from repro.core import kvstore as kv
from repro.core.client import DistributedBackend, HiStoreClient

ROOT = Path(__file__).resolve().parents[1]
CFG = scaled(log_capacity=1 << 10, async_apply_batch=256, lease_misses=3)


def _one_dev_client(**kw):
    mesh = jax.make_mesh((len(jax.devices()),), (kv.AXIS,))
    return HiStoreClient(DistributedBackend(mesh, CFG, 512, capacity_q=64),
                         batch_quantum=16, **kw)


def test_single_device_fail_is_mask_only_and_says_so():
    """Satellite bugfix: a 1-device mesh folds every replica onto the
    failing device, so fail_server degrades to mask-only — that used to
    happen silently (``wipe=self.G > 1``); now the capability is surfaced
    as FailResult.wiped plus a RuntimeWarning, and the masked state
    survives to recovery."""
    client = _one_dev_client()
    keys = np.arange(1, 33)
    assert client.put(keys, keys).all_ok
    with pytest.warns(RuntimeWarning, match="mask-only"):
        r = client.fail_server(0)
    assert r.wiped is False and r.server == 0
    client.recover_server(0)
    g = client.get(keys)
    assert g.all_found, "mask-only failure must preserve the state"
    # the data plane's kill switch surfaces the same capability
    with pytest.warns(RuntimeWarning, match="mask-only"):
        rd = client.fail_data_server(0)
    assert rd.wiped is False
    client.recover_data_server(0)
    assert all(p["agree"] for p in kv.parity_report(client.backend.store,
                                                    CFG))


def test_sever_timeouts_then_detector_demotes():
    """A severed server answers nothing: ops time out (un-acked / un-
    routed, never wrong answers) while the detector ages the stalled
    heartbeat, demotes within the lease bound, and recovery re-admits."""
    client = _one_dev_client()
    backend = client.backend
    keys = np.arange(1, 17)
    assert client.put(keys, keys).all_ok
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")    # 1-dev mask-only warning
        r = client.sever_server(0)
    assert r.wiped is False
    assert 0 not in backend._dead, "sever must NOT update the routing view"
    # with every server severed ops push back visibly (and each retry is
    # an observation round, so the lease expires inside the loop)
    g = client.get(keys)
    assert not bool(np.asarray(g.routed).any()), \
        "pre-recovery reads must report push-back, not misses"
    assert not bool(np.asarray(g.found).any())
    assert backend.detected == [0], \
        f"detector must demote within the bound (got {backend.detected})"
    rec = client.recover_server(0)
    assert rec.server == 0 and not backend._severed
    g2 = client.get(keys)
    assert g2.all_found, "mask-only sever preserves state through recovery"


def test_detector_disabled_without_lease_misses():
    """lease_misses=0 turns detection off: no heartbeat reads, no
    demotions — the oracle kill switches still work as before."""
    mesh = jax.make_mesh((len(jax.devices()),), (kv.AXIS,))
    cfg0 = scaled(log_capacity=1 << 10, async_apply_batch=256,
                  lease_misses=0)
    client = HiStoreClient(DistributedBackend(mesh, cfg0, 256,
                                              capacity_q=64),
                           batch_quantum=16)
    keys = np.arange(1, 17)
    assert client.put(keys, keys).all_ok
    assert client.backend.lease_misses == 0
    client.get(keys)
    assert client.backend.detected == []


def test_recover_result_reports_online_mode():
    """recover_server surfaces what it did: online snapshot recovery by
    default, the stop-the-world drain on request."""
    client = _one_dev_client()
    keys = np.arange(1, 17)
    assert client.put(keys, keys).all_ok
    client.drain()
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        client.fail_server(0)
    rec = client.backend.recover_server(0, online=False)
    assert rec.online is False and rec.catch_up_pending == 0


def test_lease_battery_8dev():
    """The full detector battery (see tests/lease_selftest.py): severed
    heartbeats only, detection bound, online catch-up under foreground
    load, multi-failure fallback rebuilds, typed RecoveryError."""
    env = dict(os.environ,
               PYTHONPATH=os.pathsep.join(
                   [str(ROOT / "src"), str(ROOT / "tests")]),
               XLA_FLAGS="--xla_force_host_platform_device_count=8")
    proc = subprocess.run(
        [sys.executable, str(ROOT / "tests/lease_selftest.py")],
        env=env, capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stdout[-2000:] + proc.stderr[-2000:]
    assert "LEASE-SELFTEST-OK" in proc.stdout
