"""Lease-based failure detection: the client discovers failures itself.

Two rigs:
  * the 8-device subprocess battery (tests/lease_selftest.py) — the real
    thing: sever-only schedules, the exact detection bound, online
    catch-up with interleaved foreground ops, multi-failure fallback
    rebuilds.  Deliberately NOT marked ``slow``: the detector is this
    PR's tentpole and the battery is sized for the fast tier (one mix,
    short trace).
  * in-process single-device tests — the capability edge (a 1-device
    mesh cannot wipe: every replica lives on the failing device), the
    explicit FailResult/warning surface of that divergence, and the
    detector's demote-on-stalled-heartbeats logic.
"""
import time
import warnings
from pathlib import Path

import jax
import numpy as np
import pytest

from repro.configs.histore import scaled
from repro.core import kvstore as kv
from repro.core.client import DistributedBackend, HiStoreClient

from _battery import run_battery

ROOT = Path(__file__).resolve().parents[1]
# rounds clock: these tests assert the deterministic observation-round
# bound; the wall-clock (default) path has its own tests below
CFG = scaled(log_capacity=1 << 10, async_apply_batch=256, lease_misses=3,
             lease_clock="rounds")


def _one_dev_client(cfg=CFG, **kw):
    mesh = jax.make_mesh((len(jax.devices()),), (kv.AXIS,))
    return HiStoreClient(DistributedBackend(mesh, cfg, 512, capacity_q=64),
                         batch_quantum=16, **kw)


def test_single_device_fail_is_mask_only_and_says_so():
    """Satellite bugfix: a 1-device mesh folds every replica onto the
    failing device, so fail_server degrades to mask-only — that used to
    happen silently (``wipe=self.G > 1``); now the capability is surfaced
    as FailResult.wiped plus a RuntimeWarning, and the masked state
    survives to recovery."""
    client = _one_dev_client()
    keys = np.arange(1, 33)
    assert client.put(keys, keys).all_ok
    with pytest.warns(RuntimeWarning, match="mask-only"):
        r = client.fail_server(0)
    assert r.wiped is False and r.server == 0
    client.recover_server(0)
    g = client.get(keys)
    assert g.all_found, "mask-only failure must preserve the state"
    # the data plane's kill switch surfaces the same capability
    with pytest.warns(RuntimeWarning, match="mask-only"):
        rd = client.fail_data_server(0)
    assert rd.wiped is False
    client.recover_data_server(0)
    assert all(p["agree"] for p in kv.parity_report(client.backend.store,
                                                    CFG))


def test_sever_timeouts_then_detector_demotes():
    """A severed server answers nothing: ops time out (un-acked / un-
    routed, never wrong answers) while the detector ages the stalled
    heartbeat, demotes within the lease bound, and recovery re-admits."""
    client = _one_dev_client()
    backend = client.backend
    keys = np.arange(1, 17)
    assert client.put(keys, keys).all_ok
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")    # 1-dev mask-only warning
        r = client.sever_server(0)
    assert r.wiped is False
    assert 0 not in backend._dead, "sever must NOT update the routing view"
    # with every server severed ops push back visibly (and each retry is
    # an observation round, so the lease expires inside the loop)
    g = client.get(keys)
    assert not bool(np.asarray(g.routed).any()), \
        "pre-recovery reads must report push-back, not misses"
    assert not bool(np.asarray(g.found).any())
    assert backend.detected == [0], \
        f"detector must demote within the bound (got {backend.detected})"
    rec = client.recover_server(0)
    assert rec.server == 0 and not backend._severed
    g2 = client.get(keys)
    assert g2.all_found, "mask-only sever preserves state through recovery"


def test_detector_disabled_without_lease_misses():
    """lease_misses=0 turns detection off: no heartbeat reads, no
    demotions, no ticker — the oracle kill switches still work."""
    mesh = jax.make_mesh((len(jax.devices()),), (kv.AXIS,))
    cfg0 = scaled(log_capacity=1 << 10, async_apply_batch=256,
                  lease_misses=0)
    client = HiStoreClient(DistributedBackend(mesh, cfg0, 256,
                                              capacity_q=64),
                           batch_quantum=16)
    keys = np.arange(1, 17)
    assert client.put(keys, keys).all_ok
    assert client.backend.lease_misses == 0
    client.get(keys)
    assert client.backend.detected == []
    assert client.start_ticker() is False, \
        "a disabled detector must not spawn a ticker thread"


def test_rounds_mode_exact_bound_regression():
    """The deterministic rounds-clock bound is UNCHANGED by the wall
    clock: a severed server is demoted on exactly the lease_misses-th
    stalled observation round — no sooner, no later."""
    client = _one_dev_client()
    backend = client.backend
    keys = np.arange(1, 17)
    assert client.put(keys, keys).all_ok
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")    # 1-dev mask-only warning
        client.sever_server(0)
    for i in range(CFG.lease_misses):
        assert 0 not in backend._dead, \
            f"demoted after only {i} rounds (bound is {CFG.lease_misses})"
        backend._lease_tick(bump=True)     # one observation round
    assert backend.detected == [0]


def test_oracle_fail_resets_stall_accounting():
    """An oracle kill after a partially-aged lease must clear the stall
    flag: a known-dead server can no longer 'stall', so healthy
    push-back retries never latch onto wall-mode pacing."""
    client = _one_dev_client()
    backend = client.backend
    keys = np.arange(1, 17)
    assert client.put(keys, keys).all_ok
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")    # 1-dev mask-only warnings
        client.sever_server(0)
        backend._lease_tick(bump=True)     # one stalled observation
        assert backend.lease_stalled()
        client.fail_server(0)              # oracle masking takes over
    assert not backend.lease_stalled(), \
        "a known-dead server must not latch the stall flag"


def test_wall_clock_ticker_detects_while_idle():
    """Wall-clock leases (the default): after a sever the background
    ticker alone — zero foreground ops — demotes within lease_timeout_s
    plus one tick interval (plus scheduling slack)."""
    wcfg = scaled(log_capacity=1 << 10, async_apply_batch=256,
                  lease_misses=3, lease_clock="wall",
                  lease_timeout_s=0.5, lease_interval_s=0.1)
    client = _one_dev_client(cfg=wcfg)
    backend = client.backend
    keys = np.arange(1, 17)
    assert client.put(keys, keys).all_ok
    backend._lease_tick(bump=True)     # compile the tick op pre-sever
    assert client.start_ticker()
    try:
        with warnings.catch_warnings():
            warnings.simplefilter("ignore")
            client.sever_server(0)
        stats0 = dict(client.stats)
        budget = wcfg.lease_timeout_s + wcfg.lease_interval_s + 3.0
        t0 = time.monotonic()
        while 0 not in backend._dead:
            time.sleep(0.02)
            assert time.monotonic() - t0 <= budget, \
                "idle wall-clock detection must fire within the lease"
    finally:
        client.stop_ticker()
    assert backend.detected == [0]
    assert dict(client.stats) == stats0, \
        "the ticker must not have issued foreground ops"
    client.recover_server(0)
    assert client.get(keys).all_found


def test_wall_clock_detection_completes_within_retry_loop():
    """Wall-mode retry pacing: on hardware where retries burn in
    milliseconds, the paced loop must still span a lease timeout, so a
    single client op against a severed server DETECTS within its own
    retry budget (the rounds-mode guarantee, preserved)."""
    wcfg = scaled(log_capacity=1 << 10, async_apply_batch=256,
                  lease_misses=3, lease_clock="wall",
                  lease_timeout_s=0.4, lease_interval_s=0.1)
    client = _one_dev_client(cfg=wcfg)
    backend = client.backend
    keys = np.arange(1, 17)
    assert client.put(keys, keys).all_ok
    client.get(keys)                    # warm the compiled get+tick
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        client.sever_server(0)
    g = client.get(keys)                # one op: paced retries inside
    assert not bool(np.asarray(g.routed).any())
    assert backend.detected == [0], \
        "the paced retry loop must outlast the wall-clock lease"
    client.recover_server(0)
    assert client.get(keys).all_found


def test_data_server_lease_detection_one_dev():
    """The unified plane covers DATA servers: severed data heartbeats
    age the data lease; demotion lands in detected_data (never in the
    index detector's list); recovery from the detected state re-admits."""
    client = _one_dev_client()
    backend = client.backend
    keys = np.arange(1, 17)
    assert client.put(keys, keys).all_ok
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")    # 1-dev mask-only warning
        r = client.sever_data_server(0)
    assert r.wiped is False
    assert 0 not in backend._data_dead, \
        "sever_data must NOT update the routing view"
    g = client.get(keys)   # each retry is an observation round
    assert not bool(np.asarray(g.routed).any()), \
        "reads of a crashed data shard push back, never fabricate"
    assert backend.detected_data == [0] and backend.detected == []
    client.recover_data_server(0)
    g2 = client.get(keys)
    assert g2.all_found, "mask-only sever preserves state to recovery"


def test_ticker_does_not_pin_a_dropped_backend():
    """The ticker thread holds only a weakref: dropping the last client
    reference without stop_ticker() must stop the loop (and release the
    device-resident store) instead of ticking forever."""
    import gc
    wcfg = scaled(log_capacity=1 << 10, async_apply_batch=256,
                  lease_misses=3, lease_clock="wall",
                  lease_timeout_s=0.5, lease_interval_s=0.05)
    client = _one_dev_client(cfg=wcfg)
    assert client.start_ticker()
    t = client.backend._ticker
    del client
    gc.collect()
    t.join(timeout=10.0)
    assert not t.is_alive(), \
        "a garbage-collected backend must end its ticker thread"


def test_lease_misconfiguration_raises():
    """A liveness plane that silently disables itself is the exact gap
    this subsystem closes: an unknown clock or a wall clock without a
    timeout must fail construction, not fall back quietly."""
    mesh = jax.make_mesh((len(jax.devices()),), (kv.AXIS,))
    with pytest.raises(ValueError, match="lease_clock"):
        DistributedBackend(mesh, scaled(lease_clock="Wall"), 64,
                           capacity_q=16)
    with pytest.raises(ValueError, match="lease_timeout_s"):
        DistributedBackend(mesh, scaled(lease_timeout_s=0.0), 64,
                           capacity_q=16)
    # lease_misses=0 is the sanctioned off switch — no timeout needed
    b = DistributedBackend(mesh, scaled(lease_misses=0,
                                        lease_timeout_s=0.0), 64,
                           capacity_q=16)
    assert b.lease_misses == 0


def test_run_battery_persists_logs_on_timeout(tmp_path):
    """A HUNG battery must still leave its partial transcript in
    test-logs/ (the CI artifact) before TimeoutExpired propagates."""
    import subprocess
    from _battery import LOG_DIR, run_battery
    stub = tmp_path / "hang.py"
    stub.write_text("import sys, time\n"
                    "print('partial line', flush=True)\n"
                    "time.sleep(60)\n")
    with pytest.raises(subprocess.TimeoutExpired):
        run_battery(stub, "hang_stub", timeout=3)
    out = (LOG_DIR / "hang_stub.out").read_text()
    err = (LOG_DIR / "hang_stub.err").read_text()
    assert "partial line" in out
    assert "killed after 3s timeout" in err
    (LOG_DIR / "hang_stub.out").unlink()
    (LOG_DIR / "hang_stub.err").unlink()


def test_scan_completeness_flag_one_dev():
    """ScanResult.complete: a scan that cannot cover a group names it;
    recovery restores complete=True with the range back."""
    client = _one_dev_client()
    keys = np.arange(1, 33)
    assert client.put(keys, keys).all_ok
    s0 = client.scan(0, 10 ** 6)
    assert s0.complete is True and s0.missing_groups == ()
    assert s0.is_complete
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        client.sever_server(0)
    s1 = client.scan(0, 10 ** 6)
    assert s1.complete is False and s1.missing_groups == (0,)
    assert not s1.is_complete
    assert client.backend.detected == [0], \
        "the completeness retries must drive detection"
    client.recover_server(0)
    s2 = client.scan(0, 10 ** 6)
    assert s2.complete is True and int(s2.count) == 32


def test_recover_result_reports_online_mode():
    """recover_server surfaces what it did: online snapshot recovery by
    default, the stop-the-world drain on request."""
    client = _one_dev_client()
    keys = np.arange(1, 17)
    assert client.put(keys, keys).all_ok
    client.drain()
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        client.fail_server(0)
    rec = client.backend.recover_server(0, online=False)
    assert rec.online is False and rec.catch_up_pending == 0


def test_lease_battery_8dev():
    """The full detector battery (see tests/lease_selftest.py): severed
    heartbeats only, detection bound, online catch-up under foreground
    load, multi-failure fallback rebuilds, typed RecoveryError, the
    data-server lease phase, the idle wall-clock ticker phase, and the
    scan-completeness phase."""
    proc = run_battery(ROOT / "tests/lease_selftest.py", "lease_selftest",
                       extra_pythonpath=[ROOT / "tests"], timeout=1500)
    assert proc.returncode == 0, proc.stdout[-2000:] + proc.stderr[-2000:]
    assert "LEASE-SELFTEST-OK" in proc.stdout
