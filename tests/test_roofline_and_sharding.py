"""Roofline HLO parsing, term math, and partition-rule invariants."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, SHAPES, get_config, input_specs, \
    shape_applicable
from repro.roofline.analysis import (collective_bytes_from_hlo,
                                     roofline_terms)


def test_collective_parser():
    hlo = """
  %ar = bf16[128,256]{1,0} all-reduce(%x), replica_groups={}
  %ag.1 = f32[64]{0} all-gather-start(%y)
  %p = (bf16[8,8]{1,0}, bf16[8,8]{1,0}) collective-permute(%z)
  %aa = s32[1024]{0} all-to-all(%w)
  %rs = f32[32,32]{1,0} reduce-scatter(%v)
  %not_a_coll = f32[999]{0} add(%a, %b)
"""
    out = collective_bytes_from_hlo(hlo)
    b = out["bytes_by_type"]
    assert b["all-reduce"] == 128 * 256 * 2
    assert b["all-gather"] == 64 * 4
    assert b["collective-permute"] == 2 * 8 * 8 * 2
    assert b["all-to-all"] == 1024 * 4
    assert b["reduce-scatter"] == 32 * 32 * 4
    assert out["total_bytes"] == sum(b.values())


def test_roofline_terms_dominance():
    t = roofline_terms(197e12 * 2.0, 819e9 * 0.5, 50e9 * 1.0)
    assert t["dominant"] == "compute_s"
    assert abs(t["compute_s"] - 2.0) < 1e-9
    assert abs(t["roofline_fraction_compute"] - 1.0) < 1e-9
    t = roofline_terms(197e12, 819e9 * 10, 0)
    assert t["dominant"] == "memory_s"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_param_specs_divisible_on_production_mesh(arch):
    """Every sharded dim of every param divides its mesh axes (the
    guarantee that made the 40-cell dry-run compile)."""
    from repro.models.transformer import init_params
    from repro.sharding.partition import param_pspecs

    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 16, "model": 16}

    cfg = get_config(arch)
    params_s = jax.eval_shape(lambda k: init_params(cfg, k),
                              jax.random.PRNGKey(0))
    specs = param_pspecs(cfg, params_s, FakeMesh())

    def check(leaf, spec):
        for dim, ax in zip(leaf.shape, tuple(spec)):
            if ax is None:
                continue
            sz = 1
            for a in (ax if isinstance(ax, tuple) else (ax,)):
                sz *= FakeMesh.shape[a]
            assert dim % sz == 0, (arch, leaf.shape, tuple(spec))

    jax.tree.map(check, params_s, specs,
                 is_leaf=lambda x: hasattr(x, "shape") and not isinstance(x, dict))


def test_shape_applicability_rules():
    full_attn = ["mistral-large-123b", "command-r-35b", "mistral-nemo-12b",
                 "internvl2-76b", "musicgen-large", "deepseek-v2-lite-16b",
                 "kimi-k2-1t-a32b"]
    subq = ["zamba2-7b", "falcon-mamba-7b", "gemma3-27b"]
    for a in full_attn:
        ok, why = shape_applicable(get_config(a), SHAPES["long_500k"])
        assert not ok and "sub-quadratic" in why
    for a in subq:
        ok, _ = shape_applicable(get_config(a), SHAPES["long_500k"])
        assert ok
    for a in ARCH_IDS:
        for s in ("train_4k", "prefill_32k", "decode_32k"):
            assert shape_applicable(get_config(a), SHAPES[s])[0]


def test_input_specs_shapes():
    for a in ARCH_IDS:
        cfg = get_config(a)
        d = input_specs(cfg, SHAPES["train_4k"])
        if cfg.frontend == "embed":
            assert d["embeds"].shape == (256, 4096, cfg.d_model)
        else:
            assert d["tokens"].shape == (256, 4096)
        d = input_specs(cfg, SHAPES["decode_32k"])
        assert d["pos"].shape == (128,)


def test_with_opts_parsing():
    cfg = get_config("kimi-k2-1t-a32b")
    c2 = cfg.with_opts("moe_impl=smap,attn_block_skip=true,top_k=4")
    assert c2.moe_impl == "smap" and c2.attn_block_skip and c2.top_k == 4
    assert cfg.with_opts("") is cfg
