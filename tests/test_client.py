"""HiStoreClient tests: typed results, batch padding, overflow retry,
distributed DELETE round-trip, and local/distributed backend parity on a
shared op trace (the 8-device battery lives in dist_selftest.py; here the
distributed backend runs on the single-device mesh of the pytest process).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.histore import scaled
from repro.core import kvstore as kv
from repro.core.client import (DistributedBackend, HiStoreClient,
                               LocalBackend)
from repro.core.results import GetResult, PutResult, ScanResult

CFG = scaled(log_capacity=1 << 10, async_apply_batch=256)


def _mesh():
    return jax.make_mesh((len(jax.devices()),), (kv.AXIS,))


def _local_client(**kw):
    kw.setdefault("batch_quantum", 32)
    return HiStoreClient(LocalBackend(4096, CFG), **kw)


def _dist_client(capacity_q=64, **kw):
    kw.setdefault("batch_quantum", 32)
    return HiStoreClient(
        DistributedBackend(_mesh(), CFG, 4096, capacity_q=capacity_q,
                           scan_limit=128), **kw)


def _keys(n, seed=0, base=0):
    return np.random.RandomState(seed).choice(10 ** 6, n,
                                              replace=False) + 1 + base


def test_typed_results_roundtrip_local():
    c = _local_client()
    keys = _keys(100)
    res = c.put(keys, np.arange(100))
    assert isinstance(res, PutResult)
    assert res.ok.shape == (100,) and res.all_ok and res.retries == 0
    g = c.get(keys)
    assert isinstance(g, GetResult) and g.all_found
    np.testing.assert_array_equal(np.asarray(g.values)[:, 0], np.arange(100))
    s = c.scan(0, 10 ** 7, limit=128)
    assert isinstance(s, ScanResult)
    assert int(s.count) == 100
    np.testing.assert_array_equal(np.asarray(s.keys)[:100], np.sort(keys))


def test_batches_pad_and_split_without_shape_leak():
    c = _local_client(batch_quantum=32, max_batch=64)
    # every odd size below quantum, above quantum, and above max_batch
    for n, seed in [(1, 1), (7, 2), (33, 3), (150, 4)]:
        ks = _keys(n, seed=seed, base=seed * 10 ** 6)
        r = c.put(ks, np.arange(n))
        assert r.ok.shape == (n,) and r.all_ok
        g = c.get(ks)
        assert g.found.shape == (n,) and g.all_found
        assert g.values.shape[0] == n


def test_overflow_retry_distributed_put_get():
    """Force a tiny exchange capacity: every put must still eventually ack
    through the client's push-back retry loop, and reads must see them."""
    c = _dist_client(capacity_q=4, max_retries=64)
    keys = _keys(64, seed=5)
    res = c.put(keys, np.arange(64))
    assert res.all_ok, "all puts must eventually be acknowledged"
    assert res.retries > 0, "tiny capacity must engage the retry loop"
    g = c.get(keys)
    assert g.all_found
    np.testing.assert_array_equal(np.asarray(g.values)[:, 0], np.arange(64))


def test_distributed_delete_roundtrip():
    """PUT -> DELETE -> GET miss -> SCAN excludes the key."""
    c = _dist_client()
    keys = _keys(64, seed=6)
    assert c.put(keys, np.arange(64)).all_ok
    d = c.delete(keys[:16])
    assert bool(d.ok.all()) and bool(d.found.all())
    g = c.get(keys[:16])
    assert not bool(g.found.any()), "deleted keys must miss"
    g2 = c.get(keys[16:])
    assert g2.all_found, "survivors must still hit"
    s = c.scan(0, 10 ** 7)
    got = set(np.asarray(s.keys[: int(s.count)]).tolist())
    assert got == set(int(k) for k in keys[16:])
    # delete of a missing key: acked but not found
    d2 = c.delete(keys[:5])
    assert bool(d2.ok.all()) and not bool(d2.found.any())


def test_local_distributed_parity_on_shared_trace():
    """Both backends must agree on found-masks, values, delete founds,
    replication counts and scan contents for the same op trace.  (The
    trace is deliberately small — one put/get/delete/scan round each; the
    heavy randomized coverage lives in tests/test_fault_injection.py.)"""
    clients = [_local_client(), _dist_client()]
    keys = _keys(64, seed=7)
    probes = np.concatenate([keys[:16], keys[:16] + 10 ** 7])  # hits+misses
    outs = []
    for c in clients:
        trace = {}
        r = c.put(keys, np.arange(64))
        trace["put_ok"] = np.asarray(r.ok)
        trace["put_rep"] = np.asarray(r.replicas)
        g = c.get(probes)
        trace["found"] = np.asarray(g.found)
        trace["vals"] = np.asarray(g.values)[:, 0] * trace["found"]
        d = c.delete(keys[20:36])
        trace["del_found"] = np.asarray(d.found)
        trace["del_rep"] = np.asarray(d.replicas)
        g2 = c.get(keys)
        trace["found2"] = np.asarray(g2.found)
        s = c.scan(0, 10 ** 7, limit=128)
        n = int(s.count)
        trace["scan_n"] = n
        trace["scan_keys"] = np.sort(np.asarray(s.keys)[:n])
        outs.append(trace)
    a, b = outs
    for k in a:
        np.testing.assert_array_equal(a[k], b[k], err_msg=k)
    assert (a["put_rep"] == CFG.n_backups).all()


def test_apply_every_n_ops_policy():
    c = _local_client(apply_every_n_ops=64)
    for i in range(4):
        c.put(_keys(40, seed=10 + i, base=i * 10 ** 6), np.arange(40))
    # 160 mutations at a 64-op cadence -> at least 2 scheduled applies
    assert c.stats["applies"] >= 2
    # applies actually drained into the sorted replicas
    assert c.backend.pending_ops() < 160


def test_get_result_hops_channel():
    """GetResult.hops is part of the client contract: 1 per routed read on
    a healthy store, chunk-stable, and positionally backward-compatible
    (constructing a GetResult without routed/hops still works — the
    oracle does exactly that)."""
    from repro.core.results import GetResult as GR
    legacy = GR(np.zeros(2, np.int32), np.zeros(2, bool),
                np.zeros(2, np.int32), np.zeros((2, 4), np.int32))
    assert legacy.routed is None and legacy.hops is None and legacy.one_rtt
    for c in (_local_client(), _dist_client()):
        ks = _keys(70, seed=9)
        assert c.put(ks, np.arange(70)).all_ok
        g = c.get(ks)   # spans two 64-lane chunks
        assert g.all_found and g.one_rtt
        np.testing.assert_array_equal(np.asarray(g.hops), np.ones(70))
        miss = c.get(ks + 10 ** 7)
        assert not bool(miss.found.any())
        np.testing.assert_array_equal(np.asarray(miss.hops), np.ones(70))


def test_serving_release_drains_long_sequences():
    """Regression for the release page-leak: a sequence with more pages
    than the old hard-coded SCAN limit of 64 must still be fully
    reclaimed (the limit now derives from max_len // page_size and the
    scan repeats until the range drains)."""
    pytest.importorskip("repro.models.transformer")
    from repro.configs.tiny import tiny_config
    from repro.models.transformer import init_params
    from repro.serving.engine import Request, ServingEngine, page_key

    cfg = tiny_config("musicgen-large")
    params = init_params(cfg, jax.random.PRNGKey(0))
    eng = ServingEngine(cfg, params, batch_slots=1, max_len=1024,
                        page_size=8)
    budget = eng.max_len // eng.page_size
    assert budget > 64  # the old hard-coded limit would leak here
    rid = 123
    taken = [eng.free_pages.pop() for _ in range(budget)]
    free_before = len(eng.free_pages)
    for i, addr in enumerate(taken):
        eng.client.put([page_key(rid, i)], [addr])
    r = Request(rid, [1, 2, 3], 4)
    eng.release(r)
    assert len(eng.free_pages) == free_before + budget, "pages leaked"
    # releasing again reclaims nothing (no double-free)
    eng.release(r)
    assert len(eng.free_pages) == free_before + budget
